"""JAX version-compat shims (single import site for API drift).

The repo targets the ``jax.sharding.AxisType`` / ``jax.set_mesh`` API
surface of recent JAX, but must also run on older installs (the container
pins 0.4.x, where neither exists).  Everything version-dependent goes
through this module so call sites never probe ``hasattr`` themselves:

  ``make_mesh(shape, axes)``   — ``jax.make_mesh`` with explicit Auto axis
                                 types when the install supports them.
  ``set_mesh(mesh)``           — context manager: ``jax.set_mesh`` /
                                 ``jax.sharding.use_mesh`` / plain
                                 ``with mesh:`` (oldest API), whichever
                                 exists.
  ``AXIS_TYPE_AUTO``           — ``jax.sharding.AxisType.Auto`` or ``None``
                                 when the enum predates this install.
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["AXIS_TYPE_AUTO", "all_to_all", "make_mesh", "pcast", "set_mesh",
           "shard_map"]

AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API has them."""
    if AXIS_TYPE_AUTO is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AXIS_TYPE_AUTO,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    # oldest API: Mesh is itself a context manager
    return contextlib.nullcontext(mesh) if mesh is None else mesh


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                                  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # noqa: F401


def all_to_all(x, axis_name, *, split_axis: int = 0, concat_axis: int = 0):
    """Tiled ``jax.lax.all_to_all`` over one shard_map axis (or a tuple of
    axes, collectived jointly).  ``x`` is the local ``(S, ...)`` lane
    stack: lane ``s`` of the result is what shard ``s`` addressed to this
    shard — the batched per-shard-group exchange the coded executor's
    residual combining runs on."""
    name = axis_name if not (isinstance(axis_name, (tuple, list))
                             and len(axis_name) == 1) else axis_name[0]
    return jax.lax.all_to_all(x, name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def pcast(x, axis_name, *, to: str = "varying"):
    """``jax.lax.pcast`` where it exists; identity on older JAX, whose
    shard_map treats every value as device-varying already."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_name, to=to)
    return x
