"""Pure-jnp oracles for the SSD scan.

``ssd_scan_ref``     — literal per-step recurrence (ground truth; O(S) scan
                       steps, slow and HBM-heavy — baseline path).
``ssd_scan_chunked`` — chunked formulation in pure jnp, same math as the
                       Pallas kernel: intra-chunk masked matmul + O(S/Q)
                       scan over chunk states.  This is the XLA-only
                       production path (hillclimb §Perf): it turns S scan
                       iterations into S/Q and makes the hot loop MXU work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref", "ssd_scan_chunked"]


def ssd_scan_ref(x, log_a, b, c):
    """x (S,P), log_a (S,), b (S,N), c (S,N) -> y (S,P).

    h_t = a_t h_{t-1} + b_t x_t^T ;  y_t = c_t . h_t
    """
    S, P = x.shape
    N = b.shape[1]

    def step(h, inp):
        xt, lat, bt, ct = inp
        h = jnp.exp(lat) * h + jnp.outer(bt, xt)
        y = ct @ h
        return h, y

    h0 = jnp.zeros((N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (x.astype(jnp.float32), log_a.astype(jnp.float32),
         b.astype(jnp.float32), c.astype(jnp.float32)))
    return ys.astype(x.dtype)


def ssd_scan_chunked(x, log_a, b, c, *, chunk: int = 128):
    """Chunked SSD, pure jnp (same recurrence as ssd_scan_ref)."""
    S, P = x.shape
    N = b.shape[1]
    Q = min(chunk, S)
    pad = -S % Q
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
        log_a = jnp.pad(log_a, (0, pad))
    nc = x.shape[0] // Q
    xc = x.reshape(nc, Q, P).astype(jnp.float32)
    bc = b.reshape(nc, Q, N).astype(jnp.float32)
    cc = c.reshape(nc, Q, N).astype(jnp.float32)
    lac = jnp.cumsum(log_a.reshape(nc, Q, 1).astype(jnp.float32), axis=1)

    rows = jnp.arange(Q)[:, None]
    cols = jnp.arange(Q)[None, :]
    tri = cols <= rows                                    # (Q, Q)

    def step(h, inp):
        xq, bq, cq, la = inp                              # (Q,P),(Q,N),(Q,1)
        decay = jnp.exp(la - la.T)
        g = jnp.where(tri, (cq @ bq.T) * decay, 0.0)
        y = g @ xq + (cq * jnp.exp(la)) @ h               # intra + inter
        la_end = la[-1:, :]
        h = jnp.exp(la_end) * h + bq.T @ (xq * jnp.exp(la_end - la))
        return h, y

    h0 = jnp.zeros((N, P), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xc, bc, cc, lac))
    return ys.reshape(nc * Q, P)[:S].astype(x.dtype)
