"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

One head: inputs x (S, P), per-step log-decay log_a (S, 1) <= 0, input/output
projections B, C (S, N).  Recurrence

    h_t = a_t h_{t-1} + B_t (x_t)^T        y_t = C_t . h_t

is evaluated chunk-by-chunk (chunk Q): the intra-chunk term is a masked
(Q, Q) "attention" matmul on the MXU; the inter-chunk term carries the
(N, P) state in VMEM scratch across the sequential chunk grid.  All decay
factors are exponentials of non-positive numbers — numerically stable.

This is the TPU-native adaptation of the SSD algorithm: instead of the GPU
warp-level scan, chunks map to MXU matmuls + one small sequential grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _ssd_kernel(x_ref, b_ref, c_ref, la_ref, y_ref, h_ref, *, Q: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)        # (Q, P)
    b = b_ref[...].astype(jnp.float32)        # (Q, N)
    c = c_ref[...].astype(jnp.float32)        # (Q, N)
    la = jnp.cumsum(la_ref[...].astype(jnp.float32), axis=0)  # (Q, 1)

    # intra-chunk: masked decay-weighted attention
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(la - la.T)                 # (Q, Q); <=1 below diagonal
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    g = jnp.where(cols <= rows, g * decay, 0.0)
    y = jax.lax.dot_general(g, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of carried state
    h = h_ref[...]                             # (N, P)
    y += jax.lax.dot_general(c * jnp.exp(la), h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update
    la_end = la[-1:, :]                        # (1, 1)
    w = jnp.exp(la_end - la)                   # (Q, 1)
    h_ref[...] = jnp.exp(la_end) * h + jax.lax.dot_general(
        b, x * w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,                  # (S, P)
    log_a: jax.Array,              # (S,) log decay, <= 0
    b: jax.Array,                  # (S, N)
    c: jax.Array,                  # (S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:                    # (S, P)
    S, P = x.shape
    N = b.shape[1]
    Q = min(chunk, S)
    pad = -S % Q
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
        log_a = jnp.pad(log_a, (0, pad))
    Sp = x.shape[0]
    la2 = log_a[:, None].astype(jnp.float32)
    n_chunks = Sp // Q

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((Q, P), lambda t: (t, 0)),
            pl.BlockSpec((Q, N), lambda t: (t, 0)),
            pl.BlockSpec((Q, N), lambda t: (t, 0)),
            pl.BlockSpec((Q, 1), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((Q, P), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, b, c, la2)
    return out[:S]
