"""Batched/multi-head wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import ssd_scan_chunked, ssd_scan_ref
from .ssd import ssd_scan

__all__ = ["ssd"]


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel",
                                             "interpret", "impl"))
def ssd(x, log_a, b, c, *, chunk=128, use_kernel=False, interpret=True,
        impl="step"):
    """x (B, S, H, P), log_a (B, S, H), b/c (B, S, H, N) -> (B, S, H, P).

    impl: 'step' (literal recurrence, baseline) | 'chunked' (XLA-only
    production path, S/chunk scan iterations of MXU matmuls)."""
    def one_head(xh, lah, bh, ch):
        if use_kernel:
            return ssd_scan(xh, lah, bh, ch, chunk=chunk,
                            interpret=interpret)
        if impl == "chunked":
            return ssd_scan_chunked(xh, lah, bh, ch, chunk=chunk)
        return ssd_scan_ref(xh, lah, bh, ch)

    # (B, H, S, *)
    xt = x.transpose(0, 2, 1, 3)
    lat = log_a.transpose(0, 2, 1)
    bt = b.transpose(0, 2, 1, 3)
    ct = c.transpose(0, 2, 1, 3)
    out = jax.vmap(jax.vmap(one_head))(xt, lat, bt, ct)
    return out.transpose(0, 2, 1, 3)
