"""Pure-jnp oracle for flash attention (one head)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    Sq, d = q.shape
    Skv = k.shape[0]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    rows = jnp.arange(Sq)[:, None]
    cols = jnp.arange(Skv)[None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= (rows - cols) < window
        if not causal:
            mask &= (cols - rows) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
