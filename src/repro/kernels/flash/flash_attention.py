"""Pallas TPU kernel: flash attention (causal / sliding-window / full).

Online-softmax blocked attention for one head: grid is (q_blocks, kv_blocks)
with the kv dimension innermost and sequential; running max / normalizer /
output accumulator live in VMEM scratch across the kv sweep, so HBM traffic
is O(S * d) instead of O(S^2).

Used by: prefill attention for every transformer arch (GQA wrappers vmap over
heads and batch; KV heads are broadcast to query groups in ops.py), and the
window path implements Mixtral SWA / Gemma-3 local layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, n_kv: int, skv: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                                     # (bq, d)
    k = k_ref[...]                                     # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (bq, bk)

    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < skv          # padded kv rows never win the softmax
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= (rows - cols) < window
        if not causal:
            mask &= (cols - rows) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,                  # (Sq, d)
    k: jax.Array,                  # (Skv, d)
    v: jax.Array,                  # (Skv, d)
    *,
    causal: bool = True,
    window: int = 0,               # 0 = unbounded
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    Sq, d = q.shape
    Skv = k.shape[0]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(bq, max(8, Sq))
    bk = min(bk, max(8, Skv))

    def pad_rows(a, mult):
        p = -a.shape[0] % mult
        return jnp.pad(a, ((0, p), (0, 0))) if p else a

    qp, kp, vp = pad_rows(q, bq), pad_rows(k, bk), pad_rows(v, bk)
    n_q, n_kv = qp.shape[0] // bq, kp.shape[0] // bk
    grid = (n_q, n_kv)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv, skv=Skv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:Sq]
