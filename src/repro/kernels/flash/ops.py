"""Multi-head / GQA wrapper around the flash-attention kernel.

``mha``: (B, Sq, Hq, D) x (B, Skv, Hkv, D) -> (B, Sq, Hq, D), broadcasting
KV heads over query groups (GQA).  On CPU the default dispatches to the
reference; on TPU set use_kernel=True (interpret=False).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ref import attention_ref

__all__ = ["mha"]


def _broadcast_kv(k, hq):
    hkv = k.shape[2]
    if hkv == hq:
        return k
    assert hq % hkv == 0
    return jnp.repeat(k, hq // hkv, axis=2)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "use_kernel", "interpret", "bq", "bk"))
def mha(q, k, v, *, causal=True, window=0, use_kernel=False,
        interpret=True, bq=128, bk=128):
    B, Sq, Hq, D = q.shape
    k = _broadcast_kv(k, Hq)
    v = _broadcast_kv(v, Hq)
    # (B, H, S, D)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_kernel:
        fn = functools.partial(flash_attention, causal=causal, window=window,
                               interpret=interpret, bq=bq, bk=bk)
    else:
        fn = functools.partial(attention_ref, causal=causal, window=window)
    out = jax.vmap(jax.vmap(fn))(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
