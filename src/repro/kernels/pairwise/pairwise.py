"""Pallas TPU kernel: blocked all-pairs Gram matrix (the reducer hot spot).

Within a reducer the A2A problem computes a similarity for every pair of its
inputs — a Gram matrix ``X @ X^T`` over the reducer's (L, d) block.  On TPU
this is MXU work; we tile (bm, bn, bk) so each step keeps two input tiles and
one accumulator tile in VMEM and issues 128x128-aligned matmuls.

The kernel computes C[i, j] = sum_k X[i, k] * Y[j, k] with fp32 accumulation;
metric post-processing (L2 / cosine) happens in ops.py from the same Gram
values (norms are the diagonal, so no extra memory pass).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pairwise_gram", "min_tile_sublanes"]

# minimum TPU tile second-to-last ("sublane") extent by dtype width; the
# last ("lane") dimension is always 128 (see the Pallas guide's tiling
# constraints table)
_MIN_SUBLANES = {4: 8, 2: 16, 1: 32}
_LANE = 128


def min_tile_sublanes(dtype) -> int:
    """Minimum sublane tile extent for ``dtype`` (8 f32 / 16 bf16 / 32 i8)."""
    return _MIN_SUBLANES.get(jnp.dtype(dtype).itemsize, 8)


def _clamp_block(b: int, n: int, dtype, *, lane: bool = False) -> int:
    """Shrink block size ``b`` toward extent ``n`` without breaking TPU tile
    alignment: the clamped block is rounded *up* to the dtype's minimum tile
    multiple (sublane, or 128 for the lane axis), so sub-tile bucket widths
    never produce unaligned BlockSpecs."""
    mult = _LANE if lane else min_tile_sublanes(dtype)
    return min(b, -(-max(n, 1) // mult) * mult)


def _gram_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int, m: int, n: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),     # X @ Y^T
        preferred_element_type=jnp.float32,
    )

    # masked tail-tile: the row/col grids are ceil(M/bm) x ceil(N/bn), so the
    # last tiles can hang past the array — whatever the OOB lanes accumulated
    # is zeroed at flush instead of padding M/N up front.  (program_id is
    # read outside the `when` body: interpret mode can't substitute it
    # inside a cond branch.)
    bm, bn = acc_ref.shape
    row = pl.program_id(0) * bm + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bn), 0)
    col = pl.program_id(1) * bn + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bn), 1)
    valid = (row < m) & (col < n)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = jnp.where(valid, acc_ref[...], 0).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"))
def pairwise_gram(
    x: jax.Array,                 # (M, K)
    y: jax.Array,                 # (N, K)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:                   # (M, N) = x @ y^T
    M, K = x.shape
    N, Ky = y.shape
    assert K == Ky, (x.shape, y.shape)
    bm = _clamp_block(bm, M, x.dtype)
    bn = _clamp_block(bn, N, y.dtype)
    bk = _clamp_block(bk, K, x.dtype, lane=True)

    def pad(a, mult1):
        # only K is materially padded (it feeds the accumulation, so OOB
        # garbage there would corrupt results); M/N tails are handled by the
        # kernel's masked flush — narrow bucket blocks stay narrow instead
        # of rounding up to a full tile row/column.
        p1 = -a.shape[1] % mult1
        if p1:
            a = jnp.pad(a, ((0, 0), (0, p1)))
        return a

    xp = pad(x, bk)
    yp = pad(y, bk)
    Kp = xp.shape[1]
    n_k = Kp // bk
    grid = (-(-M // bm), -(-N // bn), n_k)

    return pl.pallas_call(
        functools.partial(_gram_kernel, n_k=n_k, m=M, n=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, yp)
