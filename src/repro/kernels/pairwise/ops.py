"""Jitted wrapper for the pairwise kernel with metric post-processing.

On CPU (this container) the Pallas kernel runs in interpret mode only when
explicitly requested; by default we dispatch to the jnp reference, keeping
the public API identical so the engine can flip `use_kernel` freely.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .pairwise import pairwise_gram
from .ref import pairwise_ref

__all__ = ["pairwise", "pairwise_kernel"]


def _finish(g, metric: str):
    if metric == "dot":
        return g
    n2 = jnp.diagonal(g)
    if metric == "l2":
        return n2[:, None] + n2[None, :] - 2.0 * g
    if metric == "cosine":
        nrm = jnp.sqrt(jnp.clip(n2, 1e-18))
        return g / (nrm[:, None] * nrm[None, :])
    raise ValueError(metric)


@functools.partial(jax.jit, static_argnames=("metric", "interpret", "bm",
                                             "bn", "bk"))
def pairwise_kernel(x, *, metric: str = "dot", interpret: bool = True,
                    bm: int = 128, bn: int = 128, bk: int = 512):
    """All-pairs similarity of rows of x via the Pallas kernel."""
    g = pairwise_gram(x, x, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return _finish(g, metric)


def pairwise(x, *, metric: str = "dot", use_kernel: bool = False, **kw):
    if use_kernel:
        return pairwise_kernel(x, metric=metric, **kw)
    return pairwise_ref(x, metric=metric)
