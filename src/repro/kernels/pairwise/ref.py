"""Pure-jnp oracle for the pairwise kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pairwise_gram_ref", "pairwise_ref"]


def pairwise_gram_ref(x, y, out_dtype=jnp.float32):
    return (x.astype(jnp.float32) @ y.astype(jnp.float32).T).astype(out_dtype)


def pairwise_ref(x, metric: str = "dot"):
    g = pairwise_gram_ref(x, x)
    if metric == "dot":
        return g
    n2 = jnp.diagonal(g)
    if metric == "l2":
        return n2[:, None] + n2[None, :] - 2.0 * g
    if metric == "cosine":
        nrm = jnp.sqrt(jnp.clip(n2, 1e-18))
        return g / (nrm[:, None] * nrm[None, :])
    raise ValueError(metric)
