"""Fused gather+Gram Pallas TPU megakernel: the shuffle streams into the MXU.

Both shipped executors pay the map->reduce shuffle twice: ``_gather_reduce``
materializes the gathered ``(R, L, d)`` block in HBM (``jnp.take`` + mask),
then the ``pairwise_gram`` kernel reads it back to compute each reducer's
all-pairs block.  For the A2A workload that doubles HBM traffic on the very
quantity — communication cost — the mapping schema was optimized to
minimize.  This kernel consumes the plan's index matrix directly:

  * the per-reducer ``idx`` / ``mask`` rows are **scalar-prefetched**
    (``pltpu.PrefetchScalarGridSpec``) into SMEM, so row ids are available
    before the kernel body runs;
  * input-table rows are DMA'd straight from the replicated ``(m, d)``
    table (left in ``ANY``/HBM) into two VMEM tiles — the gather *is* the
    DMA, and the padded ``(R, L, d)`` tensor is never written to HBM;
  * each reducer's ``(L, L)`` Gram block is accumulated tile-by-tile on the
    MXU with fp32 accumulation; masked slots are zeroed at gather time, so
    the flushed block is already masked (invalid pairs -> 0, matching
    ``block_similarity``).

Grid layout: ``(R, n_t, n_t)`` with ``n_t = ceil(L / bl)`` row tiles.  The
``i`` tile is gathered once per row of tiles (at ``j == 0``) and reused;
the ``j`` tile is re-gathered per step — the flash-attention tradeoff:
``n_t·L·d`` extra reads instead of an ``L·d`` HBM round trip, a win
whenever the slot count fits a few tiles (every capacity bucket of the
skew-aware plans; see ``fused_traffic_model``).

``fused_gather_gram_streamed`` is the jnp twin with the same tile dataflow
(per-bucket tiles only, never the dense ``(R, L, d)`` buffer) — it is what
the fused executor runs on non-TPU backends and what the dry-run lowers;
``fused_gather_gram_ref`` is the naive materializing oracle for tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pairwise import min_tile_sublanes

__all__ = [
    "fused_gather_gram",
    "fused_gather_gram_ref",
    "fused_gather_gram_streamed",
    "fused_gather_gram_rect",
    "fused_gather_gram_rect_ref",
    "fused_gather_gram_rect_streamed",
    "fused_traffic_model",
]


def _round_up(n: int, mult: int) -> int:
    return -(-max(n, 1) // mult) * mult


def _fused_kernel(idx_ref, msk_ref, x_ref, o_ref, xi_ref, xj_ref, sem_ref,
                  *, bl: int):
    """One (reducer, i-tile, j-tile) grid step.

    idx_ref/msk_ref — scalar-prefetched (R, Lp) int32 in SMEM;
    x_ref — the full input table, ANY/HBM (rows DMA'd on demand);
    xi/xj — (bl, d) VMEM gather tiles; o_ref — (1, bl, bl) output tile.
    """
    r = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    def gather(tile, dst_ref):
        """DMA rows idx[r, tile*bl : (tile+1)*bl] of the table into VMEM,
        zeroing masked slots so the Gram block needs no separate mask.

        Row copies are double-buffered (row t+1 starts before waiting on
        row t, alternating semaphores) so the gather is pipelined rather
        than a chain of bl sequential round-trip latencies."""
        def get_cp(t):
            row = idx_ref[r, tile * bl + t]
            return pltpu.make_async_copy(
                x_ref.at[pl.ds(row, 1), :], dst_ref.at[pl.ds(t, 1), :],
                sem_ref.at[t % 2])

        get_cp(0).start()

        def body(t, _):
            @pl.when(t + 1 < bl)
            def _start_next():
                get_cp(t + 1).start()
            get_cp(t).wait()

            @pl.when(msk_ref[r, tile * bl + t] == 0)
            def _zero():
                dst_ref[pl.ds(t, 1), :] = jnp.zeros_like(
                    dst_ref[pl.ds(t, 1), :])
            return 0
        jax.lax.fori_loop(0, bl, body, 0)

    # the i tile survives the whole j sweep; re-gather only the j tile
    @pl.when(j == 0)
    def _():
        gather(i, xi_ref)
    gather(j, xj_ref)

    o_ref[0, :, :] = jax.lax.dot_general(
        xi_ref[...], xj_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),        # Xi @ Xj^T
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bl", "interpret", "out_dtype"))
def fused_gather_gram(
    x: jax.Array,                  # (m, d) replicated input table
    idx: jax.Array,                # (R, L) int32 plan rows
    mask: jax.Array,               # (R, L) bool/int32 slot validity
    *,
    bl: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:                    # (R, L, L) masked per-reducer Gram
    R, L = idx.shape
    d = x.shape[1]
    if R == 0:
        return jnp.zeros((0, L, L), out_dtype)
    bl = min(bl, _round_up(L, min_tile_sublanes(x.dtype)))
    Lp = _round_up(L, bl)
    n_t = Lp // bl
    idx = jnp.pad(idx.astype(jnp.int32), ((0, 0), (0, Lp - L)))
    mask = jnp.pad(mask.astype(jnp.int32), ((0, 0), (0, Lp - L)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # idx and mask rows
        grid=(R, n_t, n_t),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],   # table in HBM
        out_specs=pl.BlockSpec((1, bl, bl), lambda r, i, j, *_: (r, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bl, d), x.dtype),      # xi gather tile
            pltpu.VMEM((bl, d), x.dtype),      # xj gather tile
            pltpu.SemaphoreType.DMA((2,)),     # double-buffered row copies
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fused_kernel, bl=bl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Lp, Lp), out_dtype),
        interpret=interpret,
    )(idx, mask, x)
    return out[:, :L, :L]


# ---------------------------------------------------------------------------
# rectangular (X2Y) variant: independent row/column gather maps
# ---------------------------------------------------------------------------
def _fused_rect_kernel(xidx_ref, xmsk_ref, yidx_ref, ymsk_ref, x_ref, y_ref,
                       o_ref, xi_ref, yj_ref, sem_ref, *, blx: int,
                       bly: int):
    """One (reducer, x-tile, y-tile) grid step of the rectangular kernel.

    Same dataflow as ``_fused_kernel`` with the two block axes decoupled:
    the row tile gathers ``blx`` X-table rows through ``xidx``, the column
    tile gathers ``bly`` Y-table rows through ``yidx``, and the MXU emits
    the (blx, bly) cross block.  The square kernel is the degenerate
    X == Y case."""
    r = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    def gather(src_ref, idx_ref, msk_ref, tile, bl, dst_ref):
        """DMA rows idx[r, tile*bl : (tile+1)*bl] of ``src_ref`` into VMEM,
        zeroing masked slots; double-buffered like the square kernel."""
        def get_cp(t):
            row = idx_ref[r, tile * bl + t]
            return pltpu.make_async_copy(
                src_ref.at[pl.ds(row, 1), :], dst_ref.at[pl.ds(t, 1), :],
                sem_ref.at[t % 2])

        get_cp(0).start()

        def body(t, _):
            @pl.when(t + 1 < bl)
            def _start_next():
                get_cp(t + 1).start()
            get_cp(t).wait()

            @pl.when(msk_ref[r, tile * bl + t] == 0)
            def _zero():
                dst_ref[pl.ds(t, 1), :] = jnp.zeros_like(
                    dst_ref[pl.ds(t, 1), :])
            return 0
        jax.lax.fori_loop(0, bl, body, 0)

    # the x tile survives the whole y sweep; re-gather only the y tile
    @pl.when(j == 0)
    def _():
        gather(x_ref, xidx_ref, xmsk_ref, i, blx, xi_ref)
    gather(y_ref, yidx_ref, ymsk_ref, j, bly, yj_ref)

    o_ref[0, :, :] = jax.lax.dot_general(
        xi_ref[...], yj_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),        # Xi @ Yj^T
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bl", "interpret", "out_dtype"))
def fused_gather_gram_rect(
    x: jax.Array,                  # (mx, d) replicated X table
    y: jax.Array,                  # (my, d) replicated Y table
    xidx: jax.Array,               # (R, Lx) int32 X-side plan rows
    xmask: jax.Array,              # (R, Lx) bool/int32
    yidx: jax.Array,               # (R, Ly) int32 Y-side plan rows
    ymask: jax.Array,              # (R, Ly) bool/int32
    *,
    bl: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:                    # (R, Lx, Ly) masked per-reducer cross Gram
    """Rectangular fused gather+Gram: the bipartite shuffle streams into
    the MXU.  Row and column gathers run through independent index maps
    over two (possibly distinct) tables; each side pads to its own tile
    width, so |X| != |Y| plans never pad to a square."""
    R, Lx = xidx.shape
    Ly = yidx.shape[1]
    assert x.shape[1] == y.shape[1], (x.shape, y.shape)
    d = x.shape[1]
    if R == 0:
        return jnp.zeros((0, Lx, Ly), out_dtype)
    blx = min(bl, _round_up(Lx, min_tile_sublanes(x.dtype)))
    bly = min(bl, _round_up(Ly, min_tile_sublanes(y.dtype)))
    Lxp = _round_up(Lx, blx)
    Lyp = _round_up(Ly, bly)
    n_tx = Lxp // blx
    n_ty = Lyp // bly
    xidx = jnp.pad(xidx.astype(jnp.int32), ((0, 0), (0, Lxp - Lx)))
    xmask = jnp.pad(xmask.astype(jnp.int32), ((0, 0), (0, Lxp - Lx)))
    yidx = jnp.pad(yidx.astype(jnp.int32), ((0, 0), (0, Lyp - Ly)))
    ymask = jnp.pad(ymask.astype(jnp.int32), ((0, 0), (0, Lyp - Ly)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,                 # xidx, xmask, yidx, ymask
        grid=(R, n_tx, n_ty),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),    # X table in HBM
                  pl.BlockSpec(memory_space=pltpu.ANY)],   # Y table in HBM
        out_specs=pl.BlockSpec((1, blx, bly), lambda r, i, j, *_: (r, i, j)),
        scratch_shapes=[
            pltpu.VMEM((blx, d), x.dtype),     # xi gather tile
            pltpu.VMEM((bly, d), y.dtype),     # yj gather tile
            pltpu.SemaphoreType.DMA((2,)),     # double-buffered row copies
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fused_rect_kernel, blx=blx, bly=bly),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Lxp, Lyp), out_dtype),
        interpret=interpret,
    )(xidx, xmask, yidx, ymask, x, y)
    return out[:, :Lx, :Ly]


def fused_gather_gram_rect_ref(x, y, xidx, xmask, yidx, ymask):
    """Materializing rectangular oracle: gather both sides -> mask ->
    batched cross Gram (fp32)."""
    gx = jnp.take(x, xidx, axis=0) * xmask.astype(x.dtype)[..., None]
    gy = jnp.take(y, yidx, axis=0) * ymask.astype(y.dtype)[..., None]
    return jax.lax.dot_general(
        gx, gy, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def fused_gather_gram_rect_streamed(x, y, xidx, xmask, yidx, ymask, *,
                                    bl: int = 128):
    """jnp twin of the rectangular kernel's tile dataflow.

    Gathers (R, bl, d) tiles per side only; the y tile is re-gathered per
    (i, j) step exactly like the kernel, so lowered HLO traffic mirrors
    the DMA schedule.  Non-TPU fused-executor path and dry-run target."""
    R, Lx = xidx.shape
    Ly = yidx.shape[1]
    xmaskf = xmask.astype(x.dtype)[..., None]
    ymaskf = ymask.astype(y.dtype)[..., None]
    dims = (((2,), (2,)), ((0,), (0,)))      # batched Xi @ Yj^T

    def tile(tab, idx, maskf, t, width):
        g = jnp.take(tab,
                     jax.lax.dynamic_slice_in_dim(idx, t * bl, width, 1),
                     axis=0)
        return g * jax.lax.dynamic_slice_in_dim(maskf, t * bl, width, 1)

    if Lx <= bl and Ly <= bl:
        gx = jnp.take(x, xidx, axis=0) * xmaskf
        gy = jnp.take(y, yidx, axis=0) * ymaskf
        return jax.lax.dot_general(gx, gy, dims,
                                   preferred_element_type=jnp.float32)

    def widths_of(L):
        n_t = L // bl
        return [bl] * n_t + ([L - n_t * bl] if L % bl else [])

    xw = widths_of(Lx)
    yw = widths_of(Ly)
    rows = []
    for i, wi in enumerate(xw):
        gi = tile(x, xidx, xmaskf, i, wi)
        rows.append(jnp.concatenate(
            [jax.lax.dot_general(gi, tile(y, yidx, ymaskf, j, wj), dims,
                                 preferred_element_type=jnp.float32)
             for j, wj in enumerate(yw)], axis=2))
    return jnp.concatenate(rows, axis=1)


def fused_gather_gram_ref(x, idx, mask):
    """Materializing oracle: gather -> mask -> batched Gram (fp32)."""
    g = jnp.take(x, idx, axis=0) * mask.astype(x.dtype)[..., None]
    return jax.lax.dot_general(
        g, g, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def fused_gather_gram_streamed(x, idx, mask, *, bl: int = 128):
    """jnp twin of the kernel's tile dataflow (non-TPU fused executor).

    Gathers (R, bl, d) tiles only — a multi-tile width never materializes
    its full (R, L, d) gather, and a bucketed plan never materializes the
    dense one.  The j tile is re-gathered per (i, j) step exactly like the
    kernel, so lowered HLO traffic mirrors the kernel's DMA schedule.
    """
    R, L = idx.shape
    maskf = mask.astype(x.dtype)[..., None]
    dims = (((2,), (2,)), ((0,), (0,)))      # batched Xi @ Xj^T

    def tile(t, width):
        g = jnp.take(x, jax.lax.dynamic_slice_in_dim(idx, t * bl, width, 1),
                     axis=0)
        return g * jax.lax.dynamic_slice_in_dim(maskf, t * bl, width, 1)

    if L <= bl:
        g = jnp.take(x, idx, axis=0) * maskf
        return jax.lax.dot_general(g, g, dims,
                                   preferred_element_type=jnp.float32)

    n_t = L // bl
    widths = [bl] * n_t + ([L - n_t * bl] if L % bl else [])
    rows = []
    for i, wi in enumerate(widths):
        gi = tile(i, wi)
        rows.append(jnp.concatenate(
            [jax.lax.dot_general(gi, tile(j, wj), dims,
                                 preferred_element_type=jnp.float32)
             for j, wj in enumerate(widths)], axis=2))
    return jnp.concatenate(rows, axis=1)


def fused_traffic_model(buckets, d: int, itemsize: int,
                        bl: int = 128) -> dict:
    """Analytic HBM bytes of the kernel dataflow vs the unfused pipeline.

    Per reducer of bucket width Lb with n = ceil(Lb/bl) row tiles:

      fused    — xi gathered once per tile row (Lb rows), xj re-gathered per
                 (i, j) tile (n·Lb rows), plus the (Lb, Lb) fp32 block write.
      unfused  — the gather writes (Lb, d) then the Gram kernel reads it as
                 both operands (3·Lb·d round trip counted once each way ->
                 4·Lb·d with the gather's own table read), plus the block.

    Returns totals plus ``saved_bytes`` (the materialized-gather round trip
    the fused kernel removes, net of its tile re-reads).
    """
    fused = unfused = blocks = 0
    for b in buckets:
        Rb, Lb = int(b.idx.shape[0]), int(b.idx.shape[1])
        n = -(-Lb // bl)
        fused += Rb * (1 + n) * Lb * d * itemsize
        unfused += Rb * 4 * Lb * d * itemsize
        blocks += Rb * Lb * Lb * 4
    return {
        "fused_bytes": fused + blocks,
        "unfused_bytes": unfused + blocks,
        "saved_bytes": unfused - fused,
        "block_bytes": blocks,
    }
