"""StreamingExecutor: delta execution of maintained plans (DESIGN.md 1f).

The fifth registry executor ("streaming").  Cold builds run the fused
substrate like any other executor; after that the executor keeps the
assembled (m, m) pair matrix as serving state and consumes
:class:`~repro.stream.delta.PlanDelta` artifacts: only the delta's dirty
reducers are recomputed (their compact sub-plan runs through the bucketed
gather+Gram substrate at power-of-two shapes), and the cached matrix is
*patched* — touched rows/columns are invalidated and refilled by a delta
scatter — instead of being rebuilt.  A full re-plan delta (gap drift,
opaque schema) falls back to a cold build, counted in ``stats()``.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.mapreduce.allpairs import (
    _finish_pair_matrix,
    _finish_x2y_matrix,
    _scatter_blocks,
    _scatter_blocks_x2y,
)
from repro.mapreduce.engine import (
    ReducerBucket,
    ReducerPlan,
    _as_tables,
    run_reducers_bucketed,
    run_reducers_x2y_bucketed,
)
from repro.mapreduce.executors import (
    Executor,
    _bucket_valid_slots,
    _row_bytes,
    make_executor,
)
from repro.obs import LEDGER as _LEDGER
from repro.obs import REGISTRY as _OBS_REGISTRY
from repro.obs import _config as _obs_config

from .delta import PlanDelta, _pow2

__all__ = ["StreamingExecutor"]


class StreamingExecutor(Executor):
    """Incremental pair-matrix serving over a mutable plan.

    ``run_pairs`` is the cold path: it delegates to the ``substrate``
    executor ("fused" by default — all capacity buckets in one program)
    and caches the assembled matrix.  ``apply_delta`` is the streaming
    path: recompute the delta's dirty reducers only, patch the cached
    matrix.  State is keyed by the reducer function object, so the serving
    tier's memoized ``_block_fn`` reuses both the cache and the substrate's
    jit entries across edits.

    Patch correctness: every value a dirty reducer produces is computed
    from the *current* table, so scattering dirty blocks over the cached
    matrix (max-combine, after invalidating touched rows/columns to -inf)
    writes only current-correct values — overlapping clean pairs agree
    exactly, touched pairs are refilled, and touched pairs no longer
    covered (deleted inputs) decay to 0.  ``PlanDelta.verify`` proves the
    dirty reducers cover every touched pair.
    """

    name = "streaming"

    def __init__(self, stats: Optional[dict] = None,
                 substrate: str = "fused"):
        super().__init__(stats)
        self.substrate = substrate
        self._sub = make_executor(substrate)     # private: isolated counters
        self._sims: Optional[jax.Array] = None
        self._fn: Optional[Callable] = None
        self._sims_x2y: Optional[jax.Array] = None
        self._fn_x2y: Optional[Callable] = None

    def _fresh_stats(self) -> dict:
        return {"calls": 0, "full_builds": 0, "delta_updates": 0,
                "dirty_reducers": 0, "reducers_total": 0,
                "patched_inputs": 0, "fallbacks": 0,
                "warmed_shapes": 0, "recompute_fraction": 0.0}

    # ------------------------------------------------------------- protocol
    def run(self, inputs, plan, reducer_fn, *, mesh=None, shard_axes=None,
            **kwargs):
        """Non-pairs reducer execution has no serving state to patch:
        delegate to the substrate (counted as a fallback)."""
        self._count("calls")
        self._count("fallbacks")
        return self._sub.run(inputs, plan, reducer_fn, mesh=mesh,
                             shard_axes=shard_axes, **kwargs)

    def run_pairs(self, x, plan, reducer_fn, m, *, mesh=None,
                  use_kernel=False, interpret=False):
        """Cold build: execute the full plan on the substrate and adopt the
        (m, m) matrix as streaming state."""
        self._count("calls")
        return self._rebuild(x, plan, reducer_fn, m, mesh=mesh,
                             use_kernel=use_kernel, interpret=interpret)

    def lower(self, input_shape, plan, *, reducer_fn=None, metric=None,
              mesh=None, dtype=jnp.float32, shard_axes=None,
              delta: Optional[PlanDelta] = None, **kwargs):
        """Lower the *delta* program (dry-run/roofline): the bucketed
        gather+reduce over the dirty sub-plan — what one edit actually
        executes.  Without a delta (or on a full-re-plan delta) this is the
        full plan's program, i.e. the re-shuffle a static planner would
        pay.  Returns ``[(bucket, Lowered), ...]`` like the bucketed
        executor."""
        target = plan
        if delta is not None and delta.sub_plan is not None \
                and not delta.full_replan:
            target = delta.sub_plan
        return make_executor("bucketed").lower(
            input_shape, target, reducer_fn=reducer_fn, metric=metric,
            mesh=mesh, dtype=dtype, shard_axes=shard_axes, **kwargs)

    def reset(self) -> None:
        super().reset()
        self._sub.reset()

    # --------------------------------------------------------- reconciliation
    def _note_stream(self, table, plan, workload: str, *,
                     cold: bool) -> None:
        """Ledger record for a cold (full-plan) build: the streaming
        executor paid the whole re-shuffle, so measured == predicted."""
        if not _obs_config.ENABLED:
            return
        d, isz = _row_bytes(table)
        slots = _bucket_valid_slots(plan)
        _LEDGER.record(
            executor=self.name, workload=workload,
            predicted_rows=float(plan.comm_cost),
            lb_rows=plan.lower_bound, plan_slots=slots,
            measured_slots=slots, d=d, itemsize=isz,
            meta={"cold": cold})
        _OBS_REGISTRY.histogram("stream.recompute_fraction",
                                executor=self.name).observe(1.0)

    def _note_delta(self, table, delta: PlanDelta, workload: str,
                    executed: bool) -> None:
        """Ledger record for one delta: predicted traffic is the delta
        ledger (``delta_comm_rows`` — the dirty sub-plan's weighted rows),
        measured is what the patch program actually gathered, and the
        lower bound stays the *full instance's* theorem bound — so
        ``measured_over_lb`` < 1 quantifies how far below a full
        re-shuffle's floor the streaming path serves this edit."""
        if not _obs_config.ENABLED:
            return
        d, isz = _row_bytes(table)
        sp = delta.sub_plan
        slots = _bucket_valid_slots(sp) if sp is not None else 0
        _LEDGER.record(
            executor=self.name, workload=workload,
            predicted_rows=delta.delta_comm_rows(),
            lb_rows=delta.lower_bound, plan_slots=slots,
            measured_slots=slots if executed else 0, d=d, itemsize=isz,
            meta={"kind": delta.kind,
                  "recompute_fraction": float(delta.recompute_fraction),
                  "dirty_reducers": int(len(delta.dirty_rows))})
        _OBS_REGISTRY.histogram("stream.recompute_fraction",
                                executor=self.name).observe(
                                    float(delta.recompute_fraction))

    # ------------------------------------------------------------ streaming
    @property
    def sims(self) -> Optional[jax.Array]:
        """The maintained matrix at table capacity — a power-of-two square
        so consecutive inserts hit compiled programs instead of recompiling
        per table size; rows/cols past the live table are zero.  (None
        before the first build.)"""
        return self._sims

    def invalidate(self) -> None:
        """Drop the maintained state; the next call rebuilds cold."""
        self._sims = None
        self._fn = None
        self._sims_x2y = None
        self._fn_x2y = None

    @staticmethod
    def _cap(n: int) -> int:
        """Serving capacity for ``n`` live rows: the next power of two
        *above* ``max(n + 1, 1.25 n)``.  The headroom is the first-edit
        latency fix: a table sitting exactly at a power of two (the bench's
        m=512) used to cross capacity on its first insert and recompile
        every program at the doubled shapes — 2108ms on an edit that
        steady-states at 93ms.  With headroom, the capacity chosen at
        ``load_table`` time survives the first ~25% of growth, so the
        shapes ``warm_delta_shapes`` pre-compiles are the shapes the first
        edit runs."""
        if n <= 0:
            return 1
        return _pow2(max(n + 1, -(-n * 5 // 4)))

    @classmethod
    def _at_capacity(cls, x, square: bool = False):
        """Pad the leading axis (both axes with ``square=True``) to
        serving capacity (:meth:`_cap`): edits then reuse the same
        compiled gather/patch programs until the capacity actually
        doubles.  Padding rows are never referenced (the plan indexes
        live rows only)."""
        cap = cls._cap(x.shape[0])
        if cap > x.shape[0]:
            pad = (0, cap - x.shape[0])
            pads = (pad, pad) if square else \
                (pad,) + ((0, 0),) * (x.ndim - 1)
            x = jnp.pad(x, pads)
        return x

    def _rebuild(self, x, plan, reducer_fn, m, *, mesh=None,
                 use_kernel=False, interpret=False):
        sims = self._sub.run_pairs(x, plan, reducer_fn, m, mesh=mesh,
                                   use_kernel=use_kernel,
                                   interpret=interpret)
        self._sims = self._at_capacity(sims, square=True)
        self._fn = reducer_fn
        self._count("full_builds")
        self._count("dirty_reducers", plan.num_reducers)
        self._count("reducers_total", plan.num_reducers)
        self._stats["recompute_fraction"] = 1.0
        self._note_stream(x, plan, "pairs", cold=True)
        return sims

    # ------------------------------------------------------- rectangular X2Y
    @property
    def sims_x2y(self) -> Optional[jax.Array]:
        """The maintained (capacity-padded) cross matrix; None before the
        first rectangular build."""
        return self._sims_x2y

    def run_x2y(self, tables, plan, reducer_fn, shape, *, mesh=None,
                use_kernel=False, interpret=False):
        """Cold rectangular build: execute the full rect plan on the
        substrate and adopt the (mx, my) matrix as streaming state.
        Payload-carrying outputs (trailing dims — the skew join) execute
        identically but are not adopted as patchable state."""
        self._count("calls")
        return self._rebuild_x2y(tables, plan, reducer_fn, shape,
                                 mesh=mesh, use_kernel=use_kernel,
                                 interpret=interpret)

    @classmethod
    def _at_rect_capacity(cls, s):
        """Pad both matrix axes to serving capacity (rectangular analogue
        of ``_at_capacity(square=True)``)."""
        cx, cy = cls._cap(s.shape[0]), cls._cap(s.shape[1])
        if (cx, cy) != s.shape[:2]:
            s = jnp.pad(s, ((0, cx - s.shape[0]), (0, cy - s.shape[1])))
        return s

    def _rebuild_x2y(self, tables, plan, reducer_fn, shape, *, mesh=None,
                     use_kernel=False, interpret=False):
        sims = self._sub.run_x2y(tables, plan, reducer_fn, shape,
                                 mesh=mesh, use_kernel=use_kernel,
                                 interpret=interpret)
        if sims.ndim == 2:
            self._sims_x2y = self._at_rect_capacity(sims)
            self._fn_x2y = reducer_fn
        self._count("full_builds")
        self._count("dirty_reducers", plan.num_reducers)
        self._count("reducers_total", plan.num_reducers)
        self._stats["recompute_fraction"] = 1.0
        self._note_stream(_as_tables(tables)[0], plan, "x2y", cold=True)
        return sims

    def apply_delta_x2y(self, tables, delta: PlanDelta, reducer_fn,
                        shape, *,
                        plan_provider: Optional[
                            Callable[[], ReducerPlan]] = None,
                        mesh=None, use_kernel=False, interpret=False):
        """Apply one X2Y edit: patch the maintained (mx, my) matrix.

        ``tables`` are the *current* full (X, Y) tables (tombstoned rows
        included); ``shape = (mx, my)`` their live leading sizes.  The
        delta's ``meta['touched_x']`` rows and ``meta['touched_y']``
        columns are invalidated and the dirty reducers' rect sub-plan is
        recomputed and scattered back — the two-sided analogue of
        :meth:`apply_delta`.  Returns the live (mx, my) view."""
        self._count("calls")
        mx, my = shape
        cold = (self._sims_x2y is None or self._fn_x2y is not reducer_fn
                or delta.full_replan)
        if cold:
            assert plan_provider is not None, (
                "cold streaming rebuild needs the full rect plan")
            return self._rebuild_x2y(tables, plan_provider(), reducer_fn,
                                     shape, mesh=mesh,
                                     use_kernel=use_kernel,
                                     interpret=interpret)

        sims = self._sims_x2y
        if mx > sims.shape[0] or my > sims.shape[1]:  # capacity doubled
            sims = self._at_rect_capacity(jnp.pad(sims, (
                (0, max(mx - sims.shape[0], 0)),
                (0, max(my - sims.shape[1], 0)))))
        tx = np.asarray(delta.meta.get("touched_x", ()), np.int64)
        ty = np.asarray(delta.meta.get("touched_y", ()), np.int64)
        if len(tx) or len(ty):
            if len(tx):
                sims = sims.at[jnp.asarray(tx), :].set(-jnp.inf)
            if len(ty):
                sims = sims.at[:, jnp.asarray(ty)].set(-jnp.inf)
            if delta.sub_plan is not None and len(delta.dirty_rows):
                xt, yt = _as_tables(tables)
                per_bucket = run_reducers_x2y_bucketed(
                    (self._at_capacity(xt), self._at_capacity(yt)),
                    delta.sub_plan, reducer_fn, mesh=mesh,
                    combine="buckets")
                for b, blocks in per_bucket:
                    sims = _scatter_blocks_x2y(
                        sims, blocks, jnp.asarray(b.idx),
                        jnp.asarray(b.mask), jnp.asarray(b.yidx),
                        jnp.asarray(b.ymask))
            sims = _finish_x2y_matrix(sims)

        self._sims_x2y = sims
        self._count("delta_updates")
        self._count("dirty_reducers", int(len(delta.dirty_rows)))
        self._count("reducers_total", int(delta.num_reducers))
        self._count("patched_inputs", int(len(tx) + len(ty)))
        self._stats["recompute_fraction"] = float(delta.recompute_fraction)
        self._note_delta(
            _as_tables(tables)[0], delta, "delta_x2y",
            executed=bool((len(tx) or len(ty))
                          and delta.sub_plan is not None
                          and len(delta.dirty_rows)))
        return sims[:mx, :my]

    def apply_delta(self, x, delta: PlanDelta, reducer_fn, m, *,
                    plan_provider: Optional[Callable[[], ReducerPlan]] = None,
                    mesh=None, use_kernel=False, interpret=False):
        """Apply one edit: patch the maintained matrix through the delta.

        ``x`` is the *current* full table (tombstoned rows included);
        ``m = x.shape[0]``.  ``plan_provider`` supplies the full post-edit
        plan, called only when a cold rebuild is unavoidable (full-re-plan
        delta, or no maintained state / different reducer function).
        Returns the live (m, m) view of the maintained matrix.
        """
        self._count("calls")
        cold = (self._sims is None or self._fn is not reducer_fn
                or delta.full_replan)
        if cold:
            assert plan_provider is not None, (
                "cold streaming rebuild needs the full plan")
            return self._rebuild(x, plan_provider(), reducer_fn, m,
                                 mesh=mesh, use_kernel=use_kernel,
                                 interpret=interpret)

        sims = self._sims
        if m > sims.shape[0]:                     # capacity doubled
            sims = self._at_capacity(
                jnp.pad(sims, ((0, m - sims.shape[0]),) * 2), square=True)
        cap = sims.shape[0]
        touched = delta.touched_inputs
        if len(touched):
            t = jnp.asarray(touched)
            sims = sims.at[t, :].set(-jnp.inf).at[:, t].set(-jnp.inf)
            if delta.sub_plan is not None and len(delta.dirty_rows):
                per_bucket = run_reducers_bucketed(
                    self._at_capacity(x), delta.sub_plan, reducer_fn,
                    mesh=mesh, combine="buckets")
                for b, blocks in per_bucket:
                    sims = _scatter_blocks(sims, blocks,
                                           jnp.asarray(b.idx),
                                           jnp.asarray(b.mask))
            sims = _finish_pair_matrix(sims, cap)

        self._sims = sims
        self._count("delta_updates")
        self._count("dirty_reducers", int(len(delta.dirty_rows)))
        self._count("reducers_total", int(delta.num_reducers))
        self._count("patched_inputs", int(len(touched)))
        self._stats["recompute_fraction"] = float(delta.recompute_fraction)
        self._note_delta(
            x, delta, "delta",
            executed=bool(len(touched) and delta.sub_plan is not None
                          and len(delta.dirty_rows)))
        return sims[:m, :m]

    # ------------------------------------------------------------ AOT warmup
    @staticmethod
    def _warm_plan(R: int, width: int, ywidth: int = 0) -> ReducerPlan:
        """A synthetic one-bucket plan at exactly the given padded shape:
        all rows masked out (row id -1 — the padding convention), so the
        program compiles and runs against zeros without reading anything."""
        bucket = ReducerBucket(
            width=int(width), rows=np.full(R, -1, np.int64),
            idx=np.zeros((R, width), np.int32),
            mask=np.zeros((R, width), bool),
            ywidth=int(ywidth),
            yidx=(np.zeros((R, ywidth), np.int32) if ywidth else None),
            ymask=(np.zeros((R, ywidth), bool) if ywidth else None))
        return ReducerPlan(
            idx=bucket.idx, mask=bucket.mask, num_reducers=R,
            comm_cost=0.0, max_inputs=int(width), algorithm="warmup",
            lower_bound=None, buckets=(bucket,),
            yidx=bucket.yidx, ymask=bucket.ymask,
            max_y_inputs=int(ywidth))

    def warm_delta_shapes(self, x, shapes, reducer_fn, *,
                          mesh=None) -> int:
        """Pre-compile the delta path for every ``(rows, width)`` sub-plan
        shape in ``shapes`` (``IncrementalPlanner.delta_shapes()``), plus
        the invalidate/scatter/finish patch programs at serving capacity.

        Runs the *exact* apply_delta code path — same
        ``run_reducers_bucketed`` call signature, same scatter and finish
        ops — so the first real edit hits a warm jit cache instead of
        paying a multi-second compile storm.  Returns the number of
        shapes warmed (also counted in ``stats()['warmed_shapes']``)."""
        if not shapes:
            return 0
        xt = self._at_capacity(jnp.asarray(x))
        cap = self._sims.shape[0] if self._sims is not None \
            else self._cap(int(np.asarray(x).shape[0]))
        scratch = self._sims if self._sims is not None \
            else jnp.zeros((cap, cap), jnp.float32)
        t = jnp.asarray(np.zeros(1, np.int64))   # matches apply_delta's
        # jnp.asarray(touched_inputs) dtype canonicalization exactly
        scratch = scratch.at[t, :].set(-jnp.inf).at[:, t].set(-jnp.inf)
        for shape in shapes:
            R, width = int(shape[0]), int(shape[1])
            plan = self._warm_plan(R, width)
            per_bucket = run_reducers_bucketed(
                xt, plan, reducer_fn, mesh=mesh, combine="buckets")
            for b, blocks in per_bucket:
                scratch = _scatter_blocks(scratch, blocks,
                                          jnp.asarray(b.idx),
                                          jnp.asarray(b.mask))
        _finish_pair_matrix(scratch, cap).block_until_ready()
        self._count("warmed_shapes", len(shapes))
        return len(shapes)

    def warm_delta_shapes_x2y(self, tables, shapes, reducer_fn, *,
                              mesh=None) -> int:
        """Rectangular warmup: pre-compile the ``apply_delta_x2y`` path
        for every ``(rows, x width, y width)`` shape
        (``IncrementalX2YPlanner.delta_shapes()``)."""
        if not shapes:
            return 0
        xt, yt = _as_tables(tables)
        xt, yt = self._at_capacity(xt), self._at_capacity(yt)
        if self._sims_x2y is not None:
            scratch = self._sims_x2y
        else:
            scratch = jnp.zeros((self._cap(xt.shape[0]),
                                 self._cap(yt.shape[0])), jnp.float32)
        t = jnp.asarray(np.zeros(1, np.int64))   # matches apply_delta's
        # jnp.asarray(touched_inputs) dtype canonicalization exactly
        scratch = scratch.at[t, :].set(-jnp.inf).at[:, t].set(-jnp.inf)
        for shape in shapes:
            R, wx, wy = (int(shape[0]), int(shape[1]), int(shape[2]))
            plan = self._warm_plan(R, wx, wy)
            per_bucket = run_reducers_x2y_bucketed(
                (xt, yt), plan, reducer_fn, mesh=mesh, combine="buckets")
            for b, blocks in per_bucket:
                scratch = _scatter_blocks_x2y(
                    scratch, blocks, jnp.asarray(b.idx),
                    jnp.asarray(b.mask), jnp.asarray(b.yidx),
                    jnp.asarray(b.ymask))
        _finish_x2y_matrix(scratch).block_until_ready()
        self._count("warmed_shapes", len(shapes))
        return len(shapes)
