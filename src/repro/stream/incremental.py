"""IncrementalPlanner: online maintenance of a mapping schema (DESIGN.md 1f).

The registry planner (``repro.core.plan_a2a``) treats a plan as a pure
function of the weight profile: any change to the input list is a full
re-plan and a full re-shuffle.  Afrati et al. ("Upper and Lower Bounds on
the Cost of a Map-Reduce Computation") frame communication as the quantity
to bound *per unit of useful work* — and a one-input edit does O(m) useful
work (m new/removed pairs), not O(m^2).  This module makes plans mutable
state: ``insert`` / ``delete`` / ``reweight`` repair the maintained schema
locally and emit a :class:`~repro.stream.delta.PlanDelta` naming exactly
the reducers whose blocks changed.

Repair strategy (the bin-packing family, ``binpack-k*`` and ``single``):

  insert(w)   — residual FFD/best-fit: place the new input into the
                fullest existing bin whose slack still holds it (every
                reducer containing that bin stays <= q because its bins
                stay <= q/k).  Only when no bin has slack does the planner
                open a new bin and new reducers — one reducer per (k-1)
                live bins, pairing the new bin against every live bin, so
                A2A coverage is restored by construction (capacity forces
                the new reducers: (k-1) * q/k + w <= q).
  delete(i)   — drop the input from its bin; an emptied bin is tombstoned
                (never packed into again — a revived bin would hold inputs
                that were never paired against bins opened while it was
                empty).  No recompute: surviving pair values are
                unchanged, the executor just zeroes row/column i.
  reweight    — in-place when the bin's slack absorbs the change (a pure
                planning-state update: feature rows are untouched, so no
                reducer is dirty), else delete + re-insert of the same id.

The maintained invariant — every pair of live bins meets at >= 1 reducer,
and every live bin sits in >= 1 reducer — is exactly A2A coverage, checked
by ``snapshot().validate('a2a')`` in the conformance suite and by
``PlanDelta.verify`` after every edit when ``check=True``.

Repairs drift; the re-plan trigger, background repacking, and the
double-buffered re-plan live in :class:`~repro.stream.base.
StreamPlannerBase` (shared with the X2Y planner).  Two bounds are
maintained per edit: Thm 8 (``s^2/q`` — the theorem bound conformance
ships against) and the binpack strategy bound of Thm 9, which is what a
fresh ``binpack-k`` plan can actually reach; triggers compare against the
achievable one.  A full re-plan adopts the fresh schema as planning state
but emits only a compact *patch* delta (pair values are plan-independent),
and the superseded profile's ``PLAN_CACHE`` entry is dropped via
``PlanCache.invalidate`` so a churning stream does not evict live
request-serving profiles.  Schema shapes the repair rules do not
understand (hybrid Algorithm 5, the big-input path — both use overlapping
bins) re-plan on every edit; this is counted, never wrong.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.bounds import (
    a2a_binpack_comm_lower_bound,
    a2a_comm_lower_bound,
)
from repro.core.planner import plan_a2a
from repro.core.schema import InfeasibleError, MappingSchema
from repro.core.strategies import PLAN_CACHE, PlanCache
from repro.mapreduce.engine import ReducerPlan, build_plan

from .base import StreamPlannerBase, _EPS
from .delta import PlanDelta, compact_plan

__all__ = ["IncrementalPlanner"]


class IncrementalPlanner(StreamPlannerBase):
    """Mutable mapping-schema state over a growing/shrinking input table.

    Input ids are stable full-table positions: ``insert`` appends a new id
    and never reuses a deleted one, so the serving tier can keep feature
    rows in a flat table with tombstones.  ``plan()`` returns the current
    full :class:`ReducerPlan` (ids into the full table); ``snapshot()``
    returns a compacted :class:`MappingSchema` over the live inputs for
    validation and cold re-plan comparison.
    """

    def __init__(self, q: float, weights: Sequence[float] = (), *,
                 method: str = "auto", replan_drift: float = 1.5,
                 max_gap: Optional[float] = 2.0,
                 repack_gap: Optional[float] = None,
                 background: bool = False,
                 pad_reducers_to: int = 1, pad_slots_to: int = 1,
                 max_buckets: int = 8, check: bool = True):
        super().__init__(replan_drift=replan_drift, max_gap=max_gap,
                         repack_gap=repack_gap, background=background,
                         check=check)
        self.q = float(q)
        self.method = method
        self._pad = dict(pad_reducers_to=pad_reducers_to,
                         pad_slots_to=pad_slots_to, max_buckets=max_buckets)
        self.weights: list[float] = [float(w) for w in weights]
        self.active: list[bool] = [True] * len(self.weights)
        self._cache_key: Optional[tuple] = None
        self._adopt_replan()

    # ------------------------------------------------------------ properties
    @property
    def num_active(self) -> int:
        return int(np.sum(self.active))

    @property
    def num_reducers(self) -> int:
        return len(self.reducers)

    def active_ids(self) -> np.ndarray:
        return np.flatnonzero(self.active)

    def active_weights(self) -> np.ndarray:
        ids = self.active_ids()
        return np.asarray([self.weights[i] for i in ids], dtype=np.float64)

    # ---------------------------------------------------------------- bounds
    def _recompute_lb(self) -> None:
        """Both instance bounds for the live profile: Thm 8 (theorem) and
        the strategy-level achievable reference of the schema family in
        force (Thm 9 for binpack-k; the single schema ships exactly s)."""
        w = self.active_weights()
        if not len(w):
            self._lb = self._lb_ach = 0.0
            return
        self._lb = a2a_comm_lower_bound(w, self.q)
        ach = self._lb
        if self.kind == "binpack" and self.k >= 1:
            ach = max(ach, a2a_binpack_comm_lower_bound(w, self.q, self.k))
        elif self.kind == "single":
            ach = max(ach, float(np.sum(w)))
        self._lb_ach = ach

    # -------------------------------------------------------------- adoption
    def _adopt_replan(self) -> None:
        """Full re-plan of the live profile through ``PLAN_CACHE``; adopt
        the winning schema as the new mutable state."""
        ids = self.active_ids()
        w = self.active_weights()
        old_key = self._cache_key
        if len(ids) == 0:
            schema = MappingSchema(w, self.q, [], [], algorithm="empty",
                                   lower_bound=0.0)
            self._cache_key = None
        else:
            schema = plan_a2a(w, self.q, self.method)   # may raise: the
            # planner state (including old_key) is untouched until it wins
            order = np.argsort(-w, kind="stable")
            self._cache_key = PlanCache.key(w[order], self.q, self.method)
        if old_key is not None and old_key != self._cache_key:
            # this stream has permanently moved off its previous profile
            PLAN_CACHE.invalidate(old_key)
        # bins from _remap_schema are fresh lists; the outer reducers list
        # is shallow-copied so appends stay private, and existing inner
        # reducer lists are never mutated (repairs touch bins, or append
        # brand-new reducer lists) — the PLAN_CACHE entry stays clean.
        self._adopt_schema_state(
            schema, [[int(ids[i]) for i in b] for b in schema.bins],
            list(schema.reducers))
        self.comm_cost = (schema.communication_cost() if self.overlapping
                          else self._comm_from_state())
        self._recompute_lb()
        self._after_adopt()

    def _adopt_schema_state(self, schema: MappingSchema,
                            bins: list[list[int]],
                            reducers: list[list[int]]) -> None:
        """Install a schema's shape (kind/k/bin_size) and bin/reducer
        structure over full-table ids; shared by the synchronous adopt and
        the background swap."""
        self.algorithm = schema.algorithm
        self.overlapping = bool(schema.meta.get("bins_overlap", False))
        self.bins = bins
        self.reducers = reducers
        self.dead_bins: set[int] = {b for b, mem in enumerate(bins)
                                    if not mem}
        n_live = sum(1 for b in bins if b) or (1 if self.num_active else 0)
        if schema.algorithm == "single" and self.num_active > 0:
            self.kind = "single"
            self.k, self.bin_size = 1, self.q
        elif schema.algorithm.startswith("binpack-k") \
                and not self.overlapping:
            self.kind = "binpack"
            self.k = int(schema.meta["k"])
            self.bin_size = float(schema.meta["bin_size"])
        elif n_live == 0:
            self.kind = "empty"
            self.k, self.bin_size = 0, 0.0
        else:
            self.kind = "opaque"
            self.k, self.bin_size = 0, 0.0
        self._bw = np.asarray(
            [sum(self.weights[i] for i in b) for b in self.bins],
            dtype=np.float64)
        self.bin_of = {i: b for b, members in enumerate(self.bins)
                       for i in members}
        self.reducers_of_bin: dict[int, list[int]] = {
            b: [] for b in range(len(self.bins))}
        for r, red in enumerate(self.reducers):
            for b in red:
                self.reducers_of_bin[b].append(r)
        self._plan: Optional[ReducerPlan] = None

    def _comm_from_state(self) -> float:
        """Disjoint-bin communication cost: sum of member bin weights over
        reducers (dead bins weigh 0)."""
        if not self.reducers:
            return 0.0
        flat = np.fromiter((b for red in self.reducers for b in red),
                           dtype=np.int64,
                           count=sum(len(r) for r in self.reducers))
        return float(np.sum(self._bw[flat])) if len(flat) else 0.0

    # --------------------------------------------------- background re-plan
    def _capture_profile(self):
        return self.active_ids().copy(), self.active_weights().copy()

    def _background_plan(self, payload):
        ids, w = payload
        # no PLAN_CACHE traffic from the daemon thread: the captured
        # profile is transient and must not evict live serving entries
        return ids, plan_a2a(w, self.q, self.method, use_cache=False)

    def _swap_in(self, result) -> bool:
        """Adopt a background plan built for a captured profile onto the
        *current* one: deletes since capture are filtered out of its bins,
        inserts are replayed through the repair rules, and reweights are
        re-validated against bin capacity.  False (state is then rebuilt
        by a synchronous re-plan) when the plan went stale."""
        ids, schema = result
        if schema.meta.get("bins_overlap", False):
            return False            # no local repair rules to replay with
        bins = [[i for i in (int(ids[j]) for j in b) if self.active[i]]
                for b in schema.bins]
        bw = np.asarray([sum(self.weights[i] for i in b) for b in bins],
                        dtype=np.float64)
        if schema.algorithm == "single":
            cap = self.q
            total = float(np.sum(self.active_weights()))
            if total > cap + _EPS:
                return False
        elif schema.algorithm.startswith("binpack-k"):
            cap = float(schema.meta["bin_size"])
            if len(bw) and float(np.max(bw, initial=0.0)) > cap + _EPS:
                return False        # an interleaved reweight overflowed
        else:
            return False
        old_key = self._cache_key
        self._cache_key = None      # planned off-cache for a stale profile
        if old_key is not None:
            PLAN_CACHE.invalidate(old_key)
        self._adopt_schema_state(schema, bins, list(schema.reducers))
        self.comm_cost = self._comm_from_state()
        self._recompute_lb()
        # replay inserts that arrived after capture (ascending = insertion
        # order); a failed placement leaves a half-adopted-but-consistent
        # structure that the caller's synchronous re-plan rebuilds anyway
        placed = set(self.bin_of)
        for i in self.active_ids():
            if int(i) in placed:
                continue
            if self._repair_place(int(i)) is None:
                return False
        self._recompute_lb()
        self._after_adopt()
        return True

    # --------------------------------------------------------------- queries
    def expanded(self) -> list[list[int]]:
        """reducer -> sorted live full-table input ids."""
        return [self.expand_row(r) for r in range(len(self.reducers))]

    def expand_row(self, r: int) -> list[int]:
        ids: set[int] = set()
        for b in self.reducers[r]:
            ids.update(self.bins[b])
        return sorted(ids)

    def plan(self) -> ReducerPlan:
        """The current full ReducerPlan (idx/mask into the full table),
        rebuilt lazily after edits."""
        if self._plan is None:
            w_full = np.asarray(
                [w if a else 0.0
                 for w, a in zip(self.weights, self.active)],
                dtype=np.float64)
            schema = MappingSchema(
                weights=w_full, q=self.q, bins=self.bins,
                reducers=self.reducers,
                algorithm=f"stream:{self.algorithm}",
                meta={"partial_cover": True,
                      "bins_overlap": self.overlapping},
                lower_bound=self._lb)
            self._plan = build_plan(schema, **self._pad)
        return self._plan

    def snapshot(self) -> MappingSchema:
        """Compacted MappingSchema over the live inputs (ids remapped to
        0..n-1) — what the conformance suite validates and what a cold
        re-plan is compared against."""
        ids = self.active_ids()
        remap = {int(g): i for i, g in enumerate(ids)}
        return MappingSchema(
            weights=self.active_weights(), q=self.q,
            bins=[[remap[i] for i in b] for b in self.bins],
            reducers=[list(r) for r in self.reducers],
            algorithm=f"stream:{self.algorithm}",
            meta={"bins_overlap": self.overlapping},
            lower_bound=self._lb)

    def delta_shapes(self, max_shapes: int = 256) -> list[tuple[int, int]]:
        """The bounded set of ``(padded rows, bucket width)`` sub-plan
        shapes a repair-path edit can produce, read off the live bin
        structure: an insert into bin ``b``'s slack dirties
        ``reducers_of_bin[b]`` (each reducer one slot wider), a forced new
        bin dirties ``ceil(B / (k-1))`` pairing reducers.  Each candidate
        dirty-set size signature is pushed through ``compact_plan`` itself
        (synthetic ids — only the lengths shape the program), so the
        shapes ``StreamingExecutor.warm_delta_shapes`` pre-compiles at
        load time are exactly the edit-time shapes by construction."""
        if self.kind not in ("binpack", "single"):
            return []
        shapes: set[tuple[int, int]] = set()
        seen: set[tuple] = set()

        def add(counts: list[int]) -> None:
            sig = tuple(sorted(counts))
            if not counts or sig in seen:
                return
            seen.add(sig)
            sub = compact_plan(
                [list(range(c)) for c in counts], comm_cost=0.0,
                algorithm="warmup",
                max_buckets=self._pad["max_buckets"],
                pad_reducers_to=self._pad["pad_reducers_to"])
            for b in sub.buckets:
                shapes.add((int(b.idx.shape[0]), int(b.width)))

        if self.kind == "single":
            add([self.num_active + 1])
        else:
            # disjoint bins: reducer size == sum of member bin sizes
            sizes = [sum(len(self.bins[b]) for b in red)
                     for red in self.reducers]
            live = [b for b in range(len(self.bins))
                    if b not in self.dead_bins and self.bins[b]]
            for b in live:
                add([sizes[r] + 1 for r in self.reducers_of_bin[b]])
            group = max(self.k - 1, 1)
            add([1 + sum(len(self.bins[b]) for b in live[lo: lo + group])
                 for lo in range(0, len(live), group)])
        return sorted(shapes)[:max_shapes]

    # ----------------------------------------------------------------- edits
    def insert(self, weight: float) -> PlanDelta:
        """Add one input; returns the delta (``delta.input_id`` is the new
        full-table id).  Raises ``InfeasibleError`` (edit rolled back) when
        no schema can hold the grown profile."""
        i = len(self.weights)
        self.weights.append(float(weight))
        self.active.append(True)
        try:
            return self._edited("insert", i, self._repair_place(i))
        except InfeasibleError:
            self.weights.pop()
            self.active.pop()
            self.stats["edits"] -= 1             # the edit never happened
            raise

    def delete(self, i: int) -> PlanDelta:
        """Tombstone input ``i``; its pairs need no recompute — the
        executor zeroes row/column i of the served matrix."""
        i = int(i)
        assert self.active[i], f"input {i} is not live"
        self.active[i] = False
        if self.kind in ("opaque", "empty"):
            return self._edited("delete", i, None)
        b = self.bin_of.pop(i)
        self.bins[b].remove(i)
        self._bw[b] -= self.weights[i]
        self.comm_cost -= self.weights[i] * len(self.reducers_of_bin[b])
        if not self.bins[b]:
            self.dead_bins.add(b)
            self.stats["dead_bins"] += 1
        return self._edited(
            "delete", i,
            dict(dirty=[], touched=[i], repaired=True))

    def reweight(self, i: int, weight: float) -> PlanDelta:
        """Change input ``i``'s size.  Feature rows are untouched, so no
        reducer block changes value — only planning state moves."""
        i = int(i)
        assert self.active[i], f"input {i} is not live"
        old = self.weights[i]
        self.weights[i] = float(weight)
        try:
            return self._reweight_placed(i, old, weight)
        except InfeasibleError:
            # roll back to a consistent pre-edit state (the pre-edit
            # profile was feasible, so this re-plan cannot raise)
            self.weights[i] = old
            self._adopt_replan()
            self.stats["edits"] -= 1             # the edit never happened
            raise

    def _reweight_placed(self, i: int, old: float,
                         weight: float) -> PlanDelta:
        if self.kind in ("opaque", "empty"):
            return self._edited("reweight", i, None)
        b = self.bin_of[i]
        # in-place when the capacity constraint still holds: the bin's
        # slack for binpack, the whole reducer's q for the single schema
        fits = (float(np.sum(self.active_weights())) <= self.q + _EPS
                if self.kind == "single"
                else self._bw[b] - old + weight <= self.bin_size + _EPS)
        if fits:
            self._bw[b] += weight - old
            self.comm_cost += (weight - old) * len(self.reducers_of_bin[b])
            return self._edited(
                "reweight", i, dict(dirty=[], touched=[], repaired=True))
        # move: out of the old bin, re-place like an insert (same id)
        self.bin_of.pop(i)
        self.bins[b].remove(i)
        self._bw[b] -= old
        self.comm_cost -= old * len(self.reducers_of_bin[b])
        if not self.bins[b]:
            self.dead_bins.add(b)
            self.stats["dead_bins"] += 1
        repair = self._repair_place(i)
        if repair is not None:
            # values of every pair are unchanged (feature rows untouched);
            # the opened reducers only need computing on the next cold build
            repair = dict(repair, touched=[], dirty=[], moved=True)
        return self._edited("reweight", i, repair)

    # ---------------------------------------------------------------- repair
    def _repair_place(self, i: int) -> Optional[dict]:
        """Place input ``i`` (already weighted) into the maintained
        structure; None when only a full re-plan can absorb it."""
        w = self.weights[i]
        if self.kind == "single":
            live = self.active_weights()
            if float(np.sum(live)) > self.q + _EPS:
                return None
            nb = self._open_bin(i)
            if not self.reducers:
                self.reducers.append([nb])
                self.reducers_of_bin[nb] = [0]
                self.stats["opened_reducers"] += 1
            else:
                self.reducers[0] = self.reducers[0] + [nb]
                self.reducers_of_bin[nb] = [0]
            self.comm_cost += w
            return dict(dirty=[0], touched=[i], repaired=True)
        if self.kind != "binpack" or w > self.bin_size + _EPS:
            return None
        # residual best-fit: fullest live bin whose slack holds w
        fits = np.flatnonzero(self._bw + w <= self.bin_size + _EPS)
        fits = np.asarray([b for b in fits if b not in self.dead_bins
                           and self.bins[b]], dtype=np.int64)
        if len(fits):
            b = int(fits[np.argmax(self._bw[fits])])
            self.bins[b].append(i)
            self._bw[b] += w
            self.bin_of[i] = b
            self.comm_cost += w * len(self.reducers_of_bin[b])
            return dict(dirty=list(self.reducers_of_bin[b]), touched=[i],
                        repaired=True)
        # no slack anywhere: capacity forces a new bin + pairing reducers
        nb = self._open_bin(i)
        live = [b for b in range(len(self.bins))
                if b != nb and b not in self.dead_bins and self.bins[b]]
        dirty = []
        group = max(self.k - 1, 1)
        for lo in range(0, len(live), group):
            chunk = live[lo: lo + group]
            r = len(self.reducers)
            self.reducers.append([nb] + chunk)
            dirty.append(r)
            self.reducers_of_bin[nb].append(r)
            for b in chunk:
                self.reducers_of_bin[b].append(r)
            self.comm_cost += w + float(np.sum(self._bw[chunk]))
        if not live:                         # first live bin: solo reducer
            r = len(self.reducers)
            self.reducers.append([nb])
            dirty.append(r)
            self.reducers_of_bin[nb].append(r)
            self.comm_cost += w
        self.stats["opened_reducers"] += len(dirty)
        return dict(dirty=dirty, touched=[i], repaired=True)

    def _open_bin(self, i: int) -> int:
        nb = len(self.bins)
        self.bins.append([i])
        self._bw = np.append(self._bw, self.weights[i])
        self.bin_of[i] = nb
        self.reducers_of_bin[nb] = []
        self.stats["opened_bins"] += 1
        return nb

    # --------------------------------------------------------------- repack
    def _repack_pass(self, max_bins: int = 4) -> tuple[int, int]:
        """Local repacking: drain the lightest live bins into other bins'
        slack (whole-bin try-then-commit), tombstone the emptied bins,
        then prune reducers left pairing nothing.  Pure planning-state
        surgery — a migrated input's new bin already meets every live bin
        (the A2A invariant), so no pair value changes and no reducer needs
        recomputing; the communication ledger just shrinks."""
        if self.kind != "binpack":
            return 0, 0
        moved = 0
        live = sorted((b for b in range(len(self.bins))
                       if b not in self.dead_bins and self.bins[b]),
                      key=lambda b: self._bw[b])
        for src in live[:max_bins]:
            if src in self.dead_bins or not self.bins[src]:
                continue        # drained into earlier in this pass
            assign = self._plan_drain(src)
            if assign is None:
                continue
            deg_src = len(self.reducers_of_bin[src])
            for i, tgt in assign:
                w = self.weights[i]
                self.bins[src].remove(i)
                self.bins[tgt].append(i)
                self.bin_of[i] = tgt
                self._bw[src] -= w
                self._bw[tgt] += w
                self.comm_cost += w * (len(self.reducers_of_bin[tgt])
                                       - deg_src)
                moved += 1
            self.dead_bins.add(src)
            self.stats["dead_bins"] += 1
        pruned = self._prune_dead_reducers()
        return moved, pruned

    def _plan_drain(self, src: int) -> Optional[list[tuple[int, int]]]:
        """Assignment draining bin ``src`` entirely into other live bins'
        slack (heaviest member first, fullest target that fits), or None
        when the whole bin does not fit — partial drains never retire a
        bin, so they are not worth the ledger churn."""
        loads = self._bw.copy()
        targets = [b for b in range(len(self.bins))
                   if b != src and b not in self.dead_bins and self.bins[b]]
        if not targets:
            return None
        assign = []
        for i in sorted(self.bins[src], key=lambda j: -self.weights[j]):
            w = self.weights[i]
            best, best_load = -1, -1.0
            for b in targets:
                if loads[b] + w <= self.bin_size + _EPS \
                        and loads[b] > best_load:
                    best, best_load = b, float(loads[b])
            if best < 0:
                return None
            loads[best] += w
            assign.append((i, best))
        return assign

    def _prune_dead_reducers(self) -> int:
        """Drop reducers whose member bins include <= 1 live bin — they
        pair nothing — provided the surviving bin keeps >= 1 other reducer
        (every live bin must stay in a reducer so its internal pairs stay
        covered).  Reducer ids are re-compacted; only called on
        empty-dirty edits, so no outstanding delta references old ids."""
        deg = {b: len(rs) for b, rs in self.reducers_of_bin.items()}
        keep: list[list[int]] = []
        pruned = 0
        for red in self.reducers:
            mem = [b for b in red
                   if b not in self.dead_bins and self.bins[b]]
            if len(mem) == 0 or (len(mem) == 1 and deg[mem[0]] > 1):
                self.comm_cost -= float(sum(self._bw[b] for b in mem))
                for b in red:
                    deg[b] -= 1
                pruned += 1
            else:
                keep.append(red)
        if pruned:
            self.reducers = keep
            self.reducers_of_bin = {b: [] for b in range(len(self.bins))}
            for r, red in enumerate(self.reducers):
                for b in red:
                    self.reducers_of_bin[b].append(r)
        return pruned

    # ------------------------------------------------------------- finishing
    def _patch_after_replan(self, kind: str, i: int) -> dict:
        """The compact patch that re-serves the edited input under the
        freshly adopted plan: inserts dirty every reducer containing the
        new input (they cover all its pairs — the A2A property), deletes
        just zero their row/column, reweights move no feature rows."""
        if kind == "insert":
            if not self.overlapping and i in self.bin_of:
                rows = sorted(self.reducers_of_bin[self.bin_of[i]])
            else:   # overlapping bins: scan for membership
                rows = sorted(r for r, red in enumerate(self.reducers)
                              if any(i in self.bins[b] for b in red))
            return dict(dirty=rows, touched=[i], repaired=True)
        if kind == "delete":
            return dict(dirty=[], touched=[i], repaired=True)
        return dict(dirty=[], touched=[], repaired=True)     # reweight

    def _finish_delta(self, kind: str, i: int, repair: dict,
                      extra_meta: Optional[dict] = None) -> PlanDelta:
        dirty = np.asarray(sorted(repair["dirty"]), dtype=np.int64)
        sub = None
        # expand only the dirty rows: per-edit host work stays O(dirty),
        # not O(R) (the full expansion is only needed to re-verify a
        # reweight *move*, which is the rare repair)
        rows_map = {int(r): self.expand_row(int(r)) for r in dirty}
        if len(dirty):
            rows = [rows_map[int(r)] for r in dirty]
            comm = float(sum(self.weights[j] for ids in rows for j in ids))
            sub = compact_plan(
                rows, comm_cost=comm, algorithm=f"stream-delta:{kind}",
                max_buckets=self._pad["max_buckets"],
                pad_reducers_to=self._pad["pad_reducers_to"])
        meta = {"algorithm": self.algorithm,
                "achievable_gap": float(self.achievable_gap)}
        if extra_meta:
            meta.update(extra_meta)
        delta = PlanDelta(
            kind=kind, input_id=i,
            touched_inputs=np.asarray(repair["touched"], dtype=np.int64),
            dirty_rows=dirty, sub_plan=sub, full_replan=False,
            num_reducers=self.num_reducers, comm_cost=self.comm_cost,
            lower_bound=self._lb, gap_drift=self.gap_drift,
            meta=meta)
        if self.check:
            if kind == "reweight":
                # an in-place reweight changes no structure: nothing to
                # re-verify; a move needs the full expansion (rare repair)
                if repair.get("moved"):
                    delta.verify(self.expanded(), self.active_ids())
            else:
                delta.verify(rows_map, self.active_ids())
        return delta
