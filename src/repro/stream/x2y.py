"""IncrementalX2YPlanner: online maintenance of an X2Y mapping schema.

The rectangular analogue of :class:`~repro.stream.incremental.
IncrementalPlanner` for the paper's Section-10 bipartite workload: X
inputs pack into bins of size ``b``, Y inputs into bins of ``q - b``, and
every reducer meets one live X-bin with one live Y-bin — the maintained
invariant is exactly X2Y coverage (every (live x, live y) cross pair
meets at >= 1 reducer).

Repair rules:

  insert_x(w) — residual best-fit into the fullest live X-bin whose slack
                still holds ``w`` (its reducers go dirty: they gain one X
                row against their full Y side).  No slack: open a new
                X-bin and one new reducer per live Y-bin — coverage of
                the new input against every live Y input is restored by
                construction, and every new reducer's load is
                ``w + |y-bin| <= b + (q - b) = q``.
  insert_y(w) — symmetric with capacity ``q - b``.
  delete_x(i) / delete_y(j) — drop the input from its bin (emptied bins
                are tombstoned, never revived); no recompute — the
                executor zeroes row i / column j of the served matrix.

Triggers, background repacking, and the double-buffered re-plan live in
:class:`~repro.stream.base.StreamPlannerBase` (shared with the all-pairs
planner).  The theorem bound is Thm 25 (``x2y_comm_lower_bound`` =
``2 s_x s_y / q``); the achievable reference is ``2x`` that — the
grid-of-bins family any feasible covering schema belongs to ships each
side once per opposite-side bin, which costs at least
``2 (2 s_x s_y / q)`` when both sides saturate their capacity split — so
ceilings fire on real degradation, not on the bound's intrinsic
looseness.  A full re-plan (through ``repro.core.plan_x2y``, which may
move the split point ``b`` itself) adopts the fresh schema as planning
state but emits only a compact *patch* delta: pair values are
plan-independent, so the served matrix never rebuilds.
``PlanDelta.verify_x2y`` is the per-edit coverage proof when
``check=True``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.bounds import x2y_comm_lower_bound
from repro.core.planner import plan_x2y
from repro.core.schema import InfeasibleError
from repro.mapreduce.engine import ReducerPlan, build_x2y_plan_arrays

from .base import StreamPlannerBase, _EPS
from .delta import PlanDelta, compact_x2y_plan

__all__ = ["IncrementalX2YPlanner"]


def _ffd_pack(ids: Sequence[int], weights: Sequence[float],
              cap: float) -> list[list[int]]:
    """First-fit-decreasing over explicit ids (the one-sided bootstrap
    path: no cross pairs exist yet, so any feasible packing works)."""
    bins: list[list[int]] = []
    loads: list[float] = []
    for i in sorted(ids, key=lambda i: -weights[i]):
        w = float(weights[i])
        if w > cap + _EPS:
            raise InfeasibleError(
                f"input {i} (w={w}) exceeds bin capacity {cap}")
        for b, load in enumerate(loads):
            if load + w <= cap + _EPS:
                bins[b].append(i)
                loads[b] += w
                break
        else:
            bins.append([i])
            loads.append(w)
    return bins


class IncrementalX2YPlanner(StreamPlannerBase):
    """Mutable X2Y mapping-schema state over growing/shrinking X and Y
    tables.

    Ids are stable full-table positions per side: ``insert_x`` appends a
    new X id (``insert_y`` a new Y id) and deleted ids are never reused,
    so the serving tier keeps two flat feature tables with tombstones.
    ``plan()`` returns the current rectangular :class:`ReducerPlan`
    (idx/mask into the X table, yidx/ymask into the Y table);
    ``snapshot_counts()`` exposes the live bin structure for validation.
    """

    def __init__(self, q: float, wx: Sequence[float] = (),
                 wy: Sequence[float] = (), *, replan_drift: float = 1.5,
                 max_gap: Optional[float] = 2.0,
                 repack_gap: Optional[float] = None,
                 background: bool = False,
                 pad_reducers_to: int = 1, max_buckets: int = 8,
                 check: bool = True):
        super().__init__(replan_drift=replan_drift, max_gap=max_gap,
                         repack_gap=repack_gap, background=background,
                         check=check)
        self.q = float(q)
        self._pad = dict(pad_reducers_to=pad_reducers_to,
                         max_buckets=max_buckets)
        self.wx: list[float] = [float(w) for w in wx]
        self.wy: list[float] = [float(w) for w in wy]
        self.active_x: list[bool] = [True] * len(self.wx)
        self.active_y: list[bool] = [True] * len(self.wy)
        self._adopt_replan()

    # ------------------------------------------------------------ properties
    @property
    def num_active_x(self) -> int:
        return int(np.sum(self.active_x))

    @property
    def num_active_y(self) -> int:
        return int(np.sum(self.active_y))

    @property
    def num_reducers(self) -> int:
        return len(self.reducers)

    def active_x_ids(self) -> np.ndarray:
        return np.flatnonzero(self.active_x)

    def active_y_ids(self) -> np.ndarray:
        return np.flatnonzero(self.active_y)

    def active_x_weights(self) -> np.ndarray:
        return np.asarray([self.wx[i] for i in self.active_x_ids()],
                          dtype=np.float64)

    def active_y_weights(self) -> np.ndarray:
        return np.asarray([self.wy[j] for j in self.active_y_ids()],
                          dtype=np.float64)

    # ---------------------------------------------------------------- bounds
    def _recompute_lb(self) -> None:
        """Thm 25 theorem bound, plus the grid-family achievable
        reference (2x Thm 25 — what a fresh split-point plan actually
        reaches when both sides saturate their capacity split)."""
        if self.num_active_x and self.num_active_y:
            self._lb = x2y_comm_lower_bound(
                self.active_x_weights(), self.active_y_weights(), self.q)
            self._lb_ach = 2.0 * self._lb
        else:
            self._lb = self._lb_ach = 0.0

    # -------------------------------------------------------------- adoption
    def _adopt_replan(self) -> None:
        """Full re-plan of the live profile through ``plan_x2y``; adopt
        the winning schema (including its split point ``b``) as the new
        mutable state.  One-sided profiles have no cross pairs: the
        present side is FFD-packed at the full capacity ``q`` and no
        reducers exist (nothing ships)."""
        x_ids = self.active_x_ids()
        y_ids = self.active_y_ids()
        wx = self.active_x_weights()
        wy = self.active_y_weights()
        if len(x_ids) == 0 or len(y_ids) == 0:
            algorithm = "empty" if not (len(x_ids) or len(y_ids)) \
                else "x2y-one-sided"
            # all capacity to the present side; the other side's first
            # insert forces a full re-plan (w > 0 slack), which then
            # picks a real split point
            b = self.q if len(y_ids) == 0 else 0.0
            xbins = _ffd_pack(x_ids, self.wx, self.q) if len(x_ids) else []
            ybins = _ffd_pack(y_ids, self.wy, self.q) if len(y_ids) else []
            reducers: list[tuple[int, int]] = []
        else:
            schema = plan_x2y(wx, wy, self.q)   # may raise InfeasibleError
            algorithm = schema.algorithm
            b = float(schema.meta["b"])
            nxb = int(schema.meta["x_bins"])
            nx = len(x_ids)
            xbins = [[int(x_ids[i]) for i in bin_]
                     for bin_ in schema.bins[:nxb]]
            ybins = [[int(y_ids[i - nx]) for i in bin_]
                     for bin_ in schema.bins[nxb:]]
            reducers = [(int(r[0]), int(r[1]) - nxb)
                        for r in schema.reducers]
        self._adopt_x2y_state(algorithm, b, xbins, ybins, reducers)
        self._recompute_lb()
        self._after_adopt()

    def _adopt_x2y_state(self, algorithm: str, b: float,
                         xbins: list[list[int]], ybins: list[list[int]],
                         reducers: list[tuple[int, int]]) -> None:
        """Install a split point + bin/reducer structure over full-table
        ids; shared by the synchronous adopt and the background swap."""
        self.algorithm = algorithm
        self.b = float(b)
        self.xbins = xbins
        self.ybins = ybins
        self.reducers = reducers
        self.dead_xbins: set[int] = {bx for bx, mem in enumerate(xbins)
                                     if not mem}
        self.dead_ybins: set[int] = {by for by, mem in enumerate(ybins)
                                     if not mem}
        self._bwx = np.asarray(
            [sum(self.wx[i] for i in bn) for bn in self.xbins], np.float64)
        self._bwy = np.asarray(
            [sum(self.wy[j] for j in bn) for bn in self.ybins], np.float64)
        self.xbin_of = {i: bx for bx, mem in enumerate(self.xbins)
                        for i in mem}
        self.ybin_of = {j: by for by, mem in enumerate(self.ybins)
                        for j in mem}
        self.reducers_of_xbin: dict[int, list[int]] = {
            bx: [] for bx in range(len(self.xbins))}
        self.reducers_of_ybin: dict[int, list[int]] = {
            by: [] for by in range(len(self.ybins))}
        for r, (xb, yb) in enumerate(self.reducers):
            self.reducers_of_xbin[xb].append(r)
            self.reducers_of_ybin[yb].append(r)
        self.comm_cost = float(sum(self._bwx[xb] + self._bwy[yb]
                                   for xb, yb in self.reducers))
        self._plan: Optional[ReducerPlan] = None

    # --------------------------------------------------- background re-plan
    def _capture_profile(self):
        return (self.active_x_ids().copy(), self.active_x_weights().copy(),
                self.active_y_ids().copy(), self.active_y_weights().copy())

    def _background_plan(self, payload):
        x_ids, wx, y_ids, wy = payload
        return x_ids, y_ids, plan_x2y(wx, wy, self.q)

    def _swap_in(self, result) -> bool:
        """Adopt a background plan built for a captured profile onto the
        *current* one: deletes since capture are filtered out of its
        bins, inserts on either side are replayed through the repair
        rules.  False (caller re-plans synchronously) when the plan went
        stale — a side emptied, or a bin overflows its split capacity."""
        x_ids, y_ids, schema = result
        if not (self.num_active_x and self.num_active_y):
            return False
        b = float(schema.meta["b"])
        nxb = int(schema.meta["x_bins"])
        nx = len(x_ids)
        xbins = [[i for i in (int(x_ids[k]) for k in bin_)
                  if self.active_x[i]]
                 for bin_ in schema.bins[:nxb]]
        ybins = [[j for j in (int(y_ids[k - nx]) for k in bin_)
                  if self.active_y[j]]
                 for bin_ in schema.bins[nxb:]]
        bwx = [sum(self.wx[i] for i in bn) for bn in xbins]
        bwy = [sum(self.wy[j] for j in bn) for bn in ybins]
        if (bwx and max(bwx) > b + _EPS) \
                or (bwy and max(bwy) > self.q - b + _EPS):
            return False
        self._adopt_x2y_state(
            schema.algorithm, b, xbins, ybins,
            [(int(r[0]), int(r[1]) - nxb) for r in schema.reducers])
        self._recompute_lb()
        # replay inserts that arrived after capture, ascending per side
        for i in self.active_x_ids():
            if int(i) not in self.xbin_of \
                    and self._place("x", int(i)) is None:
                return False
        for j in self.active_y_ids():
            if int(j) not in self.ybin_of \
                    and self._place("y", int(j)) is None:
                return False
        self._recompute_lb()
        self._after_adopt()
        return True

    # --------------------------------------------------------------- queries
    def x_expanded(self) -> list[list[int]]:
        """reducer -> live X-table ids (dead-bin sides are empty)."""
        return [sorted(self.xbins[xb]) for xb, _ in self.reducers]

    def y_expanded(self) -> list[list[int]]:
        return [sorted(self.ybins[yb]) for _, yb in self.reducers]

    def plan(self) -> ReducerPlan:
        """The current full rectangular ReducerPlan (X ids into the full
        X table, Y ids into the full Y table), rebuilt lazily."""
        if self._plan is None:
            self._plan = build_x2y_plan_arrays(
                self.x_expanded(), self.y_expanded(),
                num_x=len(self.wx), num_y=len(self.wy),
                comm_cost=self.comm_cost,
                algorithm=f"stream:x2y(b={self.b:.3g})",
                lower_bound=self._lb,
                pad_reducers_to=self._pad["pad_reducers_to"],
                max_buckets=self._pad["max_buckets"])
        return self._plan

    def delta_shapes(self, max_shapes: int = 256) \
            -> list[tuple[int, int, int]]:
        """The bounded set of ``(padded rows, x width, y width)`` sub-plan
        shapes a repair-path edit can produce, read off the live bin
        structure (insert into a bin's slack dirties that bin's reducers,
        one slot wider on its side; a forced new bin dirties one fresh
        reducer per live opposite bin).  Signatures go through
        ``compact_x2y_plan`` itself, so the shapes
        ``StreamingExecutor.warm_delta_shapes_x2y`` pre-compiles at load
        time are exactly the edit-time shapes by construction."""
        if not self.reducers:
            return []
        shapes: set[tuple[int, int, int]] = set()
        seen: set[tuple] = set()

        def add(pairs: list[tuple[int, int]]) -> None:
            sig = tuple(sorted(pairs))
            if not pairs or sig in seen:
                return
            seen.add(sig)
            sub = compact_x2y_plan(
                [list(range(cx)) for cx, _ in pairs],
                [list(range(cy)) for _, cy in pairs],
                num_x=max(len(self.wx), 1), num_y=max(len(self.wy), 1),
                comm_cost=0.0, algorithm="warmup",
                max_buckets=self._pad["max_buckets"],
                pad_reducers_to=self._pad["pad_reducers_to"])
            for bk in sub.buckets:
                shapes.add((int(bk.idx.shape[0]), int(bk.width),
                            int(bk.ywidth)))

        live_x = [bx for bx in range(len(self.xbins))
                  if bx not in self.dead_xbins and self.xbins[bx]]
        live_y = [by for by in range(len(self.ybins))
                  if by not in self.dead_ybins and self.ybins[by]]
        for bx in live_x:       # insert_x into bx's slack
            add([(len(self.xbins[bx]) + 1,
                  len(self.ybins[self.reducers[r][1]]))
                 for r in self.reducers_of_xbin[bx]])
        for by in live_y:       # insert_y into by's slack
            add([(len(self.xbins[self.reducers[r][0]]),
                  len(self.ybins[by]) + 1)
                 for r in self.reducers_of_ybin[by]])
        # forced new bin: one fresh reducer per live opposite bin
        add([(1, len(self.ybins[by])) for by in live_y])
        add([(len(self.xbins[bx]), 1) for bx in live_x])
        return sorted(shapes)[:max_shapes]

    # ----------------------------------------------------------------- edits
    def insert_x(self, weight: float) -> PlanDelta:
        """Add one X input; ``delta.input_id`` is the new X-table id.
        Raises ``InfeasibleError`` (edit rolled back) when no schema can
        hold the grown profile."""
        i = len(self.wx)
        self.wx.append(float(weight))
        self.active_x.append(True)
        try:
            return self._edited("insert_x", i, self._place("x", i))
        except InfeasibleError:
            self.wx.pop()
            self.active_x.pop()
            self.stats["edits"] -= 1
            raise

    def insert_y(self, weight: float) -> PlanDelta:
        """Add one Y input; symmetric to :meth:`insert_x`."""
        j = len(self.wy)
        self.wy.append(float(weight))
        self.active_y.append(True)
        try:
            return self._edited("insert_y", j, self._place("y", j))
        except InfeasibleError:
            self.wy.pop()
            self.active_y.pop()
            self.stats["edits"] -= 1
            raise

    def delete_x(self, i: int) -> PlanDelta:
        """Tombstone X input ``i``; no recompute — the executor zeroes
        row i of the served (mx, my) matrix."""
        i = int(i)
        assert self.active_x[i], f"x input {i} is not live"
        self.active_x[i] = False
        b = self.xbin_of.pop(i)
        self.xbins[b].remove(i)
        self._bwx[b] -= self.wx[i]
        self.comm_cost -= self.wx[i] * len(self.reducers_of_xbin[b])
        if not self.xbins[b]:
            self.dead_xbins.add(b)
            self.stats["dead_bins"] += 1
        return self._edited("delete_x", i,
                            dict(dirty=[], touched_x=[i], touched_y=[]))

    def delete_y(self, j: int) -> PlanDelta:
        """Tombstone Y input ``j``; the executor zeroes column j."""
        j = int(j)
        assert self.active_y[j], f"y input {j} is not live"
        self.active_y[j] = False
        b = self.ybin_of.pop(j)
        self.ybins[b].remove(j)
        self._bwy[b] -= self.wy[j]
        self.comm_cost -= self.wy[j] * len(self.reducers_of_ybin[b])
        if not self.ybins[b]:
            self.dead_ybins.add(b)
            self.stats["dead_bins"] += 1
        return self._edited("delete_y", j,
                            dict(dirty=[], touched_x=[], touched_y=[j]))

    # ---------------------------------------------------------------- repair
    def _place(self, side: str, i: int) -> Optional[dict]:
        """Place the new input into the maintained bin structure; None
        when only a full re-plan can absorb it (over-capacity weight, or
        a one-sided bootstrap that must now pick a real split point)."""
        if side == "x":
            w, cap = self.wx[i], self.b
            bins, bw, dead = self.xbins, self._bwx, self.dead_xbins
            own_reds, bin_of = self.reducers_of_xbin, self.xbin_of
            other_bins, other_dead = self.ybins, self.dead_ybins
            other_bw, other_reds = self._bwy, self.reducers_of_ybin
            touched = dict(touched_x=[i], touched_y=[])
        else:
            w, cap = self.wy[i], self.q - self.b
            bins, bw, dead = self.ybins, self._bwy, self.dead_ybins
            own_reds, bin_of = self.reducers_of_ybin, self.ybin_of
            other_bins, other_dead = self.xbins, self.dead_xbins
            other_bw, other_reds = self._bwx, self.reducers_of_xbin
            touched = dict(touched_x=[], touched_y=[i])
        live_other = [b for b in range(len(other_bins))
                      if b not in other_dead and other_bins[b]]
        if live_other and w > cap + _EPS:
            return None                      # re-plan may move b itself
        if not live_other:
            # no cross pairs yet: repair only if the present side's
            # capacity (q on a one-sided bootstrap) holds w
            if w > (cap if self.reducers else self.q) + _EPS:
                return None
        # residual best-fit: fullest live bin whose slack holds w
        fits = np.flatnonzero(bw + w <= cap + _EPS) if len(bw) else \
            np.asarray([], np.int64)
        fits = np.asarray([b for b in fits if b not in dead and bins[b]],
                          dtype=np.int64)
        if len(fits):
            b = int(fits[np.argmax(bw[fits])])
            bins[b].append(i)
            bw[b] += w
            bin_of[i] = b
            self.comm_cost += w * len(own_reds[b])
            return dict(dirty=list(own_reds[b]), **touched)
        # no slack anywhere: capacity forces a new bin + one reducer per
        # live bin of the other side (coverage by construction)
        nb = len(bins)
        bins.append([i])
        if side == "x":
            self._bwx = np.append(self._bwx, w)
        else:
            self._bwy = np.append(self._bwy, w)
        bin_of[i] = nb
        own_reds[nb] = []
        self.stats["opened_bins"] += 1
        dirty = []
        for ob in live_other:
            r = len(self.reducers)
            self.reducers.append((nb, ob) if side == "x" else (ob, nb))
            dirty.append(r)
            own_reds[nb].append(r)
            other_reds[ob].append(r)
            self.comm_cost += w + float(other_bw[ob])
        self.stats["opened_reducers"] += len(dirty)
        return dict(dirty=dirty, **touched)

    # --------------------------------------------------------------- repack
    def _repack_pass(self, max_bins: int = 4) -> tuple[int, int]:
        """Local repacking, per side: drain the lightest live bins into
        other bins' slack (whole-bin try-then-commit), tombstone the
        emptied bins, then prune reducers with a dead side — they cover
        no cross pair but still ship their live side's weight.  A
        migrated input's target bin already meets every live opposite
        bin (the X2Y grid invariant), so no pair value changes."""
        moved = 0
        moved += self._drain_side("x", max_bins)
        moved += self._drain_side("y", max_bins)
        pruned = self._prune_dead_reducers()
        return moved, pruned

    def _drain_side(self, side: str, max_bins: int) -> int:
        if side == "x":
            bins, bw, dead = self.xbins, self._bwx, self.dead_xbins
            cap, weights = self.b, self.wx
            own_reds, bin_of = self.reducers_of_xbin, self.xbin_of
        else:
            bins, bw, dead = self.ybins, self._bwy, self.dead_ybins
            cap, weights = self.q - self.b, self.wy
            own_reds, bin_of = self.reducers_of_ybin, self.ybin_of
        moved = 0
        live = sorted((b for b in range(len(bins))
                       if b not in dead and bins[b]),
                      key=lambda b: bw[b])
        for src in live[:max_bins]:
            if src in dead or not bins[src]:
                continue
            targets = [b for b in range(len(bins))
                       if b != src and b not in dead and bins[b]]
            if not targets:
                continue
            loads = bw.copy()
            assign = []
            for i in sorted(bins[src], key=lambda j: -weights[j]):
                w = weights[i]
                best, best_load = -1, -1.0
                for b in targets:
                    if loads[b] + w <= cap + _EPS and loads[b] > best_load:
                        best, best_load = b, float(loads[b])
                if best < 0:
                    assign = None
                    break
                loads[best] += w
                assign.append((i, best))
            if assign is None:
                continue
            deg_src = len(own_reds[src])
            for i, tgt in assign:
                w = weights[i]
                bins[src].remove(i)
                bins[tgt].append(i)
                bin_of[i] = tgt
                bw[src] -= w
                bw[tgt] += w
                self.comm_cost += w * (len(own_reds[tgt]) - deg_src)
                moved += 1
            dead.add(src)
            self.stats["dead_bins"] += 1
        return moved

    def _prune_dead_reducers(self) -> int:
        """Drop reducers whose X or Y bin is dead — they cover no cross
        pair (X2Y coverage is full bipartite between *live* bins), so
        pruning is always safe and saves the live side's shipped weight.
        Reducer ids are re-compacted; only called on empty-dirty edits,
        so no outstanding delta references old ids."""
        keep: list[tuple[int, int]] = []
        pruned = 0
        for (xb, yb) in self.reducers:
            x_dead = xb in self.dead_xbins or not self.xbins[xb]
            y_dead = yb in self.dead_ybins or not self.ybins[yb]
            if x_dead or y_dead:
                self.comm_cost -= float(self._bwx[xb] + self._bwy[yb])
                pruned += 1
            else:
                keep.append((xb, yb))
        if pruned:
            self.reducers = keep
            self.reducers_of_xbin = {
                b: [] for b in range(len(self.xbins))}
            self.reducers_of_ybin = {
                b: [] for b in range(len(self.ybins))}
            for r, (xb, yb) in enumerate(self.reducers):
                self.reducers_of_xbin[xb].append(r)
                self.reducers_of_ybin[yb].append(r)
        return pruned

    # ------------------------------------------------------------- finishing
    def _patch_after_replan(self, kind: str, i: int) -> dict:
        """Compact patch re-serving the edited input under the freshly
        adopted plan: an inserted input's reducers cover all its cross
        pairs (the X2Y grid property); deletes just zero their
        row/column."""
        if kind == "insert_x":
            rows = sorted(self.reducers_of_xbin[self.xbin_of[i]]) \
                if i in self.xbin_of else []
            return dict(dirty=rows, touched_x=[i], touched_y=[])
        if kind == "insert_y":
            rows = sorted(self.reducers_of_ybin[self.ybin_of[i]]) \
                if i in self.ybin_of else []
            return dict(dirty=rows, touched_x=[], touched_y=[i])
        if kind == "delete_x":
            return dict(dirty=[], touched_x=[i], touched_y=[])
        return dict(dirty=[], touched_x=[], touched_y=[i])

    def _finish_delta(self, kind: str, i: int, repair: dict,
                      extra_meta: Optional[dict] = None) -> PlanDelta:
        dirty = np.asarray(sorted(repair["dirty"]), dtype=np.int64)
        sub = None
        xs_map = {int(r): sorted(self.xbins[self.reducers[int(r)][0]])
                  for r in dirty}
        ys_map = {int(r): sorted(self.ybins[self.reducers[int(r)][1]])
                  for r in dirty}
        if len(dirty):
            xs = [xs_map[int(r)] for r in dirty]
            ys = [ys_map[int(r)] for r in dirty]
            comm = float(
                sum(self.wx[a] for row in xs for a in row)
                + sum(self.wy[a] for row in ys for a in row))
            sub = compact_x2y_plan(
                xs, ys, num_x=len(self.wx), num_y=len(self.wy),
                comm_cost=comm, algorithm=f"stream-delta:{kind}",
                max_buckets=self._pad["max_buckets"],
                pad_reducers_to=self._pad["pad_reducers_to"])
        meta = {"workload": "x2y", "algorithm": self.algorithm,
                "achievable_gap": float(self.achievable_gap),
                "touched_x": [int(a) for a in repair["touched_x"]],
                "touched_y": [int(a) for a in repair["touched_y"]]}
        if extra_meta:
            meta.update(extra_meta)
        delta = PlanDelta(
            kind=kind, input_id=i,
            touched_inputs=np.asarray(
                repair["touched_x"] + repair["touched_y"], dtype=np.int64),
            dirty_rows=dirty, sub_plan=sub, full_replan=False,
            num_reducers=self.num_reducers, comm_cost=self.comm_cost,
            lower_bound=self._lb, gap_drift=self.gap_drift,
            meta=meta)
        if self.check:
            delta.verify_x2y(xs_map, ys_map, self.active_x_ids(),
                             self.active_y_ids())
        return delta
