"""IncrementalX2YPlanner: online maintenance of an X2Y mapping schema.

The rectangular analogue of :class:`~repro.stream.incremental.
IncrementalPlanner` for the paper's Section-10 bipartite workload: X
inputs pack into bins of size ``b``, Y inputs into bins of ``q - b``, and
every reducer meets one live X-bin with one live Y-bin — the maintained
invariant is exactly X2Y coverage (every (live x, live y) cross pair
meets at >= 1 reducer).

Repair rules:

  insert_x(w) — residual best-fit into the fullest live X-bin whose slack
                still holds ``w`` (its reducers go dirty: they gain one X
                row against their full Y side).  No slack: open a new
                X-bin and one new reducer per live Y-bin — coverage of
                the new input against every live Y input is restored by
                construction, and every new reducer's load is
                ``w + |y-bin| <= b + (q - b) = q``.
  insert_y(w) — symmetric with capacity ``q - b``.
  delete_x(i) / delete_y(j) — drop the input from its bin (emptied bins
                are tombstoned, never revived); no recompute — the
                executor zeroes row i / column j of the served matrix.

An insert too large for its side's bin capacity, or gap drift past
``replan_drift`` (maintained cost over the live profile's
``x2y_comm_lower_bound``, relative to the gap at the last full re-plan),
triggers a full re-plan through ``repro.core.plan_x2y`` — which may move
the split point ``b`` itself.  ``PlanDelta.verify_x2y`` is the per-edit
coverage proof when ``check=True``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.bounds import x2y_comm_lower_bound
from repro.core.planner import plan_x2y
from repro.core.schema import InfeasibleError
from repro.mapreduce.engine import ReducerPlan, build_x2y_plan_arrays

from .delta import PlanDelta, compact_x2y_plan

__all__ = ["IncrementalX2YPlanner"]

_EPS = 1e-12


def _ffd_pack(ids: Sequence[int], weights: Sequence[float],
              cap: float) -> list[list[int]]:
    """First-fit-decreasing over explicit ids (the one-sided bootstrap
    path: no cross pairs exist yet, so any feasible packing works)."""
    bins: list[list[int]] = []
    loads: list[float] = []
    for i in sorted(ids, key=lambda i: -weights[i]):
        w = float(weights[i])
        if w > cap + _EPS:
            raise InfeasibleError(
                f"input {i} (w={w}) exceeds bin capacity {cap}")
        for b, load in enumerate(loads):
            if load + w <= cap + _EPS:
                bins[b].append(i)
                loads[b] += w
                break
        else:
            bins.append([i])
            loads.append(w)
    return bins


class IncrementalX2YPlanner:
    """Mutable X2Y mapping-schema state over growing/shrinking X and Y
    tables.

    Ids are stable full-table positions per side: ``insert_x`` appends a
    new X id (``insert_y`` a new Y id) and deleted ids are never reused,
    so the serving tier keeps two flat feature tables with tombstones.
    ``plan()`` returns the current rectangular :class:`ReducerPlan`
    (idx/mask into the X table, yidx/ymask into the Y table);
    ``snapshot_counts()`` exposes the live bin structure for validation.
    """

    def __init__(self, q: float, wx: Sequence[float] = (),
                 wy: Sequence[float] = (), *, replan_drift: float = 1.5,
                 pad_reducers_to: int = 1, max_buckets: int = 8,
                 check: bool = True):
        assert replan_drift >= 1.0, replan_drift
        self.q = float(q)
        self.replan_drift = float(replan_drift)
        self.check = check
        self._pad = dict(pad_reducers_to=pad_reducers_to,
                         max_buckets=max_buckets)
        self.wx: list[float] = [float(w) for w in wx]
        self.wy: list[float] = [float(w) for w in wy]
        self.active_x: list[bool] = [True] * len(self.wx)
        self.active_y: list[bool] = [True] * len(self.wy)
        self.stats = {
            "edits": 0, "repairs": 0, "replans": 0, "drift_replans": 0,
            "opened_bins": 0, "opened_reducers": 0, "dead_bins": 0,
        }
        self._adopt_replan()

    # ------------------------------------------------------------ properties
    @property
    def num_active_x(self) -> int:
        return int(np.sum(self.active_x))

    @property
    def num_active_y(self) -> int:
        return int(np.sum(self.active_y))

    @property
    def num_reducers(self) -> int:
        return len(self.reducers)

    @property
    def lower_bound(self) -> float:
        return self._lb

    @property
    def optimality_gap(self) -> float:
        return self.comm_cost / self._lb if self._lb > 0 else 1.0

    @property
    def gap_drift(self) -> float:
        return self.optimality_gap / max(self._base_gap, _EPS)

    def active_x_ids(self) -> np.ndarray:
        return np.flatnonzero(self.active_x)

    def active_y_ids(self) -> np.ndarray:
        return np.flatnonzero(self.active_y)

    def active_x_weights(self) -> np.ndarray:
        return np.asarray([self.wx[i] for i in self.active_x_ids()],
                          dtype=np.float64)

    def active_y_weights(self) -> np.ndarray:
        return np.asarray([self.wy[j] for j in self.active_y_ids()],
                          dtype=np.float64)

    # -------------------------------------------------------------- adoption
    def _adopt_replan(self) -> None:
        """Full re-plan of the live profile through ``plan_x2y``; adopt
        the winning schema (including its split point ``b``) as the new
        mutable state.  One-sided profiles have no cross pairs: the
        present side is FFD-packed at the full capacity ``q`` and no
        reducers exist (nothing ships)."""
        x_ids = self.active_x_ids()
        y_ids = self.active_y_ids()
        wx = self.active_x_weights()
        wy = self.active_y_weights()
        if len(x_ids) == 0 or len(y_ids) == 0:
            self.algorithm = "empty" if not (len(x_ids) or len(y_ids)) \
                else "x2y-one-sided"
            # all capacity to the present side; the other side's first
            # insert forces a full re-plan (w > 0 slack), which then
            # picks a real split point
            self.b = self.q if len(y_ids) == 0 else 0.0
            self.xbins = _ffd_pack(x_ids, self.wx, self.q) \
                if len(x_ids) else []
            self.ybins = _ffd_pack(y_ids, self.wy, self.q) \
                if len(y_ids) else []
            self.reducers: list[tuple[int, int]] = []
        else:
            schema = plan_x2y(wx, wy, self.q)   # may raise InfeasibleError
            self.algorithm = schema.algorithm
            self.b = float(schema.meta["b"])
            nxb = int(schema.meta["x_bins"])
            nx = len(x_ids)
            self.xbins = [[int(x_ids[i]) for i in bin_]
                          for bin_ in schema.bins[:nxb]]
            self.ybins = [[int(y_ids[i - nx]) for i in bin_]
                          for bin_ in schema.bins[nxb:]]
            self.reducers = [(int(r[0]), int(r[1]) - nxb)
                             for r in schema.reducers]
        self.dead_xbins: set[int] = set()
        self.dead_ybins: set[int] = set()
        self._bwx = np.asarray(
            [sum(self.wx[i] for i in b) for b in self.xbins], np.float64)
        self._bwy = np.asarray(
            [sum(self.wy[j] for j in b) for b in self.ybins], np.float64)
        self.xbin_of = {i: b for b, mem in enumerate(self.xbins)
                        for i in mem}
        self.ybin_of = {j: b for b, mem in enumerate(self.ybins)
                        for j in mem}
        self.reducers_of_xbin: dict[int, list[int]] = {
            b: [] for b in range(len(self.xbins))}
        self.reducers_of_ybin: dict[int, list[int]] = {
            b: [] for b in range(len(self.ybins))}
        for r, (xb, yb) in enumerate(self.reducers):
            self.reducers_of_xbin[xb].append(r)
            self.reducers_of_ybin[yb].append(r)
        self.comm_cost = float(sum(self._bwx[xb] + self._bwy[yb]
                                   for xb, yb in self.reducers))
        self._lb = (x2y_comm_lower_bound(wx, wy, self.q)
                    if len(x_ids) and len(y_ids) else 0.0)
        self._base_gap = self.optimality_gap
        self._plan: Optional[ReducerPlan] = None
        self.stats["replans"] += 1

    # --------------------------------------------------------------- queries
    def x_expanded(self) -> list[list[int]]:
        """reducer -> live X-table ids (dead-bin sides are empty)."""
        return [sorted(self.xbins[xb]) for xb, _ in self.reducers]

    def y_expanded(self) -> list[list[int]]:
        return [sorted(self.ybins[yb]) for _, yb in self.reducers]

    def plan(self) -> ReducerPlan:
        """The current full rectangular ReducerPlan (X ids into the full
        X table, Y ids into the full Y table), rebuilt lazily."""
        if self._plan is None:
            self._plan = build_x2y_plan_arrays(
                self.x_expanded(), self.y_expanded(),
                num_x=len(self.wx), num_y=len(self.wy),
                comm_cost=self.comm_cost,
                algorithm=f"stream:x2y(b={self.b:.3g})",
                lower_bound=self._lb,
                pad_reducers_to=self._pad["pad_reducers_to"],
                max_buckets=self._pad["max_buckets"])
        return self._plan

    # ----------------------------------------------------------------- edits
    def insert_x(self, weight: float) -> PlanDelta:
        """Add one X input; ``delta.input_id`` is the new X-table id.
        Raises ``InfeasibleError`` (edit rolled back) when no schema can
        hold the grown profile."""
        i = len(self.wx)
        self.wx.append(float(weight))
        self.active_x.append(True)
        try:
            return self._edited("insert_x", i, self._place("x", i))
        except InfeasibleError:
            self.wx.pop()
            self.active_x.pop()
            self.stats["edits"] -= 1
            raise

    def insert_y(self, weight: float) -> PlanDelta:
        """Add one Y input; symmetric to :meth:`insert_x`."""
        j = len(self.wy)
        self.wy.append(float(weight))
        self.active_y.append(True)
        try:
            return self._edited("insert_y", j, self._place("y", j))
        except InfeasibleError:
            self.wy.pop()
            self.active_y.pop()
            self.stats["edits"] -= 1
            raise

    def delete_x(self, i: int) -> PlanDelta:
        """Tombstone X input ``i``; no recompute — the executor zeroes
        row i of the served (mx, my) matrix."""
        i = int(i)
        assert self.active_x[i], f"x input {i} is not live"
        self.active_x[i] = False
        b = self.xbin_of.pop(i)
        self.xbins[b].remove(i)
        self._bwx[b] -= self.wx[i]
        self.comm_cost -= self.wx[i] * len(self.reducers_of_xbin[b])
        if not self.xbins[b]:
            self.dead_xbins.add(b)
            self.stats["dead_bins"] += 1
        return self._edited("delete_x", i,
                            dict(dirty=[], touched_x=[i], touched_y=[]))

    def delete_y(self, j: int) -> PlanDelta:
        """Tombstone Y input ``j``; the executor zeroes column j."""
        j = int(j)
        assert self.active_y[j], f"y input {j} is not live"
        self.active_y[j] = False
        b = self.ybin_of.pop(j)
        self.ybins[b].remove(j)
        self._bwy[b] -= self.wy[j]
        self.comm_cost -= self.wy[j] * len(self.reducers_of_ybin[b])
        if not self.ybins[b]:
            self.dead_ybins.add(b)
            self.stats["dead_bins"] += 1
        return self._edited("delete_y", j,
                            dict(dirty=[], touched_x=[], touched_y=[j]))

    # ---------------------------------------------------------------- repair
    def _place(self, side: str, i: int) -> Optional[dict]:
        """Place the new input into the maintained bin structure; None
        when only a full re-plan can absorb it (over-capacity weight, or
        a one-sided bootstrap that must now pick a real split point)."""
        if side == "x":
            w, cap = self.wx[i], self.b
            bins, bw, dead = self.xbins, self._bwx, self.dead_xbins
            own_reds, bin_of = self.reducers_of_xbin, self.xbin_of
            other_bins, other_dead = self.ybins, self.dead_ybins
            other_bw, other_reds = self._bwy, self.reducers_of_ybin
            touched = dict(touched_x=[i], touched_y=[])
        else:
            w, cap = self.wy[i], self.q - self.b
            bins, bw, dead = self.ybins, self._bwy, self.dead_ybins
            own_reds, bin_of = self.reducers_of_ybin, self.ybin_of
            other_bins, other_dead = self.xbins, self.dead_xbins
            other_bw, other_reds = self._bwx, self.reducers_of_xbin
            touched = dict(touched_x=[], touched_y=[i])
        live_other = [b for b in range(len(other_bins))
                      if b not in other_dead and other_bins[b]]
        if live_other and w > cap + _EPS:
            return None                      # re-plan may move b itself
        if not live_other:
            # no cross pairs yet: repair only if the present side's
            # capacity (q on a one-sided bootstrap) holds w
            if w > (cap if self.reducers else self.q) + _EPS:
                return None
        # residual best-fit: fullest live bin whose slack holds w
        fits = np.flatnonzero(bw + w <= cap + _EPS) if len(bw) else \
            np.asarray([], np.int64)
        fits = np.asarray([b for b in fits if b not in dead and bins[b]],
                          dtype=np.int64)
        if len(fits):
            b = int(fits[np.argmax(bw[fits])])
            bins[b].append(i)
            bw[b] += w
            bin_of[i] = b
            self.comm_cost += w * len(own_reds[b])
            return dict(dirty=list(own_reds[b]), **touched)
        # no slack anywhere: capacity forces a new bin + one reducer per
        # live bin of the other side (coverage by construction)
        nb = len(bins)
        bins.append([i])
        if side == "x":
            self._bwx = np.append(self._bwx, w)
        else:
            self._bwy = np.append(self._bwy, w)
        bin_of[i] = nb
        own_reds[nb] = []
        self.stats["opened_bins"] += 1
        dirty = []
        for ob in live_other:
            r = len(self.reducers)
            self.reducers.append((nb, ob) if side == "x" else (ob, nb))
            dirty.append(r)
            own_reds[nb].append(r)
            other_reds[ob].append(r)
            self.comm_cost += w + float(other_bw[ob])
        self.stats["opened_reducers"] += len(dirty)
        return dict(dirty=dirty, **touched)

    # ------------------------------------------------------------- finishing
    def _edited(self, kind: str, i: int,
                repair: Optional[dict]) -> PlanDelta:
        self.stats["edits"] += 1
        self._plan = None
        if repair is not None:
            self._lb = (x2y_comm_lower_bound(
                self.active_x_weights(), self.active_y_weights(), self.q)
                if self.num_active_x and self.num_active_y else 0.0)
            if self.gap_drift <= self.replan_drift:
                self.stats["repairs"] += 1
                return self._finish_delta(kind, i, repair)
            self.stats["drift_replans"] += 1
        self._adopt_replan()
        return PlanDelta(
            kind=kind, input_id=i,
            touched_inputs=np.concatenate(
                [self.active_x_ids(), self.active_y_ids()]),
            dirty_rows=np.arange(self.num_reducers, dtype=np.int64),
            sub_plan=None, full_replan=True,
            num_reducers=self.num_reducers, comm_cost=self.comm_cost,
            lower_bound=self._lb, gap_drift=self.gap_drift,
            meta={"workload": "x2y", "algorithm": self.algorithm,
                  "touched_x": [int(a) for a in self.active_x_ids()],
                  "touched_y": [int(a) for a in self.active_y_ids()]})

    def _finish_delta(self, kind: str, i: int, repair: dict) -> PlanDelta:
        dirty = np.asarray(sorted(repair["dirty"]), dtype=np.int64)
        sub = None
        xs_map = {int(r): sorted(self.xbins[self.reducers[int(r)][0]])
                  for r in dirty}
        ys_map = {int(r): sorted(self.ybins[self.reducers[int(r)][1]])
                  for r in dirty}
        if len(dirty):
            xs = [xs_map[int(r)] for r in dirty]
            ys = [ys_map[int(r)] for r in dirty]
            comm = float(
                sum(self.wx[a] for row in xs for a in row)
                + sum(self.wy[a] for row in ys for a in row))
            sub = compact_x2y_plan(
                xs, ys, num_x=len(self.wx), num_y=len(self.wy),
                comm_cost=comm, algorithm=f"stream-delta:{kind}",
                max_buckets=self._pad["max_buckets"],
                pad_reducers_to=self._pad["pad_reducers_to"])
        delta = PlanDelta(
            kind=kind, input_id=i,
            touched_inputs=np.asarray(
                repair["touched_x"] + repair["touched_y"], dtype=np.int64),
            dirty_rows=dirty, sub_plan=sub, full_replan=False,
            num_reducers=self.num_reducers, comm_cost=self.comm_cost,
            lower_bound=self._lb, gap_drift=self.gap_drift,
            meta={"workload": "x2y", "algorithm": self.algorithm,
                  "touched_x": list(repair["touched_x"]),
                  "touched_y": list(repair["touched_y"])})
        if self.check:
            delta.verify_x2y(xs_map, ys_map, self.active_x_ids(),
                             self.active_y_ids())
        return delta
