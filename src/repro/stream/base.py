"""StreamPlannerBase: the shared edit-finishing driver for incremental
planners (DESIGN.md 1f).

``IncrementalPlanner`` (all-pairs) and ``IncrementalX2YPlanner``
(rectangular) used to copy-paste the ``_edited`` finishing logic — and the
copies drifted apart exactly where it mattered: the re-plan trigger.  Both
measured gap drift *relative* to the gap at the last full re-plan, so a
schema that started at a mediocre gap never re-planned no matter how bad
it got (BENCH_stream: gap 2.05x, ``drift_replans: 0``).  This base class
owns the trigger so the two planners cannot diverge again, and fixes it in
three ways:

* **Unified lower-bound recomputation.**  Every edit recomputes the
  instance bounds *first*, on every path (repair, drift re-plan, forced
  re-plan), so reported ``gap_drift`` telemetry is always measured against
  the post-edit profile.  Two bounds are tracked: the paper's theorem
  bound (``lower_bound`` — Thm 8 ``s^2/q`` for all-pairs, Thm 25 for X2Y;
  what conformance checks ship against) and an *achievable* reference
  (``_lb_ach`` — the strategy-level bound of the maintained schema family,
  e.g. Thm 9 for binpack-k).  The theorem bound can sit a factor ~2 above
  what any covering schema can reach, which is how the old relative
  trigger died; triggers use the achievable gap.

* **Absolute ``max_gap`` ceiling.**  Alongside the relative
  ``replan_drift`` check, a re-plan fires whenever the achievable gap
  exceeds ``max(max_gap, base * 1.05)`` — the ``base * 1.05`` floor keeps
  a profile whose *fresh* plan already sits above ``max_gap`` from
  re-planning on every edit.

* **Background local repacking.**  When the achievable gap exceeds the
  soft ``repack_gap`` threshold (but not the re-plan ceiling), the planner
  migrates inputs out of underfilled bins and prunes reducers whose member
  bins died — shaving gap with pure planning-state surgery, no recompute
  (pair values are plan-independent).  Runs only on edits with an empty
  dirty set (delete / in-place reweight), so no outstanding delta
  references re-compacted reducer ids.

Re-plans are **double-buffered**: pair values do not depend on the plan
that produced them, so adopting a fresh schema never requires rebuilding
the served matrix — the re-plan delta is a compact *patch* (the edited
input's rows in the new plan) with ``full_replan=False``, and the
executor's 3.8s cold build is paid exactly once, at load time.  With
``background=True`` the re-plan itself moves off the edit path: a daemon
thread plans the captured profile while edits keep repairing the old
schema, and the finished plan is swapped in atomically on a later edit
(deletes since capture are filtered out of its bins, inserts are replayed
through the repair rules, reweights are re-validated against bin
capacity; any violation falls back to a synchronous re-plan).
"""

from __future__ import annotations

import threading
from typing import Optional

from repro.obs import EVENTS as _OBS_EVENTS
from repro.obs import REGISTRY as _OBS_REGISTRY

from .delta import PlanDelta

__all__ = ["StreamPlannerBase"]

_EPS = 1e-12

# a re-plan must beat the fresh plan's own achievable gap by this margin
# before the absolute ceiling may fire again — otherwise a profile whose
# best-known plan sits above max_gap would re-plan on every edit
_CEILING_MARGIN = 1.05
# same idea for the soft repack threshold
_REPACK_MARGIN = 1.02


class StreamPlannerBase:
    """Shared trigger/finishing driver for incremental stream planners.

    Subclasses implement the schema family (state, repair rules, adoption)
    and plug into the driver through these hooks:

    ``_recompute_lb()``          — set ``self._lb`` (theorem bound) and
                                   ``self._lb_ach`` (achievable reference)
                                   for the live profile.
    ``_adopt_replan()``          — synchronous full re-plan + adoption;
                                   must end with ``self._after_adopt()``.
    ``_finish_delta(kind, i, repair, extra_meta=None)``
                                 — build the repair-path PlanDelta.
    ``_patch_after_replan(kind, i)``
                                 — repair-dict describing the compact
                                   patch that re-serves the edited input
                                   under the freshly adopted plan.
    ``_repack_pass()``           — local repacking; returns
                                   ``(migrations, pruned_reducers)``.
    ``_capture_profile()``       — snapshot payload for the background
                                   planner thread.
    ``_background_plan(payload)`` — plan the captured profile (runs on the
                                   daemon thread; must not touch planner
                                   state).
    ``_swap_in(result)``         — adopt a background plan onto the
                                   *current* profile; False when the plan
                                   went stale (caller re-plans sync).
    """

    def __init__(self, *, replan_drift: float = 1.5,
                 max_gap: Optional[float] = 2.0,
                 repack_gap: Optional[float] = None,
                 background: bool = False, check: bool = True):
        assert replan_drift >= 1.0, replan_drift
        assert max_gap is None or max_gap >= 1.0, max_gap
        assert repack_gap is None or repack_gap >= 1.0, repack_gap
        self.replan_drift = float(replan_drift)
        self.max_gap = None if max_gap is None else float(max_gap)
        self.repack_gap = None if repack_gap is None else float(repack_gap)
        self.background = bool(background)
        self.check = check
        self._bg: Optional[dict] = None
        self._lb = 0.0
        self._lb_ach = 0.0
        self._base_gap = 1.0
        self._base_ach = 1.0
        self.stats = {
            "edits": 0, "repairs": 0, "replans": 0, "drift_replans": 0,
            "opened_bins": 0, "opened_reducers": 0, "dead_bins": 0,
            "repacks": 0, "migrations": 0, "pruned_reducers": 0,
            "swaps": 0,
        }

    # ------------------------------------------------------------ gap state
    @property
    def lower_bound(self) -> float:
        """The paper's theorem lower bound for the live profile (what
        conformance ships against)."""
        return self._lb

    @property
    def optimality_gap(self) -> float:
        return self.comm_cost / self._lb if self._lb > 0 else 1.0

    @property
    def achievable_gap(self) -> float:
        """Maintained cost over the *achievable* reference bound — the
        strategy-level bound of the schema family actually in force.  The
        theorem bound can be ~2x loose (binpack-k2 vs Thm 8), which is
        what killed the old relative-only trigger; ceilings use this."""
        return self.comm_cost / self._lb_ach if self._lb_ach > 0 else 1.0

    @property
    def gap_drift(self) -> float:
        """Current gap over the gap at the last full re-plan (>= ~1)."""
        return self.optimality_gap / max(self._base_gap, _EPS)

    def _gap_ceiling(self) -> float:
        if self.max_gap is None:
            return float("inf")
        return max(self.max_gap, self._base_ach * _CEILING_MARGIN)

    def _repack_threshold(self) -> float:
        if self.repack_gap is None:
            return float("inf")
        return max(self.repack_gap, self._base_ach * _REPACK_MARGIN)

    def _bump(self, key: str, by: int = 1) -> None:
        """Increment a planner stat and mirror it into the obs registry as
        ``stream.<key>{planner=<class>}``."""
        self.stats[key] = self.stats.get(key, 0) + by
        _OBS_REGISTRY.counter(f"stream.{key}",
                              planner=type(self).__name__).inc(by)

    def _after_adopt(self) -> None:
        """Re-anchor the drift baselines after any adoption (sync re-plan
        or background swap) — called by subclasses at the end of
        ``_adopt_replan`` and by the swap path."""
        self._base_gap = self.optimality_gap
        self._base_ach = self.achievable_gap
        self._plan = None
        self._bump("replans")

    # ----------------------------------------------------- finishing driver
    def _edited(self, kind: str, i: int,
                repair: Optional[dict]) -> PlanDelta:
        self._bump("edits")
        self._plan = None
        # a finished background re-plan lands *before* this edit is
        # served: the edit's repair was applied to the superseded schema,
        # so its delta becomes the swap patch for the new one
        if repair is not None and self._bg is not None \
                and self._bg["done"].is_set():
            if self._finish_background():
                self._recompute_lb()
                return self._replan_patch(kind, i, swap=True)
        self._recompute_lb()
        drift, ach = self.gap_drift, self.achievable_gap
        trigger = {"gap_drift": drift, "achievable_gap": ach}
        if repair is None:
            # forced: only a full re-plan can absorb this edit (opaque
            # schema, over-capacity weight, one-sided bootstrap)
            self._discard_background()
            _OBS_EVENTS.emit("forced_replan", planner=type(self).__name__,
                             edit=kind, input=int(i), **trigger)
            self._adopt_replan()
            return self._replan_patch(kind, i, forced=True,
                                      trigger=trigger)
        if drift > self.replan_drift or ach > self._gap_ceiling():
            if not self.background:
                self._bump("drift_replans")
                _OBS_EVENTS.emit("drift_replan",
                                 planner=type(self).__name__, mode="sync",
                                 edit=kind, input=int(i), **trigger)
                self._adopt_replan()
                return self._replan_patch(kind, i, trigger=trigger)
            if self._start_background():
                self._bump("drift_replans")
                _OBS_EVENTS.emit("drift_replan",
                                 planner=type(self).__name__,
                                 mode="background", edit=kind,
                                 input=int(i), **trigger)
            # keep serving repairs off the old schema while the re-plan
            # builds off to the side
            self._bump("repairs")
            return self._finish_delta(kind, i, repair,
                                      extra_meta={"replan_pending": True})
        if self.repack_gap is not None and self._bg is None \
                and not repair.get("dirty") \
                and ach > self._repack_threshold():
            moved, pruned = self._repack_pass()
            if moved or pruned:
                self._bump("repacks")
                self._bump("migrations", moved)
                self._bump("pruned_reducers", pruned)
                _OBS_EVENTS.emit("soft_repack",
                                 planner=type(self).__name__,
                                 migrations=int(moved),
                                 pruned_reducers=int(pruned),
                                 achievable_gap=float(ach))
        self._bump("repairs")
        return self._finish_delta(kind, i, repair)

    def _replan_patch(self, kind: str, i: int, *, swap: bool = False,
                      forced: bool = False,
                      trigger: Optional[dict] = None) -> PlanDelta:
        """The re-plan delta as a compact patch, not a cold rebuild: pair
        values are plan-independent, so the served matrix only needs the
        edited input's rows under the new plan (``full_replan`` stays
        False and the executor's cold build is first-build-only)."""
        patch = self._patch_after_replan(kind, i)
        meta = {"replan": True, "swap": bool(swap), "forced": bool(forced)}
        if trigger is not None:
            meta["trigger"] = {k: float(v) for k, v in trigger.items()}
        return self._finish_delta(kind, i, patch, extra_meta=meta)

    # ------------------------------------------------- background re-plan
    def _start_background(self) -> bool:
        """Kick off a daemon-thread re-plan of the captured live profile;
        False when one is already in flight."""
        if self._bg is not None:
            return False
        payload = self._capture_profile()
        box = {"done": threading.Event(), "result": None, "error": None}

        def work():
            try:
                box["result"] = self._background_plan(payload)
            except Exception as e:      # noqa: BLE001 — stale plans are
                box["error"] = e        # discarded, never raised late
            finally:
                box["done"].set()

        t = threading.Thread(target=work, daemon=True,
                             name="stream-replan")
        box["thread"] = t
        self._bg = box
        t.start()
        return True

    def _discard_background(self) -> None:
        """Drop any in-flight background plan (its thread finishes into a
        dead box); the caller is about to re-plan synchronously."""
        self._bg = None

    def _finish_background(self) -> bool:
        """Land the background plan: swap-adopt it onto the current
        profile (falling back to a synchronous re-plan if it went stale).
        Returns False — with planner state untouched — when the thread
        errored (e.g. the captured profile raced infeasible)."""
        box, self._bg = self._bg, None
        box["thread"].join()
        if box["error"] is not None or box["result"] is None:
            return False
        stale = not self._swap_in(box["result"])
        if stale:
            # the plan went stale (interleaved edits broke capacity or
            # placement): rebuild synchronously from the live profile
            self._adopt_replan()
        self._bump("swaps")
        _OBS_EVENTS.emit("background_swap", planner=type(self).__name__,
                         stale=stale)
        return True

    def flush_replan(self) -> bool:
        """Block until any in-flight background re-plan lands.  Planning
        state only: served pair values are plan-independent, so the cached
        matrix stays correct across the swap.  Returns True if a schema
        was adopted."""
        if self._bg is None:
            return False
        if not self._finish_background():
            return False
        self._recompute_lb()
        self._plan = None
        return True
