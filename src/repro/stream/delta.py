"""PlanDelta: the artifact one streaming edit produces (DESIGN.md 1f).

A delta names exactly what changed between two consecutive maintained
mapping schemas:

  * ``touched_inputs`` — input ids whose row/column of the served (m, m)
    pair matrix must be re-patched (the edited input itself; empty for a
    pure weight change, which moves planning state but no feature rows);
  * ``dirty_rows``     — reducer ids (in the post-edit plan) whose Gram
    blocks must be recomputed on device;
  * ``sub_plan``       — a compact :class:`~repro.mapreduce.engine.
    ReducerPlan` holding only the dirty reducers (idx/mask reference the
    *full* input table, so the streaming executor can gather straight from
    the live table), padded to power-of-two row counts / bucket widths so
    the jit cache sees a bounded shape set across an edit stream.

``verify`` is the coverage-restoration proof obligation: after an insert,
every pair involving the new input must be covered by the dirty reducers
alone (the new input exists nowhere else); after a delete or reweight no
new coverage is required, and a full re-plan re-covers everything by
construction.  The incremental planner calls it after every edit when
``check=True``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.mapreduce.engine import (
    ReducerBucket,
    ReducerPlan,
    _build_buckets,
    build_x2y_plan_arrays,
)

__all__ = ["PlanDelta", "compact_plan", "compact_x2y_plan"]


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _pad_bucket_rows(b: ReducerBucket,
                     pad_reducers_to: int = 1) -> ReducerBucket:
    """Pad a bucket's row count to the next power of two (all-masked
    padding rows, row id -1) so a long edit stream pushes a *bounded* set
    of (rows, width) shapes through the engine's jit cache instead of
    retracing on every distinct dirty-reducer count; then round up to a
    multiple of ``pad_reducers_to`` (the mesh device count) so the row
    axis stays divisible under sharded execution."""
    Rb = b.idx.shape[0]
    R = _pow2(Rb)
    R = -(-R // pad_reducers_to) * pad_reducers_to
    if R == Rb:
        return b
    pad = R - Rb
    return ReducerBucket(
        width=b.width,
        rows=np.concatenate([b.rows, np.full(pad, -1, np.int64)]),
        idx=np.concatenate([b.idx, np.zeros((pad, b.width), np.int32)]),
        mask=np.concatenate([b.mask, np.zeros((pad, b.width), bool)]))


def compact_plan(expanded: list[list[int]], *, comm_cost: float = 0.0,
                 algorithm: str = "stream-delta", max_buckets: int = 8,
                 pad_reducers_to: int = 1) -> ReducerPlan:
    """Compact ReducerPlan over an explicit reducer subset.

    ``expanded[r]`` lists *full-table* input ids, so the resulting plan
    gathers from the live (possibly tombstone-holding) table.  Capacity
    buckets use power-of-two widths (``compute_buckets``) and power-of-two
    row counts (``_pad_bucket_rows``), bounding the distinct program
    shapes across an edit stream; ``pad_reducers_to`` additionally rounds
    bucket rows to a device-count multiple for mesh execution.
    """
    R0 = len(expanded)
    L0 = max((len(ids) for ids in expanded), default=1)
    idx = np.zeros((max(R0, 1), L0), dtype=np.int32)
    mask = np.zeros((max(R0, 1), L0), dtype=bool)
    for r, ids in enumerate(expanded):
        idx[r, : len(ids)] = ids
        mask[r, : len(ids)] = True
    buckets = tuple(
        _pad_bucket_rows(b, pad_reducers_to)
        for b in _build_buckets(expanded, pad_slots_to=1, pad_reducers_to=1,
                                max_buckets=max_buckets))
    return ReducerPlan(
        idx=idx, mask=mask, num_reducers=R0, comm_cost=float(comm_cost),
        max_inputs=L0, algorithm=algorithm, lower_bound=None,
        buckets=buckets)


def _pad_rect_bucket_rows(b: ReducerBucket,
                          pad_reducers_to: int = 1) -> ReducerBucket:
    """Rectangular analogue of :func:`_pad_bucket_rows`: pad both sides'
    row counts (they share the row axis) to the next power of two, then to
    a device-count multiple."""
    Rb = b.idx.shape[0]
    R = _pow2(Rb)
    R = -(-R // pad_reducers_to) * pad_reducers_to
    if R == Rb:
        return b
    pad = R - Rb
    return ReducerBucket(
        width=b.width,
        rows=np.concatenate([b.rows, np.full(pad, -1, np.int64)]),
        idx=np.concatenate([b.idx, np.zeros((pad, b.width), np.int32)]),
        mask=np.concatenate([b.mask, np.zeros((pad, b.width), bool)]),
        ywidth=b.ywidth,
        yidx=np.concatenate([b.yidx, np.zeros((pad, b.ywidth), np.int32)]),
        ymask=np.concatenate([b.ymask, np.zeros((pad, b.ywidth), bool)]))


def compact_x2y_plan(xs: list[list[int]], ys: list[list[int]], *,
                     num_x: int, num_y: int, comm_cost: float = 0.0,
                     algorithm: str = "stream-delta-x2y",
                     max_buckets: int = 8,
                     pad_reducers_to: int = 1) -> ReducerPlan:
    """Compact rectangular ReducerPlan over an explicit dirty-reducer
    subset: ``xs[r]`` / ``ys[r]`` list *full-table* X and Y row ids, so
    the streaming executor gathers straight from the live tables.  Bucket
    rows are padded to power-of-two counts (:func:`_pad_rect_bucket_rows`)
    for the same bounded-shape jit-cache contract as :func:`compact_plan`.
    """
    plan = build_x2y_plan_arrays(
        xs, ys, num_x=num_x, num_y=num_y, comm_cost=comm_cost,
        algorithm=algorithm, pad_reducers_to=1, pad_slots_to=1,
        max_buckets=max_buckets)
    buckets = tuple(_pad_rect_bucket_rows(b, pad_reducers_to)
                    for b in plan.buckets)
    return dataclasses.replace(plan, buckets=buckets)


@dataclasses.dataclass(frozen=True)
class PlanDelta:
    """What one edit changed: dirty reducers + the re-shuffle map to run.

    kind            — 'init' | 'insert' | 'delete' | 'reweight' | 'replan'.
    input_id        — the edited input's full-table id (-1 for init).
    touched_inputs  — ids whose (m, m) row/col the executor must re-patch.
    dirty_rows      — post-edit reducer ids to recompute (ascending).
    sub_plan        — compact plan over exactly ``dirty_rows`` (None when
                      nothing recomputes, or on a full re-plan where the
                      full plan is the program).
    full_replan     — the repair path gave up (gap drift / infeasible
                      repair / opaque schema): every reducer is dirty.
    num_reducers    — reducer count after the edit (recompute-fraction
                      denominator).
    comm_cost / lower_bound — post-edit schema communication cost and the
                      instance's replication-rate lower bound.
    gap_drift       — optimality gap now / gap at the last full re-plan
                      (the planner re-plans when this crosses its
                      threshold).
    """

    kind: str
    input_id: int
    touched_inputs: np.ndarray
    dirty_rows: np.ndarray
    sub_plan: Optional[ReducerPlan]
    full_replan: bool
    num_reducers: int
    comm_cost: float
    lower_bound: float
    gap_drift: float
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def recompute_fraction(self) -> float:
        """Dirty reducers over total reducers (1.0 on a full re-plan)."""
        if self.full_replan:
            return 1.0
        return len(self.dirty_rows) / max(self.num_reducers, 1)

    @property
    def optimality_gap(self) -> Optional[float]:
        if self.lower_bound <= 0.0:
            return None
        return self.comm_cost / self.lower_bound

    def delta_comm_rows(self) -> float:
        """Weighted rows this edit actually ships — the streaming analogue
        of ``MappingSchema.communication_cost``: the dirty reducers' loads
        on a repair, the whole schema's cost on a full re-plan (that edit
        really pays the full re-shuffle).  Compare against ``comm_cost``
        (what a full re-shuffle always ships)."""
        if self.full_replan:
            return float(self.comm_cost)
        return float(self.sub_plan.comm_cost) if self.sub_plan is not None \
            else 0.0

    # ----------------------------------------------------- proof obligation
    def verify(self, expanded, active_ids: Sequence[int]) -> None:
        """Assert coverage of every affected pair is restored.

        ``expanded`` maps post-edit reducer id -> live input ids — a full
        list, or (for inserts) any mapping that covers the dirty rows;
        ``active_ids`` are the live inputs.  Insert: every (new, y) pair
        must meet inside the *dirty* reducers alone — the new input exists
        in no clean reducer, so dirty coverage is the whole proof.
        Reweight moves keep x rows unchanged but must still leave the
        moved input covered against everything (checked over all
        reducers).  Delete needs no new coverage.  Full re-plans are
        covered by the planner's schema construction (conformance-tested
        separately)."""
        if self.full_replan or self.kind in ("init", "delete"):
            return
        if self.kind == "insert":
            new = int(self.input_id)
            partners: set[int] = set()
            for r in self.dirty_rows:
                ids = expanded[int(r)]
                if new in ids:
                    partners.update(ids)
            missing = set(int(a) for a in active_ids) - partners - {new}
            assert not missing, (
                f"insert({new}): dirty reducers leave {len(missing)} pairs "
                f"uncovered, e.g. {sorted(missing)[:5]}")
            return
        if self.kind == "reweight":
            i = int(self.input_id)
            rows = (expanded.values() if isinstance(expanded, dict)
                    else expanded)
            partners = set()
            for ids in rows:
                if i in ids:
                    partners.update(ids)
            missing = set(int(a) for a in active_ids) - partners - {i}
            assert not missing, (
                f"reweight({i}): {len(missing)} pairs uncovered after the "
                f"move, e.g. {sorted(missing)[:5]}")

    def verify_x2y(self, x_expanded, y_expanded,
                   active_x: Sequence[int],
                   active_y: Sequence[int]) -> None:
        """Rectangular coverage proof (X2Y deltas from
        ``IncrementalX2YPlanner``).

        ``x_expanded`` / ``y_expanded`` map post-edit reducer id -> live
        X-table / Y-table ids — full lists, or any mapping covering the
        dirty rows.  Insert on either side: every cross pair involving the
        new input must meet inside the *dirty* reducers alone (the new
        input exists in no clean reducer).  Deletes need no new coverage;
        full re-plans are covered by the planner's schema construction."""
        if self.full_replan or self.kind in ("init", "delete_x",
                                             "delete_y"):
            return
        new = int(self.input_id)
        if self.kind == "insert_x":
            partners: set[int] = set()
            for r in self.dirty_rows:
                if new in x_expanded[int(r)]:
                    partners.update(int(j) for j in y_expanded[int(r)])
            missing = set(int(j) for j in active_y) - partners
            assert not missing, (
                f"insert_x({new}): dirty reducers leave {len(missing)} "
                f"cross pairs uncovered, e.g. {sorted(missing)[:5]}")
        elif self.kind == "insert_y":
            partners = set()
            for r in self.dirty_rows:
                if new in y_expanded[int(r)]:
                    partners.update(int(j) for j in x_expanded[int(r)])
            missing = set(int(j) for j in active_x) - partners
            assert not missing, (
                f"insert_y({new}): dirty reducers leave {len(missing)} "
                f"cross pairs uncovered, e.g. {sorted(missing)[:5]}")
