"""Streaming subsystem: incremental mapping-schema maintenance.

The planners in ``repro.core`` are pure functions of a weight profile; the
executors in ``repro.mapreduce`` run the resulting static plan.  This
package makes plans *mutable serving state* (DESIGN.md 1f):

``IncrementalPlanner``
    ``insert`` / ``delete`` / ``reweight`` maintain a live mapping schema
    by localized bin repair (residual packing into existing slack, new
    reducers only when capacity q forces them), with a tracked
    optimality-gap drift threshold that triggers an amortized full re-plan
    through ``repro.core.PLAN_CACHE``.
``PlanDelta``
    The per-edit artifact: dirty reducers, the compact re-shuffle
    sub-plan, the touched matrix rows, and the coverage-restoration proof
    (``verify``).
``StreamingExecutor``
    The fifth registry executor (``executor="streaming"``): keeps the
    assembled (m, m) pair matrix cached, recomputes only dirty reducers
    through the fused/bucketed substrate, and patches the matrix with a
    delta scatter instead of rebuilding it.
``IncrementalX2YPlanner``
    The rectangular (DESIGN.md 1g) analogue: maintains a bipartite X2Y
    schema under ``insert_x`` / ``insert_y`` / ``delete_x`` /
    ``delete_y`` (X bins at capacity ``b``, Y bins at ``q - b``; a new
    bin pairs against every live other-side bin), emitting X2Y deltas
    whose ``verify_x2y`` coverage proofs gate the (mx, my) matrix
    patches of ``StreamingExecutor.apply_delta_x2y``.

Importing this package registers the executor; ``repro.mapreduce.
get_executor("streaming")`` imports it lazily, so the rest of the engine
never pays for the subsystem unless it is used.
"""

from repro.mapreduce.executors import register_executor

from .delta import PlanDelta, compact_plan, compact_x2y_plan
from .executor import StreamingExecutor
from .incremental import IncrementalPlanner
from .x2y import IncrementalX2YPlanner

register_executor(StreamingExecutor())

__all__ = ["IncrementalPlanner", "IncrementalX2YPlanner", "PlanDelta",
           "StreamingExecutor", "compact_plan", "compact_x2y_plan"]
