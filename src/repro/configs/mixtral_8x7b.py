"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    num_experts=8, experts_per_token=2, moe_period=1,
    window=4096, rope_theta=1e6,
    subquadratic=True,    # SWA: decode touches a 4096-token window
    notes="SWA every layer; MoE every layer",
)
