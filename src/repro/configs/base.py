"""Architecture configuration + registry.

One ``ArchConfig`` per assigned architecture (src/repro/configs/<id>.py) with
the exact published dimensions; ``reduced()`` derives the CPU smoke-test
variant (same family/pattern, tiny dims).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = ["ArchConfig", "register", "get_config", "list_archs", "SHAPES"]


# assigned input-shape grid (LM family): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1          # MoE FFN every `moe_period`-th layer
    # attention pattern
    window: int = 0              # sliding window for 'window' layers
    local_global_period: int = 0  # N -> every Nth layer full, rest windowed
    # SSM / hybrid
    ssm_state: int = 0
    attn_period: int = 0         # N -> layer i%N==0 is attention, rest mamba
    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 0
    # modality frontend (stub: precomputed embeddings)
    frontend: str = "none"       # none|audio|vision
    num_frontend_tokens: int = 0
    mlp_variant: str = "swiglu"  # 'swiglu' (3 mats) | 'gelu' (2 mats)
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    subquadratic: bool = False   # eligible for long_500k
    notes: str = ""

    # ------------------------------------------------------------- derived
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def padded_vocab(self, multiple: int = 512) -> int:
        """Vocab rounded up so the embedding shards evenly over any TP axis
        up to `multiple` (MaxText-style padding; extra logits are never
        targets)."""
        return -(-self.vocab_size // multiple) * multiple

    def mamba_meta(self) -> dict:
        d_inner = 2 * self.d_model
        p = 64
        return {"d_inner": d_inner, "H": d_inner // p,
                "N": self.ssm_state, "P": p}

    def layer_kinds(self) -> list[dict]:
        """Per-layer {'mixer','window','ffn','cross'} honoring the periods."""
        out = []
        for i in range(self.num_layers):
            mixer = "attn"
            if self.ssm_state and (self.attn_period == 0
                                   or i % self.attn_period != 0):
                mixer = "mamba"
            win = self.window
            if self.local_global_period:
                # every Nth layer is global, the rest sliding-window
                win = 0 if (i % self.local_global_period ==
                            self.local_global_period - 1) else self.window
            ffn = "none" if self.d_ff == 0 else "dense"
            if self.num_experts and (i % self.moe_period ==
                                     self.moe_period - 1):
                ffn = "moe"
            out.append({"mixer": mixer, "window": win, "ffn": ffn,
                        "cross": self.encoder_layers > 0})
        return out

    # --------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Total parameters (embeddings counted once — tied)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        H, Hkv, D = self.num_heads, self.num_kv_heads, self.head_dim_()
        total = V * d
        for kind in self.layer_kinds():
            if kind["mixer"] == "attn":
                total += d * (H + 2 * Hkv) * D + H * D * d
            else:
                m = self.mamba_meta()
                di, N, Hm = m["d_inner"], m["N"], m["H"]
                total += d * (2 * di + 2 * N + Hm) + 4 * (di + 2 * N) \
                    + di * d + 3 * Hm + di
            if kind["cross"]:
                total += d * (H + 2 * Hkv) * D + H * D * d
            nmats = 2 if self.mlp_variant == "gelu" else 3
            if kind["ffn"] == "dense":
                total += nmats * d * ff
            elif kind["ffn"] == "moe":
                total += d * self.num_experts \
                    + nmats * d * ff * self.num_experts
        if self.encoder_layers:
            total += self.encoder_layers * (
                d * (H + 2 * Hkv) * D + H * D * d
                + (2 if self.mlp_variant == "gelu" else 3) * d * ff)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_equiv = dataclasses.replace(self, num_experts=0)
        inactive = 0
        nmats = 2 if self.mlp_variant == "gelu" else 3
        for kind in self.layer_kinds():
            if kind["ffn"] == "moe":
                inactive += nmats * d * ff * (self.num_experts
                                              - self.experts_per_token)
                inactive -= d * self.num_experts  # router is extra, keep
        return self.param_count() - inactive

    # -------------------------------------------------------------- reduced
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = max(1, self.attn_period, self.local_global_period,
                  self.moe_period if self.num_experts else 1)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=max(2 * pat, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            window=min(self.window, 8) if self.window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_layers else 0,
            num_frontend_tokens=8 if self.num_frontend_tokens else 0,
        )


_REGISTRY: dict[str, str] = {
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
    "granite-34b": "repro.configs.granite_34b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "stablelm-3b": "repro.configs.stablelm_3b",
}


def register(name: str, module: str) -> None:
    _REGISTRY[name] = module


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    mod = importlib.import_module(_REGISTRY[name])
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(_REGISTRY)
