"""Granite 34B code model — deep-narrow llama arch with MQA (kv=1).

[arXiv:2405.04324; hf] 88L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    head_dim=128, d_ff=24576, vocab_size=49152,
    mlp_variant="gelu",
    subquadratic=False,
    notes="MQA: single KV head is replicated across the TP axis",
)
