"""Mamba-2 370M — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified] 48L d_model=1024 d_ff=0 vocab=50280,
ssm_state=128.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=50280, ssm_state=128, attn_period=0,
    subquadratic=True,
    notes="pure SSM: O(1)-state decode, runs long_500k",
)
