"""Gemma-3 4B — 5:1 local:global attention, 128k context, huge vocab.

[hf:google/gemma-3-*; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144; sliding window 1024 on local layers.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    head_dim=256, d_ff=10240, vocab_size=262144,
    window=1024, local_global_period=6, rope_theta=1e6,
    subquadratic=True,   # 5/6 of layers are 1k-window
    notes="5 local (w=1024) : 1 global repeating; 34 = 5 blocks + 4 tail",
)
