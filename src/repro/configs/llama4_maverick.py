"""Llama-4 Maverick 400B-A17B — 128-expert top-1 MoE, alternating dense/MoE.

[hf:meta-llama/Llama-4-*; unverified] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 on every other layer (early fusion).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_period=2,
    rope_theta=5e5,
    subquadratic=False,
    notes="MoE on every 2nd layer (interleaved dense/MoE)",
)
