"""Whisper large-v3 — encoder-decoder; conv audio frontend is a STUB
(input_specs supplies precomputed frame embeddings (B, 1500, d)).

[arXiv:2212.04356; unverified] 32L d_model=1280 20H d_ff=5120 vocab=51866.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    head_dim=64, d_ff=5120, vocab_size=51866,
    encoder_layers=32, encoder_seq=1500, frontend="audio",
    mlp_variant="gelu",
    subquadratic=False,
    notes="enc-dec; RoPE substituted for learned positions (documented)",
)
