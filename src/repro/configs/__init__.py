"""Architecture configs: one module per assigned arch + registry."""
from .base import ArchConfig, SHAPES, get_config, list_archs, register

__all__ = ["ArchConfig", "SHAPES", "get_config", "list_archs", "register"]
