"""StableLM 3B — dense MHA model.

[hf:stabilityai/stablelm-*; unverified] 32L d_model=2560 32H (kv=32)
d_ff=6912 vocab=50304.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    head_dim=80, d_ff=6912, vocab_size=50304,
    subquadratic=False,
)
