"""StableLM-2 1.6B — small dense MHA model.

[hf:stabilityai/stablelm-2-1_6b; unverified] 24L d_model=2048 32H (kv=32)
d_ff=5632 vocab=100352.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=5632, vocab_size=100352,
    subquadratic=False,
)
