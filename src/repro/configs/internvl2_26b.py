"""InternVL2-26B — InternViT frontend STUB + InternLM2 backbone.

[arXiv:2404.16821; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553; input_specs supplies 256 precomputed patch embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=92553,
    frontend="vision", num_frontend_tokens=256,
    subquadratic=False,
    notes="vision tokens prepended to the text sequence",
)
