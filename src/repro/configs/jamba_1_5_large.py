"""Jamba-1.5 Large 398B — hybrid Mamba+attention 1:7, MoE 16e top-2.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
MoE 16 experts top-2 on every other layer; attention on layer i%8==0.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=65536,
    num_experts=16, experts_per_token=2, moe_period=2,
    ssm_state=128, attn_period=8,
    subquadratic=True,
    notes="1 attention : 7 mamba per 8-layer block; MoE every 2nd FFN",
)
