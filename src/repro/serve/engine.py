"""Wave-based batched serving on top of LMModel.decode_step.

A wave admits up to B requests; all slots decode in lock-step sharing the
cache write position (slot s's token at tick t lands at position t of its
own cache lane — correct because every lane advances together).  Slots whose
request finishes early idle (their outputs are ignored) until the wave
drains, then the next wave starts with a fresh cache.

True continuous batching (mid-flight admission) requires per-slot cache
write indices + per-slot attention-start masks; that variant is documented
as future work in DESIGN.md — wave batching is what the shared scalar
`cache['len']` supports exactly, and it is what examples/serve_lm.py and
the tests exercise.

``PairwiseService`` is the paper-workload serving facade: all-pairs /
some-pairs similarity queries planned through the registry planner (plans
memoized by weight profile in ``PLAN_CACHE``) and executed on the
skew-aware bucketed shuffle executor or the fused gather+Gram megakernel
path (``executor='fused'``), with per-request plan provenance, plan-cache
hit flags, and fused/jit-cache telemetry for dashboards.  ``x2y`` serves
the rectangular bipartite workload (paper Section 10) through the same
executor protocol's ``run_x2y``.

With ``executor='streaming'`` the service additionally serves a *live*
table: ``load_table`` plans once through ``repro.stream.
IncrementalPlanner`` and caches the pair matrix; ``add_input`` /
``remove_input`` / ``update_weight`` repair the maintained schema locally
and patch the matrix through the streaming executor, reporting
recompute-fraction, dirty-reducer, and gap-drift telemetry per edit
(DESIGN.md "streaming maintenance").
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import LEDGER as _LEDGER
from repro.obs import REGISTRY as _OBS_REGISTRY
from repro.obs import span as _obs_span

__all__ = ["Request", "BatchedServer", "PairwiseService"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Greedy-decoding server over B lock-step slots (wave batching)."""

    def __init__(self, model, params, batch_slots: int, max_len: int,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: list[Request] = []
        self._step = jax.jit(model.decode_step)
        self._wave: list[Optional[Request]] = []
        self._pending: list[list[int]] = []
        self._pos = 0
        self.cache = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _start_wave(self) -> bool:
        if not self.queue:
            return False
        self._wave = [None] * self.B
        self._pending = [[] for _ in range(self.B)]
        for s in range(self.B):
            if self.queue:
                req = self.queue.pop(0)
                self._wave[s] = req
                self._pending[s] = list(map(int, req.prompt))
        self.cache = self.model.init_cache(self.B, self.max_len)
        self._pos = 0
        return True

    def tick(self) -> int:
        """One lock-step decode; returns number of live requests."""
        live = [s for s, r in enumerate(self._wave)
                if r is not None and not r.done]
        if not live:
            if not self._start_wave():
                return 0
            live = [s for s, r in enumerate(self._wave) if r is not None]
        tokens = np.zeros((self.B, 1), np.int32)
        for s in live:
            if self._pending[s]:
                tokens[s, 0] = self._pending[s][0]
            elif self._wave[s].out:
                tokens[s, 0] = self._wave[s].out[-1]
        logits, self.cache = self._step(
            self.params, self.cache,
            {"tokens": jnp.asarray(tokens),
             "pos": jnp.asarray(self._pos, jnp.int32)})
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        self._pos += 1
        for s in live:
            req = self._wave[s]
            if self._pending[s]:
                self._pending[s].pop(0)
                if not self._pending[s]:
                    req.out.append(int(nxt[s]))   # first generated token
            else:
                req.out.append(int(nxt[s]))
            hit_eos = (self.eos_id is not None and req.out
                       and req.out[-1] == self.eos_id)
            if (len(req.out) >= req.max_new_tokens or hit_eos or
                    self._pos >= self.max_len):
                req.done = True
        return len(live)

    def run(self, max_ticks: int = 100_000) -> None:
        for _ in range(max_ticks):
            if self.tick() == 0 and not self.queue:
                return


class PairwiseService:
    """Serve all-pairs / some-pairs similarity through planned schemas.

    Each query brings its own input table (and optionally per-input sizes);
    the service plans a mapping schema via the registry planner — repeated
    weight profiles hit ``repro.core.PLAN_CACHE`` and skip planning — and
    executes it on any executor-registry entry ("dense" / "bucketed" /
    "fused" / "sharded" / "coded" / "streaming"); the default bucketed
    path keeps
    skewed profiles from paying the dense global-max padding.  The
    service holds a
    *private* executor instance (``make_executor``), so its dispatch
    telemetry is isolated from concurrent callers.  Responses carry the
    plan provenance (winning strategy, communication cost, optimality gap)
    and the bucket/shard telemetry the dashboards chart; the service
    accumulates the same numbers across requests in ``self.stats``.
    """

    def __init__(self, q: float, *, metric: str = "dot", mesh=None,
                 executor: str = "bucketed", max_buckets: int = 8,
                 use_kernel: bool = False, interpret: bool = False,
                 tenant: str = "default"):
        from repro.mapreduce import make_executor
        self.q = q
        self.metric = metric
        self.mesh = mesh
        self.executor = executor                 # registry name (telemetry)
        self.tenant = str(tenant)                # obs label: per-tenant series
        # a PRIVATE executor instance: dispatch counters are scoped to this
        # service, so concurrent services (or other callers of the default
        # registry objects) can't pollute each other's telemetry
        self._executor = make_executor(executor)
        self.max_buckets = max_buckets
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.stats = {
            "requests": 0,
            "reducers": 0,
            "dense_padded_elements": 0,
            "bucketed_padded_elements": 0,
            "plan_cache_hits": 0,
            "fused_kernel": 0,
            "fused_streamed": 0,
            "fused_fallbacks": 0,
            "edits": 0,
            "dirty_reducers": 0,
            "edit_reducers_total": 0,
            "stream_replans": 0,
            "stream_repacks": 0,
            "stream_swaps": 0,
            "block_requests": 0,
            "wall_s": 0.0,
        }
        self._planner = None                     # streaming: live planner
        self._table: Optional[np.ndarray] = None  # streaming: live rows
        self._block_table: Optional[np.ndarray] = None  # block serving
        self._block_schema = None
        self._block_sparse = None

    def executor_stats(self) -> dict:
        """This service's private executor dispatch counters."""
        return self._executor.stats()

    def reset_stats(self) -> None:
        """Zero the accumulated telemetry *coherently*: the per-request
        counters in ``self.stats`` and the private executor instance's
        dispatch counters reset together, so ratios like
        ``padding_savings`` or fused-path shares never mix epochs.  (The
        global ``PLAN_CACHE`` is shared with other callers and is already
        read as per-request deltas, so it is left untouched.)"""
        for k in self.stats:
            self.stats[k] = 0.0 if isinstance(self.stats[k], float) else 0
        self._executor.reset()

    def _snap(self):
        """Counter snapshot taken around one request (plan cache + this
        service's executor dispatch), so ``_info`` can report per-request
        deltas."""
        from repro.core import PLAN_CACHE
        ex = self._executor.stats()
        return {"plan_hits": PLAN_CACHE.hits,
                "fused_kernel": ex.get("kernel", 0),
                "fused_streamed": ex.get("streamed", 0),
                "fused_fallbacks": ex.get("fallbacks", 0),
                "ledger_seq": _LEDGER.seq}

    def _comm_info(self, snap: dict) -> Optional[dict]:
        """The comm-ledger reconciliation of the request bracketed by
        ``snap``: the record this service's executor produced since the
        snapshot (the streaming substrate may add others — the one labeled
        with our executor wins)."""
        recs = _LEDGER.records(since_seq=snap.get("ledger_seq", 0))
        mine = [r for r in recs if r.executor == self._executor.name]
        rec = mine[-1] if mine else (recs[-1] if recs else None)
        if rec is None:
            return None
        return {
            "measured_over_predicted": rec.measured_over_predicted,
            "measured_over_lb": rec.measured_over_lb,
            "gathered_bytes": rec.gathered_bytes,
            "predicted_bytes": rec.predicted_bytes,
            "assembled_bytes": rec.assembled_bytes,
            "local_bytes": rec.local_bytes,
            "residual_bytes": rec.residual_bytes,
            "replication": rec.replication,
            "anomaly": rec.anomaly,
        }

    def _info(self, plan, dt: float, snap: dict,
              workload: str = "pairs") -> dict:
        after = self._snap()
        delta = {k: after[k] - snap[k] for k in snap}
        from repro.mapreduce import jit_cache_stats
        self.stats["requests"] += 1
        self.stats["reducers"] += plan.num_reducers
        self.stats["dense_padded_elements"] += plan.dense_padded_elements
        self.stats["bucketed_padded_elements"] += \
            plan.bucketed_padded_elements
        self.stats["plan_cache_hits"] += delta["plan_hits"]
        self.stats["fused_kernel"] += delta["fused_kernel"]
        self.stats["fused_streamed"] += delta["fused_streamed"]
        self.stats["fused_fallbacks"] += delta["fused_fallbacks"]
        self.stats["wall_s"] += dt
        fused_path = None
        if self.executor == "fused":
            fused_path = ("fallback" if delta["fused_fallbacks"]
                          else "kernel" if delta["fused_kernel"]
                          else "streamed")
        info = {
            "algorithm": plan.algorithm,
            "comm_cost": plan.comm_cost,
            "lower_bound": plan.lower_bound,
            "optimality_gap": plan.optimality_gap,
            "reducers": plan.num_reducers,
            "bucket_widths": plan.bucket_widths(),
            "dense_padded_elements": plan.dense_padded_elements,
            "bucketed_padded_elements": plan.bucketed_padded_elements,
            "padding_savings": plan.padding_savings,
            "executor": self.executor,
            "plan_cache_hit": delta["plan_hits"] > 0,
            "fused_path": fused_path,
            "jit_cache": jit_cache_stats(),
            "wall_s": dt,
        }
        comm = self._comm_info(snap)
        if comm is not None:
            info["comm"] = comm
        _OBS_REGISTRY.counter("serve.requests", executor=self.executor,
                              workload=workload, tenant=self.tenant).inc()
        _OBS_REGISTRY.histogram("serve.request_seconds",
                                executor=self.executor, workload=workload,
                                tenant=self.tenant).observe(dt)
        ex_stats = self._executor.stats()
        if "num_shards" in ex_stats:             # sharded-executor telemetry
            info["sharded"] = {
                "num_shards": ex_stats["num_shards"],
                "balance_factor": ex_stats["balance_factor"],
                "fallbacks": ex_stats["fallbacks"],
            }
        if "replication" in ex_stats:            # coded-executor telemetry
            info["coded"] = {
                "replication": ex_stats["replication"],
                "local_fraction": ex_stats["local_fraction"],
                "residual_entries": ex_stats["residual_entries"],
            }
        return info

    def similarity(self, x, weights=None):
        """All-pairs similarity for one query table.  Returns (sims, info)."""
        from repro.mapreduce.allpairs import pairwise_similarity
        snap = self._snap()
        t0 = time.perf_counter()
        with _obs_span("request", workload="pairs",
                       executor=self.executor, tenant=self.tenant):
            sims, plan, _schema = pairwise_similarity(
                jnp.asarray(x), q=self.q, weights=weights,
                metric=self.metric, mesh=self.mesh,
                executor=self._executor, use_kernel=self.use_kernel,
                interpret=self.interpret)
            sims = jax.block_until_ready(sims)
        return sims, self._info(plan, time.perf_counter() - t0, snap,
                                workload="pairs")

    def some_pairs(self, x, pairs, weights=None):
        """Similarity restricted to an explicit required-pair set."""
        from repro.mapreduce.allpairs import some_pairs_similarity
        snap = self._snap()
        t0 = time.perf_counter()
        with _obs_span("request", workload="some_pairs",
                       executor=self.executor, tenant=self.tenant):
            sims, plan, _schema = some_pairs_similarity(
                jnp.asarray(x), pairs, q=self.q, weights=weights,
                metric=self.metric, mesh=self.mesh,
                executor=self._executor, use_kernel=self.use_kernel,
                interpret=self.interpret)
            sims = jax.block_until_ready(sims)
        return sims, self._info(plan, time.perf_counter() - t0, snap,
                                workload="some_pairs")

    def x2y(self, x, y, wx=None, wy=None):
        """Cross similarity of an X table against a Y table through the
        Section-10 rectangular (X2Y) schema.  Returns (sims (mx, my),
        info) with the same provenance/telemetry contract as
        :meth:`similarity` — the plan is rectangular and every registry
        executor serves it through ``run_x2y``."""
        from repro.mapreduce.allpairs import x2y_similarity
        snap = self._snap()
        t0 = time.perf_counter()
        with _obs_span("request", workload="x2y",
                       executor=self.executor, tenant=self.tenant):
            sims, plan, _schema = x2y_similarity(
                jnp.asarray(x), jnp.asarray(y), q=self.q, wx=wx, wy=wy,
                metric=self.metric, mesh=self.mesh,
                executor=self._executor, use_kernel=self.use_kernel,
                interpret=self.interpret)
            sims = jax.block_until_ready(sims)
        return sims, self._info(plan, time.perf_counter() - t0, snap,
                                workload="x2y")

    @property
    def padding_savings(self) -> float:
        """Aggregate dense/bucketed padded-element ratio across requests."""
        return (self.stats["dense_padded_elements"] /
                max(self.stats["bucketed_padded_elements"], 1))

    # --------------------------------------------------------- block serving
    def load_block_table(self, x, weights=None, *, c=None):
        """Adopt ``x`` for block-addressed serving (any executor).

        Plans a hierarchical schema (``plan_a2a_hierarchical``: the flat
        registry planner at small m, two-level super-input packing beyond)
        and lowers it to a CSR sparse plan — O(m + assignments) host
        state, never the (m, m) matrix — so the table can be orders of
        magnitude larger than ``similarity`` allows.  Returns an info dict
        with the plan provenance, including the composed optimality-gap
        ledger (``hierarchy``) when the two-level path ran.  Serve blocks
        with :meth:`block`."""
        from repro.core import plan_a2a_hierarchical
        from repro.mapreduce.allpairs import _sparse_plan_for
        t0 = time.perf_counter()
        self._block_table = np.asarray(x, dtype=np.float32)
        m = self._block_table.shape[0]
        w = np.full(m, 1.0) if weights is None \
            else np.asarray(weights, dtype=np.float64)
        self._block_schema = plan_a2a_hierarchical(w, self.q, c=c)
        self._block_sparse = _sparse_plan_for(self._block_schema)
        dt = time.perf_counter() - t0
        self.stats["wall_s"] += dt
        sp = self._block_sparse
        return {
            "executor": self.executor,
            "algorithm": sp.algorithm,
            "m": m,
            "reducers": sp.num_reducers,
            "bins": sp.num_bins,
            "host_entries": sp.host_entries,
            "comm_cost": sp.comm_cost,
            "lower_bound": sp.lower_bound,
            "optimality_gap": sp.optimality_gap,
            "hierarchy": self._block_schema.meta.get("hierarchy"),
            "wall_s": dt,
        }

    def block(self, i0: int, i1: int, j0: int, j1: int):
        """Serve one ``[i0:i1) x [j0:j1)`` sub-block of the pair matrix
        through this service's executor (``Executor.run_block``) — only
        the reducers covering the block run, nothing O(m^2) is built.
        Returns ``(block, info)``."""
        from repro.mapreduce.allpairs import _block_fn_x2y
        assert getattr(self, "_block_table", None) is not None, \
            "call load_block_table() first"
        t0 = time.perf_counter()
        with _obs_span("request", workload="block",
                       executor=self.executor, tenant=self.tenant):
            blk = self._executor.run_block(
                jnp.asarray(self._block_table), self._block_sparse,
                _block_fn_x2y(self.metric), int(i0), int(i1), int(j0),
                int(j1), mesh=self.mesh, use_kernel=self.use_kernel,
                interpret=self.interpret)
            blk = jax.block_until_ready(blk)
        dt = time.perf_counter() - t0
        self.stats["block_requests"] += 1
        self.stats["wall_s"] += dt
        _OBS_REGISTRY.counter(
            "serve.requests", executor=self.executor, workload="block",
            tenant=self.tenant).inc()
        _OBS_REGISTRY.histogram(
            "serve.block_seconds", executor=self.executor,
            tenant=self.tenant).observe(dt)
        return blk, {
            "executor": self.executor,
            "block": (int(i0), int(i1), int(j0), int(j1)),
            "block_calls": self._executor.stats().get("block_calls", 0),
            "wall_s": dt,
        }

    # ------------------------------------------------------------- streaming
    def _reducer_fn(self):
        from repro.mapreduce.allpairs import _block_fn
        return _block_fn(self.metric, self.use_kernel)

    def _require_streaming(self):
        from repro.stream import StreamingExecutor
        assert isinstance(self._executor, StreamingExecutor), (
            f"live-table edits need executor='streaming' "
            f"(this service runs {self.executor!r})")
        return self._executor

    def load_table(self, x, weights=None, *, replan_drift: float = 1.5,
                   max_gap: Optional[float] = 2.0,
                   repack_gap: Optional[float] = None,
                   background: bool = False, warmup: bool = True):
        """Adopt ``x`` as the live table (streaming executor only).

        Plans the initial schema through ``repro.stream.
        IncrementalPlanner``, cold-builds the pair matrix on the fused/
        bucketed substrate, pre-compiles the bounded delta-shape set
        (``warmup=True`` — the first edit then hits a warm jit cache
        instead of a compile storm), and returns ``(sims, info)``.
        Subsequent ``add_input`` / ``remove_input`` / ``update_weight``
        calls edit this table in place; ``max_gap`` / ``repack_gap`` /
        ``background`` tune the planner's re-plan ceiling, soft repack
        threshold, and double-buffered re-plan (see
        ``repro.stream.StreamPlannerBase``)."""
        from repro.stream import IncrementalPlanner
        ex = self._require_streaming()
        self._table = np.asarray(x, dtype=np.float32)
        m = self._table.shape[0]
        w = np.full(m, 1.0) if weights is None \
            else np.asarray(weights, dtype=np.float64)
        t0 = time.perf_counter()
        self._planner = IncrementalPlanner(
            self.q, w, replan_drift=replan_drift, max_gap=max_gap,
            repack_gap=repack_gap, background=background,
            max_buckets=self.max_buckets,
            # mesh execution shards the bucket row axis: pad reducer rows
            # to the device count, exactly like allpairs._plan_for
            pad_reducers_to=(self.mesh.devices.size
                             if self.mesh is not None else 1))
        plan = self._planner.plan()
        with _obs_span("request", workload="load_table",
                       executor=self.executor, tenant=self.tenant):
            sims = ex.run_pairs(jnp.asarray(self._table), plan,
                                self._reducer_fn(), m, mesh=self.mesh,
                                use_kernel=self.use_kernel,
                                interpret=self.interpret)
            sims = jax.block_until_ready(sims)
        warmed = 0
        if warmup:
            warmed = ex.warm_delta_shapes(
                jnp.asarray(self._table), self._planner.delta_shapes(),
                self._reducer_fn(), mesh=self.mesh)
        dt = time.perf_counter() - t0
        self.stats["requests"] += 1
        self.stats["reducers"] += plan.num_reducers
        self.stats["wall_s"] += dt
        info = {
            "executor": self.executor,
            "algorithm": self._planner.algorithm,
            "reducers": plan.num_reducers,
            "comm_cost": self._planner.comm_cost,
            "lower_bound": self._planner.lower_bound,
            "optimality_gap": self._planner.optimality_gap,
            "achievable_gap": self._planner.achievable_gap,
            "warmed_shapes": warmed,
            "wall_s": dt,
        }
        return sims, info

    def flush_replan(self) -> bool:
        """Block until any in-flight background re-plan lands (planning
        state only — served pair values are plan-independent).  Returns
        True if a fresh schema was adopted."""
        assert self._planner is not None, "call load_table() first"
        return self._planner.flush_replan()

    def _edit(self, op: str, *args):
        ex = self._require_streaming()
        assert self._planner is not None, "call load_table() first"
        before = dict(self._planner.stats)
        ledger_seq = _LEDGER.seq
        t0 = time.perf_counter()
        with _obs_span("edit", kind=op, executor=self.executor,
                       tenant=self.tenant):
            delta = getattr(self._planner, op)(*args)
            sims = ex.apply_delta(
                jnp.asarray(self._table), delta, self._reducer_fn(),
                self._table.shape[0], plan_provider=self._planner.plan,
                mesh=self.mesh, use_kernel=self.use_kernel,
                interpret=self.interpret)
            sims = jax.block_until_ready(sims)
        dt = time.perf_counter() - t0
        pstats = self._planner.stats
        self.stats["edits"] += 1
        self.stats["dirty_reducers"] += int(len(delta.dirty_rows))
        self.stats["edit_reducers_total"] += int(delta.num_reducers)
        self.stats["stream_replans"] += \
            pstats["replans"] - before["replans"]
        self.stats["stream_repacks"] += \
            pstats["repacks"] - before["repacks"]
        self.stats["stream_swaps"] += pstats["swaps"] - before["swaps"]
        self.stats["wall_s"] += dt
        info = {
            "executor": self.executor,
            "kind": delta.kind,
            "input_id": int(delta.input_id),
            "dirty_reducers": int(len(delta.dirty_rows)),
            "num_reducers": int(delta.num_reducers),
            "recompute_fraction": float(delta.recompute_fraction),
            "full_replan": bool(delta.full_replan),
            "replan": bool(delta.meta.get("replan", False)),
            "replan_pending": bool(delta.meta.get("replan_pending",
                                                  False)),
            "swap": bool(delta.meta.get("swap", False)),
            "repack": pstats["repacks"] > before["repacks"],
            "comm_cost": float(delta.comm_cost),
            "delta_comm_rows": float(delta.delta_comm_rows()),
            "lower_bound": float(delta.lower_bound),
            "optimality_gap": delta.optimality_gap,
            "achievable_gap": float(self._planner.achievable_gap),
            "gap_drift": float(delta.gap_drift),
            "algorithm": self._planner.algorithm,
            "wall_s": dt,
        }
        comm = self._comm_info({"ledger_seq": ledger_seq})
        if comm is not None:
            info["comm"] = comm
        _OBS_REGISTRY.counter(
            "serve.edits", executor=self.executor, kind=op,
            tenant=self.tenant).inc()
        _OBS_REGISTRY.histogram(
            "serve.edit_seconds", executor=self.executor, kind=op,
            tenant=self.tenant).observe(dt)
        return sims, info

    def add_input(self, row, weight: float = 1.0):
        """Append one feature row to the live table.  Returns
        ``(sims, info)``: the patched matrix (new input's row/column
        filled) and the edit's delta telemetry."""
        from repro.core.schema import InfeasibleError
        row = np.asarray(row, dtype=np.float32).reshape(1, -1)
        assert self._table is not None, "call load_table() first"
        assert row.shape[1] == self._table.shape[1], (
            row.shape, self._table.shape)
        self._table = np.concatenate([self._table, row])
        try:
            return self._edit("insert", float(weight))
        except InfeasibleError:
            # the planner rolled its insert back too — pop the row so the
            # table and the maintained schema stay in lockstep (any other
            # exception leaves the committed input in both)
            self._table = self._table[:-1]
            raise

    def remove_input(self, i: int):
        """Tombstone input ``i``: its row/column of the served matrix is
        zeroed; no reducer recomputes (surviving pair values are
        unchanged)."""
        return self._edit("delete", int(i))

    def update_weight(self, i: int, weight: float):
        """Change input ``i``'s planning size.  Feature rows are untouched
        so the matrix never changes — only the maintained schema (bin
        moves, possibly a gap-drift re-plan) and its telemetry do."""
        return self._edit("reweight", int(i), float(weight))
