"""Serving substrate: batched decode engine with slot-based continuous
batching over the model's KV caches."""

from .engine import BatchedServer, Request

__all__ = ["BatchedServer", "Request"]
