"""Serving substrate: batched decode engine with slot-based continuous
batching over the model's KV caches, plus the paper-workload
``PairwiseService`` (planned similarity queries on any registry executor,
including live-table streaming edits via ``add_input`` / ``remove_input``
/ ``update_weight`` on the streaming executor)."""

from .engine import BatchedServer, PairwiseService, Request

__all__ = ["BatchedServer", "PairwiseService", "Request"]
