"""Serving substrate: batched decode engine with slot-based continuous
batching over the model's KV caches, plus the paper-workload
``PairwiseService`` (planned similarity queries on the bucketed shuffle
executor)."""

from .engine import BatchedServer, PairwiseService, Request

__all__ = ["BatchedServer", "PairwiseService", "Request"]
