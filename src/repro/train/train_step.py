"""Train step factory: loss -> grads -> AdamW, with microbatching,
gradient compression, and sharding derivation from logical axes.

The returned step is a pure jittable function; ``make_state_shardings``
derives NamedShardings for the whole TrainState from the model's logical
axis tree (plus ZeRO-1: optimizer moments additionally sharded over the
data axes on the largest divisible dim).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import ShardingRules, logical_to_spec

from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "make_state_shardings",
           "init_state"]

TrainState = dict  # {'params': ..., 'opt': {'m','v'}, 'step': ()}


def init_state(model, key, opt_cfg: AdamWConfig) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------- shardings

def _zero1_spec(spec: P, shape, mesh: Mesh, data_axes) -> P:
    """Extend a param spec by sharding the largest unsharded dim over the
    data axes (ZeRO-1 for optimizer moments)."""
    n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    used = set()
    for s in spec:
        if s is None:
            continue
        for a in (s if isinstance(s, tuple) else (s,)):
            used.add(a)
    if any(a in used for a in data_axes):
        return spec  # already data-sharded (fsdp)
    best, best_dim = -1, -1
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is None and dim % n_data == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best < 0:
        return spec
    new = list(spec)
    new[best] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    return P(*new)


def make_state_shardings(model, mesh: Mesh, rules: ShardingRules,
                         zero1: bool = True):
    """NamedSharding pytree for TrainState (params, opt moments, step)."""
    axes = model.param_logical_axes()
    shapes = jax.eval_shape(model.init, jax.random.key(0))

    is_leaf = lambda a: isinstance(a, tuple)
    param_specs = jax.tree.map(
        lambda a: logical_to_spec(rules, a), axes, is_leaf=is_leaf)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs)

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if zero1 and data_axes:
        opt_specs = jax.tree.map(
            lambda s, shp: _zero1_spec(s, shp.shape, mesh, data_axes),
            param_specs, shapes)
    else:
        opt_specs = param_specs
    opt_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs)
    return {
        "params": param_sh,
        "opt": {"m": opt_sh, "v": opt_sh},
        "step": NamedSharding(mesh, P()),
    }


def batch_sharding(mesh: Mesh, batch_tree):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = P(data_axes if len(data_axes) > 1 else
             (data_axes[0] if data_axes else None))
    return jax.tree.map(lambda _: NamedSharding(mesh, spec), batch_tree)


# --------------------------------------------------------------- train step

def _compress(g, mode: str):
    if mode == "bf16":
        return g.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        # per-tensor symmetric int8 quantization (error fed back upstream
        # is omitted — we benchmark accuracy impact in tests)
        amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-9)
        q = jnp.round(g / amax * 127.0).astype(jnp.int8)
        return q.astype(jnp.float32) * (amax / 127.0)
    return g


def make_train_step(model, opt_cfg: AdamWConfig, *,
                    microbatch: int = 1):
    """Returns step(state, batch) -> (state, metrics).

    microbatch > 1 splits the per-device batch into `microbatch` chunks and
    accumulates grads with lax.scan (memory/comm trade — remat still applies
    inside the model)."""
    compression = model.flags.grad_compression

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state: TrainState, batch):
        params = state["params"]
        if microbatch > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metrics) = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compression != "none":
            grads = jax.tree.map(lambda g: _compress(g, compression), grads)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, state["step"], opt_cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_state, metrics

    return step
