"""Pure-JAX AdamW with cosine schedule, global-norm clipping, and
configurable moment dtype (bf16 moments halve optimizer HBM for the 400B
configs; see DESIGN.md memory budget)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # 'float32' | 'bfloat16'


def cosine_lr(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * warm * (cfg.min_lr_ratio
                                 + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(grads, opt, params, step, cfg: AdamWConfig):
    """Returns (new_params, new_opt).  fp32 math, params cast back."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cosine_lr(step, cfg)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step_ = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
