"""Training substrate: optimizer, train step, checkpointing, elasticity."""

from .optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from .train_step import TrainState, make_train_step, make_state_shardings
from .checkpoint import CheckpointManager

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
    "TrainState", "make_train_step", "make_state_shardings",
    "CheckpointManager",
]
