"""Elastic scaling + straggler mitigation (fleet-control plane).

On a 1000+ node fleet the control plane must (a) notice dead/slow hosts,
(b) rebuild the mesh without them, and (c) restart from the last checkpoint
with state resharded to the new topology.  The *policy* logic here is pure
and unit-tested; the single-process container exercises it by simulating
failures and restoring checkpoints onto differently-shaped meshes (see
tests/test_fault_tolerance.py).

Design decisions (DESIGN.md §FT):
  * failures drop whole data-parallel replicas — the 'model' axis (TP) is
    intra-pod and treated as an atomic failure domain;
  * step-time EMA per host flags stragglers at > straggler_factor x median;
    persistent stragglers are evicted like failures (checkpoint + rescale);
  * global batch is kept constant by raising per-replica batch when the
    replica count shrinks (synchronous SGD semantics preserved).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

__all__ = ["ElasticPolicy", "StragglerMonitor", "rescale_mesh_shape"]


@dataclasses.dataclass
class ElasticPolicy:
    min_data_parallel: int = 1
    straggler_factor: float = 2.0
    straggler_patience: int = 5       # consecutive slow steps before evict
    heartbeat_timeout_s: float = 60.0


def rescale_mesh_shape(mesh_shape: dict, healthy_replicas: int,
                       policy: ElasticPolicy) -> Optional[dict]:
    """Given the current axis sizes (e.g. {'pod':2,'data':16,'model':16})
    and the number of healthy DP replicas (pod*data), return the new axis
    sizes, or None if below the survivable minimum.

    DP replicas are interchangeable, so we keep 'model' fixed and shrink the
    data axes to the largest feasible factorization."""
    model = mesh_shape.get("model", 1)
    if healthy_replicas < policy.min_data_parallel:
        return None
    if "pod" in mesh_shape:
        pods = mesh_shape["pod"]
        per_pod = mesh_shape["data"]
        # prefer dropping whole pods only when a pod is fully dead;
        # otherwise shrink 'data' to the min healthy count across pods
        new_data = healthy_replicas // pods
        if new_data >= 1:
            return {"pod": pods, "data": new_data, "model": model}
        return {"data": healthy_replicas, "model": model}
    return {"data": healthy_replicas, "model": model}


def scale_batch(global_batch: int, old_replicas: int,
                new_replicas: int) -> int:
    """Per-replica batch that preserves the global batch (rounded up)."""
    per = math.ceil(global_batch / new_replicas)
    return per


class StragglerMonitor:
    """Tracks per-host step-time EMAs; flags persistent stragglers."""

    def __init__(self, num_hosts: int, policy: ElasticPolicy,
                 ema: float = 0.7):
        self.policy = policy
        self.ema = ema
        self.times = [None] * num_hosts
        self.slow_streak = [0] * num_hosts

    def observe(self, host: int, step_time: float) -> None:
        prev = self.times[host]
        self.times[host] = (step_time if prev is None
                            else self.ema * prev + (1 - self.ema) * step_time)

    def median(self) -> float:
        vals = sorted(t for t in self.times if t is not None)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def update_flags(self) -> list[int]:
        """Returns hosts to evict (exceeded patience)."""
        med = self.median()
        evict = []
        for h, t in enumerate(self.times):
            if t is None or med == 0.0:
                continue
            if t > self.policy.straggler_factor * med:
                self.slow_streak[h] += 1
            else:
                self.slow_streak[h] = 0
            if self.slow_streak[h] >= self.policy.straggler_patience:
                evict.append(h)
        return evict
