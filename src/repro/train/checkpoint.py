"""Fault-tolerant checkpointing: atomic, versioned, reshard-on-restore.

Layout:  <dir>/step_<N>/{manifest.json, arrays.npz}  written to a temp dir
and atomically renamed, so a crash mid-save never corrupts the latest
checkpoint.  Restore accepts a *different* mesh/sharding than the one that
saved (elastic rescale): arrays are loaded and re-placed with jax.device_put
to the new shardings.

On a real multi-host fleet each host writes its local shards; the single
process here writes the full arrays (documented in DESIGN.md §FT).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, extra: Optional[dict] = None) -> str:
        flat = _flatten(state)
        arrays = {}
        for k, v in flat.items():
            a = np.asarray(v)
            if a.dtype.name == "bfloat16":   # npz can't store bf16: view u16
                a = a.view(np.uint16)
            arrays[k] = a
        manifest = {
            "step": int(step),
            "time": time.time(),
            "keys": sorted(arrays),
            "dtypes": {k: str(np.asarray(v).dtype)
                       for k, v in flat.items()},
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "extra": extra or {},
        }
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, *,
                shardings=None, template=None):
        """Load a checkpoint; optionally re-place onto new `shardings`
        (pytree of NamedSharding matching the state tree — elastic restore
        onto a different mesh)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {}
        for k in manifest["keys"]:
            a = data[k]
            want = manifest["dtypes"].get(k)
            if want == "bfloat16":
                import ml_dtypes
                a = a.view(ml_dtypes.bfloat16)
            flat[k] = a
        state = _unflatten(flat)
        if template is not None:
            t_flat = _flatten(template)
            for k in list(flat):
                want = t_flat[k].dtype if hasattr(t_flat[k], "dtype") else None
                if want is not None and str(want) != str(flat[k].dtype):
                    flat[k] = flat[k].astype(want)
            state = _unflatten(flat)
        if shardings is not None:
            sh_flat = _flatten(shardings)
            flat = {k: jax.device_put(v, sh_flat[k])
                    for k, v in _flatten(state).items()}
            state = _unflatten(flat)
        return state, manifest

    # -------------------------------------------------------------------- gc
    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
