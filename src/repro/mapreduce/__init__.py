"""JAX execution engine for mapping schemas.

The planner (``repro.core``) decides *where* inputs go; this package
executes the plan on a device mesh: the map->reduce shuffle becomes a static
gather whose communication volume is exactly the schema's communication
cost, and the reduce phase becomes a vmapped/shard_mapped reducer function.
The hardware adaptation (reducer slots, static gather plans, wave batching)
is documented in DESIGN.md.

Public API
----------
``build_plan(schema, ...)``
    Flatten a :class:`repro.core.MappingSchema` into a :class:`ReducerPlan`
    — static (R, L) index/mask arrays padded for the mesh and kernel tiles.
    The plan carries the schema's provenance (``algorithm``,
    ``lower_bound``, ``optimality_gap``) for downstream telemetry.
``run_reducers(inputs, plan, reducer_fn, mesh=...)``
    Execute a reducer function over every slot; the gather *is* the
    shuffle.  Dense path: every reducer padded to the global max slot
    count.
``run_reducers_bucketed(inputs, plan, reducer_fn, mesh=...)``
    Skew-aware path: one vmapped gather+reduce per capacity bucket, each
    padded only to its own power-of-two width (DESIGN.md "bucketed
    shuffle execution").  ``combine='dense'`` reproduces the dense output
    layout; ``combine='buckets'`` keeps per-bucket outputs unpadded.
``run_reducers_fused(inputs, plan, reducer_fn, mesh=...)``
    Fused path (DESIGN.md "fused shuffle execution"): Gram-block reducers
    stream the shuffle straight into the MXU via the fused gather+Gram
    Pallas kernel (jnp tile-twin off-TPU) — all buckets in one program,
    the padded gather never written to HBM.  Non-Gram reducers fall back
    to the bucketed path.
``run_reducers_sharded(inputs, plan, reducer_fn, mesh=...)``
    Shard-balanced multi-device path (DESIGN.md "sharded execution"):
    ``repro.core.planner.partition_plan`` LPT-balances reducers over the
    mesh's reducer axis; each shard runs the fused tile pipeline under
    ``shard_map``, with one cross-shard gather for assembly.
``get_executor(name)`` / ``make_executor(name)`` / ``register_executor``
    The executor registry (``repro.mapreduce.executors``): executors are
    classes exposing ``run`` / ``run_pairs`` / ``lower`` / ``stats`` and
    registered by name ("dense", "bucketed", "fused", "sharded", "coded",
    "streaming") — the single dispatch point for every application entry
    below.
``pairwise_similarity(x, q=...)``
    A2A application: all-pairs similarity through a planned schema.
``some_pairs_similarity(x, pairs, q=...)``
    Sparse variant (Ullman & Ullman's some-pairs problem): only the
    required pairs must meet, only pair-incident inputs are shipped.
``assemble_pair_matrix(blocks, plan, m)``
    Scatter per-reducer blocks back into the global (m, m) matrix.
``skew_join(...)``
    X2Y application: skewed join via the Section-10 bipartite schema.
"""

from .engine import (
    ReducerBucket,
    ReducerPlan,
    SparsePlan,
    block_cache_stats,
    block_subplan,
    build_plan,
    build_sparse_plan,
    build_x2y_plan,
    configure_block_cache,
    configure_jit_cache,
    fused_stats,
    jit_cache_stats,
    run_reducers,
    run_reducers_bucketed,
    run_reducers_fused,
    run_reducers_sharded,
    run_reducers_x2y,
    run_reducers_x2y_bucketed,
)
from .executors import (
    Executor,
    get_executor,
    list_executors,
    make_executor,
    register_executor,
)
from .allpairs import (
    assemble_pair_matrix,
    assemble_pair_matrix_bucketed,
    assemble_x2y_matrix_bucketed,
    pairwise_similarity,
    pairwise_similarity_block,
    some_pairs_similarity,
    x2y_similarity,
)
from .skewjoin import join, skew_join

__all__ = [
    "ReducerBucket", "ReducerPlan", "SparsePlan", "build_plan",
    "build_sparse_plan", "block_subplan", "build_x2y_plan",
    "run_reducers", "run_reducers_bucketed", "run_reducers_fused",
    "run_reducers_sharded", "run_reducers_x2y",
    "run_reducers_x2y_bucketed",
    "Executor", "get_executor", "make_executor", "register_executor",
    "list_executors",
    "fused_stats", "jit_cache_stats", "configure_jit_cache",
    "block_cache_stats", "configure_block_cache",
    "pairwise_similarity", "pairwise_similarity_block",
    "some_pairs_similarity", "x2y_similarity",
    "assemble_pair_matrix", "assemble_pair_matrix_bucketed",
    "assemble_x2y_matrix_bucketed",
    "skew_join", "join",
]
