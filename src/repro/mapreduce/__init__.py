"""JAX execution engine for mapping schemas.

The planner (repro.core) decides *where* inputs go; this package executes the
plan on a device mesh: the map->reduce shuffle becomes a static gather whose
communication volume is exactly the schema's communication cost, and the
reduce phase becomes a vmapped/shard_mapped reducer function.
"""

from .engine import ReducerPlan, build_plan, run_reducers
from .allpairs import pairwise_similarity, assemble_pair_matrix
from .skewjoin import skew_join

__all__ = [
    "ReducerPlan", "build_plan", "run_reducers",
    "pairwise_similarity", "assemble_pair_matrix", "skew_join",
]
