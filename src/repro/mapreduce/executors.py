"""Executor protocol + registry: one pluggable execution subsystem.

The planner decides *where* inputs go; an :class:`Executor` decides *how*
the resulting :class:`~repro.mapreduce.engine.ReducerPlan` runs on the
hardware.  Every executor is a class exposing

  ``run(inputs, plan, reducer_fn, ...)``   — execute the plan;
  ``run_pairs(x, plan, reducer_fn, m, ...)`` — execute + assemble the
        (m, m) pair matrix (the all-pairs / some-pairs applications);
  ``lower(input_shape, plan, ...)``        — AOT-lower for dry-run /
        roofline analysis;
  ``stats()`` / ``reset()``                — instance-scoped dispatch
        telemetry (no module globals to pollute across callers);

registered by name ("dense", "bucketed", "fused", "sharded", "coded",
"streaming")
so applications dispatch through ``get_executor(name)`` instead of
per-module ``if executor == ...`` ladders.  ``make_executor(name)`` returns
a *fresh* instance with its own counters — what ``serve.PairwiseService``
holds so concurrent services never share telemetry.

The registry executors:

``dense``     — one gather padded to the global max slot count
                (differential-test oracle).
``bucketed``  — skew-aware: one vmapped gather+reduce per capacity bucket
                (DESIGN.md "bucketed shuffle execution").
``fused``     — gather+Gram megakernel: the shuffle streams straight into
                the MXU, all buckets in one program (DESIGN.md "fused
                shuffle execution"); non-Gram reducers fall back to
                bucketed.
``sharded``   — shard-balanced multi-device execution (DESIGN.md "sharded
                execution"): ``repro.core.planner.partition_plan`` LPT-
                balances reducers over the mesh's reducer axis, each shard
                runs the fused/bucketed tile pipeline under ``shard_map``,
                and one cross-shard gather assembles the (m, m) matrix.
``coded``     — coded shuffle execution (DESIGN.md "coded shuffle
                execution"; Afrati et al., arXiv:1206.4377): each
                reducer's sub-plan is replicated on ``r`` LPT-chosen
                shards, the output matrix is row-sliced, replica holders
                serve their slice's cells locally, and only the residual
                entries cross shards in one batched all-to-all — assembly
                bytes fall roughly as ``(1 - r/S)`` at the price of
                ``r×`` input shipping.
``streaming`` — delta execution of maintained plans (DESIGN.md "streaming
                maintenance"; ``repro.stream``, registered lazily): only
                the reducers an edit dirtied are recomputed, and the
                cached (m, m) matrix is patched instead of rebuilt.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh, shard_map
from repro.core.planner import PlanPartition, partition_plan
from repro.obs import EVENTS as _EVENTS
from repro.obs import LEDGER as _LEDGER
from repro.obs import REGISTRY as _REGISTRY_OBS
from repro.obs import _config as _obs_config

from . import engine as _engine
from .engine import (
    ReducerBucket,
    ReducerPlan,
    _as_tables,
    _cache_get,
    _shardings,
    run_reducers,
    run_reducers_bucketed,
    run_reducers_x2y,
    run_reducers_x2y_bucketed,
)

__all__ = [
    "Executor",
    "DenseExecutor",
    "BucketedExecutor",
    "FusedExecutor",
    "ShardedExecutor",
    "CodedExecutor",
    "coded_assembly_model",
    "choose_replication",
    "register_executor",
    "get_executor",
    "make_executor",
    "list_executors",
]


# ---------------------------------------------------------------------------
# protocol + registry
# ---------------------------------------------------------------------------
class Executor:
    """Base executor: run / run_pairs / lower / stats / reset.

    Subclasses set ``name`` and implement the four methods; ``_stats`` is a
    plain dict owned by the instance (pass one in to share counters across
    instances).  Every ``_count`` additionally publishes into the process
    observability registry as ``executor.<key>{executor=<name>}`` — ONE
    labeled series per executor name shared by all its instances, which is
    the aggregate view ``engine.fused_stats()`` reads.  Dispatches also
    reconcile into the comm ledger (``repro.obs.LEDGER``): measured gather
    slots and assembly bytes vs the plan's predicted cost and lower bound
    (DESIGN.md 1j)."""

    name: str = "?"

    def __init__(self, stats: Optional[dict] = None):
        self._stats = stats if stats is not None else self._fresh_stats()

    def _fresh_stats(self) -> dict:
        return {"calls": 0}

    # -- protocol ----------------------------------------------------------
    def run(self, inputs, plan: ReducerPlan, reducer_fn: Callable, *,
            mesh=None, shard_axes=None, **kwargs):
        raise NotImplementedError

    def run_pairs(self, x, plan: ReducerPlan, reducer_fn: Callable, m: int,
                  *, mesh=None, use_kernel: bool = False,
                  interpret: bool = False):
        """Execute the plan and assemble the (m, m) pair matrix."""
        raise NotImplementedError

    def run_x2y(self, tables, plan: ReducerPlan, reducer_fn: Callable,
                shape: tuple[int, int], *, mesh=None,
                use_kernel: bool = False, interpret: bool = False):
        """Execute a rectangular (X2Y) plan and assemble the (mx, my[, c])
        cross output.

        ``tables`` is an (x_table, y_table) pair (or one shared array);
        ``reducer_fn(xblock, xmask, yblock, ymask)`` emits (Lx, Ly[, c])
        cross blocks; ``shape = (mx, my)`` sizes the assembled output.
        The square ``run_pairs`` is the degenerate X == Y case of this
        method."""
        raise NotImplementedError

    def run_block(self, x, sparse, reducer_fn: Callable,
                  i0: int, i1: int, j0: int, j1: int, *, mesh=None,
                  use_kernel: bool = False, interpret: bool = False,
                  pad_reducers_to: int = 1, pad_slots_to: int = 1,
                  max_buckets: int = 8):
        """Serve the ``[i0:i1) x [j0:j1)`` sub-block of the (m, m) pair
        matrix without materializing the whole matrix.

        ``sparse`` is an :class:`~repro.mapreduce.engine.SparsePlan`;
        ``reducer_fn`` is a two-sided (X2Y) reducer.  The default routes
        the block's reducers — selected by
        :func:`~repro.mapreduce.engine.block_subplan` — through this
        executor's own ``run_x2y`` (fused/sharded executors therefore
        reuse their inverse-shuffle srcmap machinery restricted to the
        block), then zeroes global-diagonal cells to match the dense pair
        matrix's convention.  Works for every registry executor; override
        only to specialize the routing."""
        bx, by = i1 - i0, j1 - j0
        sub = _engine.block_subplan(
            sparse, i0, i1, j0, j1, pad_reducers_to=pad_reducers_to,
            pad_slots_to=pad_slots_to, max_buckets=max_buckets)
        if sub is None or bx == 0 or by == 0:
            out = jnp.zeros((max(bx, 0), max(by, 0)), jnp.float32)
        else:
            out = self.run_x2y((x[i0:i1], x[j0:j1]), sub, reducer_fn,
                               (bx, by), mesh=mesh, use_kernel=use_kernel,
                               interpret=interpret)
        lo, hi = max(i0, j0), min(i1, j1)
        if lo < hi:  # the block crosses the global diagonal: zero it
            d = jnp.arange(lo, hi)
            out = out.at[d - i0, d - j0].set(0.0)
        self._count("block_calls")
        return out

    def lower(self, input_shape, plan: ReducerPlan, *, reducer_fn=None,
              metric=None, mesh=None, dtype=jnp.float32, shard_axes=None,
              **kwargs):
        raise NotImplementedError

    def stats(self) -> dict:
        """Snapshot of this instance's dispatch counters."""
        return dict(self._stats)

    def reset(self) -> None:
        """Zero this instance's counters (in place: shared dicts stay
        shared)."""
        for k in self._stats:
            self._stats[k] = 0 if not isinstance(self._stats[k], float) \
                else 0.0

    def _count(self, key: str, by: int = 1) -> None:
        self._stats[key] = self._stats.get(key, 0) + by
        _REGISTRY_OBS.counter(f"executor.{key}", executor=self.name).inc(by)

    def _count_fallback(self, reason: str) -> None:
        """A non-fusable dispatch fell back to the bucketed path: count it
        and emit the (previously silent) lifecycle event."""
        self._count("fallbacks")
        _EVENTS.emit("executor_fallback", executor=self.name, reason=reason)

    def _reconcile(self, plan, workload: str, table, *,
                   measured_slots: int, replication: float = 1.0,
                   assembled_bytes: int = 0, local_bytes: int = 0,
                   residual_bytes: int = 0, meta: Optional[dict] = None
                   ) -> None:
        """Record this execution's comm reconciliation (no-op when obs is
        disabled).  ``table`` supplies the input row size (d, itemsize)."""
        if not _obs_config.ENABLED:
            return
        d, itemsize = _row_bytes(table)
        _LEDGER.record(
            executor=self.name, workload=workload,
            predicted_rows=float(plan.comm_cost),
            lb_rows=plan.lower_bound,
            plan_slots=_plan_valid_slots(plan),
            measured_slots=int(measured_slots), d=d, itemsize=itemsize,
            replication=replication, assembled_bytes=assembled_bytes,
            local_bytes=local_bytes, residual_bytes=residual_bytes,
            meta=meta)


def _row_bytes(table) -> tuple[int, int]:
    """(d, itemsize) of one input row — the ledger's byte scale.  Works on
    numpy/jax arrays; anything shapeless falls back to (0, 4)."""
    shape = getattr(table, "shape", None)
    if not shape or len(shape) < 2:
        return 0, 4
    itemsize = getattr(getattr(table, "dtype", None), "itemsize", 4)
    return int(shape[-1]), int(itemsize)


def _plan_valid_slots(plan) -> int:
    """Valid gather slots the plan books (X + Y sides for rect plans) —
    the ledger's ``plan_slots`` denominator.  Cached on the plan."""
    n = plan.__dict__.get("_obs_plan_slots")
    if n is None:
        n = int(np.asarray(plan.mask).sum())
        if plan.ymask is not None:
            n += int(np.asarray(plan.ymask).sum())
        object.__setattr__(plan, "_obs_plan_slots", n)
    return n


def _bucket_valid_slots(plan) -> int:
    """Valid gather slots the bucketed/fused program materializes (sum of
    per-bucket masks; padding rows are all-False, so this equals the dense
    mask sum — the 1.0-ratio invariant tests pin).  Cached on the plan."""
    n = plan.__dict__.get("_obs_bucket_slots")
    if n is None:
        if plan.buckets:
            n = 0
            for b in plan.buckets:
                n += int(np.asarray(b.mask).sum())
                if b.ymask is not None:
                    n += int(np.asarray(b.ymask).sum())
        else:
            n = _plan_valid_slots(plan)
        object.__setattr__(plan, "_obs_bucket_slots", n)
    return n


def _group_valid_slots(plan, cache_key, groups, count_y: bool) -> int:
    """Valid gather slots in stacked shard groups (the sharded/coded
    executors' measured side).  5-tuple groups carry (xi, xm, yi, ym,
    rows); ``count_y=False`` for the square coded path, where xm and ym
    are the same gather and copies must be counted once.  Cached on the
    plan per (shards, replication, rect) key."""
    cache = plan.__dict__.get("_obs_group_slots")
    if cache is None:
        cache = {}
        object.__setattr__(plan, "_obs_group_slots", cache)
    n = cache.get(cache_key)
    if n is None:
        n = 0
        for grp in groups:
            if len(grp) >= 5:
                n += int(np.asarray(grp[1]).sum())
                if count_y:
                    n += int(np.asarray(grp[3]).sum())
            else:                       # (idx, mask, rows) square stack
                n += int(np.asarray(grp[1]).sum())
        cache[cache_key] = n
    return n


def _group_gram_entries(plan, cache_key, groups) -> int:
    """Gram entries the stacked shard groups produce — what the sharded
    all-gather assembly ships.  Cached on the plan (same cache as the slot
    sums, disjoint keys)."""
    cache = plan.__dict__.get("_obs_group_slots")
    if cache is None:
        cache = {}
        object.__setattr__(plan, "_obs_group_slots", cache)
    n = cache.get(cache_key)
    if n is None:
        n = 0
        for grp in groups:
            if len(grp) >= 5:            # rect: (xi, xm, yi, ym, rows)
                xi, yi = grp[0], grp[2]
                n += int(np.prod(xi.shape[:2])) * xi.shape[2] * yi.shape[2]
            else:                        # square: (idx, mask, rows)
                i = grp[0]
                n += int(np.prod(i.shape[:2])) * i.shape[2] ** 2
        cache[cache_key] = n
    return n


_REGISTRY: dict[str, Executor] = {}
_CLASSES: dict[str, type] = {}


def register_executor(executor: Executor) -> Executor:
    """Register ``executor`` as the default instance for its ``name``
    (latest registration wins — extension point for custom executors)."""
    _REGISTRY[executor.name] = executor
    _CLASSES[executor.name] = type(executor)
    return executor


def get_executor(name) -> Executor:
    """Default registry instance by name; Executor instances pass through
    (so application entry points accept either).  Unknown names raise
    ``ValueError`` — the registry is the single dispatch point."""
    if isinstance(name, Executor):
        return name
    ex = _REGISTRY.get(name)
    if ex is None and name == "streaming":
        # the streaming subsystem registers its executor on import; loaded
        # lazily so the engine never pays for it unless it is used
        import repro.stream  # noqa: F401
        ex = _REGISTRY.get(name)
    if ex is None:
        raise ValueError(
            f"unknown executor {name!r} (registered: {list_executors()})")
    return ex


def make_executor(name: str, **kwargs) -> Executor:
    """Fresh instance (own stats) of the executor registered under
    ``name``."""
    get_executor(name)                       # raise on unknown names
    return _CLASSES[name](**kwargs)


def list_executors() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# dense + bucketed: wrappers over the engine substrate
# ---------------------------------------------------------------------------
class DenseExecutor(Executor):
    """One gather padded to the global max slot count (the oracle path)."""

    name = "dense"

    def run(self, inputs, plan, reducer_fn, *, mesh=None, shard_axes=None,
            **kwargs):
        self._count("calls")
        return run_reducers(inputs, plan, reducer_fn, mesh=mesh,
                            shard_axes=shard_axes, **kwargs)

    def run_pairs(self, x, plan, reducer_fn, m, *, mesh=None,
                  use_kernel=False, interpret=False):
        from .allpairs import assemble_pair_matrix
        self._count("calls")
        self._reconcile(plan, "pairs", x,
                        measured_slots=_plan_valid_slots(plan))
        blocks = run_reducers(x, plan, reducer_fn, mesh=mesh)  # (R, L, L)
        return assemble_pair_matrix(blocks, plan, m)

    def run_x2y(self, tables, plan, reducer_fn, shape, *, mesh=None,
                use_kernel=False, interpret=False):
        from .allpairs import assemble_x2y_matrix_bucketed
        self._count("calls")
        self._reconcile(plan, "x2y", _as_tables(tables)[0],
                        measured_slots=_plan_valid_slots(plan))
        blocks = run_reducers_x2y(tables, plan, reducer_fn, mesh=mesh)
        # the plan's dense idx/mask/yidx/ymask rows are bucket-shaped, so
        # the whole plan assembles as a single "bucket"
        return assemble_x2y_matrix_bucketed([(plan, blocks)], shape)

    def lower(self, input_shape, plan, *, reducer_fn=None, metric=None,
              mesh=None, dtype=jnp.float32, shard_axes=None, **kwargs):
        from .engine import lower_reducers
        return lower_reducers(input_shape, plan, reducer_fn, mesh,
                              dtype=dtype, shard_axes=shard_axes)


class BucketedExecutor(Executor):
    """Skew-aware: one vmapped gather+reduce per capacity bucket."""

    name = "bucketed"

    def run(self, inputs, plan, reducer_fn, *, mesh=None, shard_axes=None,
            combine: str = "dense", **kwargs):
        self._count("calls")
        return run_reducers_bucketed(inputs, plan, reducer_fn, mesh=mesh,
                                     shard_axes=shard_axes, combine=combine,
                                     **kwargs)

    def run_pairs(self, x, plan, reducer_fn, m, *, mesh=None,
                  use_kernel=False, interpret=False):
        from .allpairs import assemble_pair_matrix_bucketed
        self._count("calls")
        self._reconcile(plan, "pairs", x,
                        measured_slots=_bucket_valid_slots(plan))
        per_bucket = run_reducers_bucketed(x, plan, reducer_fn, mesh=mesh,
                                           combine="buckets")
        return assemble_pair_matrix_bucketed(per_bucket, m)

    def run_x2y(self, tables, plan, reducer_fn, shape, *, mesh=None,
                use_kernel=False, interpret=False):
        from .allpairs import assemble_x2y_matrix_bucketed
        self._count("calls")
        self._reconcile(plan, "x2y", _as_tables(tables)[0],
                        measured_slots=_bucket_valid_slots(plan))
        per_bucket = run_reducers_x2y_bucketed(tables, plan, reducer_fn,
                                               mesh=mesh, combine="buckets")
        return assemble_x2y_matrix_bucketed(per_bucket, shape)

    def lower(self, input_shape, plan, *, reducer_fn=None, metric=None,
              mesh=None, dtype=jnp.float32, shard_axes=None, **kwargs):
        """Protocol deviation (documented): the bucketed path is one XLA
        program PER capacity bucket, so this returns
        ``[(bucket, Lowered), ...]`` — not a single ``Lowered`` like the
        other executors.  Roofline consumers sum the per-bucket terms
        (``dryrun_engine.analyze_bucketed`` via ``combine_hlo_stats``)."""
        from .engine import lower_reducers_bucketed
        return lower_reducers_bucketed(input_shape, plan, reducer_fn, mesh,
                                       dtype=dtype, shard_axes=shard_axes)


# ---------------------------------------------------------------------------
# fused (gather+Gram megakernel) executor
# ---------------------------------------------------------------------------
def _finish_fused_blocks(g, mask, metric: str):
    """Metric post-processing of a masked per-reducer Gram stack.

    Mirrors ``allpairs.block_similarity`` exactly: norms are the Gram
    diagonal (masked rows were zeroed at gather time, so their norms are 0),
    invalid pairs -> 0.
    """
    if metric != "dot":
        n2 = jnp.diagonal(g, axis1=1, axis2=2)            # (Rb, Lb)
        if metric == "l2":
            g = n2[:, :, None] + n2[:, None, :] - 2.0 * g
        elif metric == "cosine":
            nrm = jnp.sqrt(n2 + 1e-9)
            g = g / (nrm[:, :, None] * nrm[:, None, :])
        else:
            raise ValueError(metric)
    valid = mask[:, :, None] & mask[:, None, :]
    return jnp.where(valid, g, 0.0)


def _finish_rect_blocks(g, xidx, xmask, yidx, ymask, n2x, n2y, metric: str):
    """Metric post-processing of a masked rectangular cross-Gram stack.

    Mirrors ``allpairs.block_similarity_x2y`` exactly.  Cross blocks carry
    no Gram diagonal, so per-row squared norms are gathered from the
    table-level vectors ``n2x``/``n2y`` (masked slots -> 0, matching the
    zero-masked gathers of the reference path); invalid pairs -> 0.
    """
    if metric != "dot":
        gx = jnp.where(xmask, jnp.take(n2x, xidx, axis=0), 0.0)  # (Rb, Lx)
        gy = jnp.where(ymask, jnp.take(n2y, yidx, axis=0), 0.0)  # (Rb, Ly)
        if metric == "l2":
            g = gx[:, :, None] + gy[:, None, :] - 2.0 * g
        elif metric == "cosine":
            g = g / (jnp.sqrt(gx + 1e-9)[:, :, None]
                     * jnp.sqrt(gy + 1e-9)[:, None, :])
        else:
            raise ValueError(metric)
    valid = xmask[:, :, None] & ymask[:, None, :]
    return jnp.where(valid, g, 0.0)


def _scatter_rows(bucket: ReducerBucket, R: int) -> np.ndarray:
    """Bucket rows for drop-style scatter: padding rows (-1) -> row R."""
    return np.where(bucket.rows >= 0, bucket.rows, R).astype(np.int32)


def _make_fused_jitted(metric, combine, mesh, shard_axes, use_kernel,
                       interpret, bl, postprocess):
    from repro.kernels.pairwise.fused_gather_gram import (
        fused_gather_gram,
        fused_gather_gram_streamed,
    )

    def run(x, buckets, pp_arg, R, L):
        per_bucket = []
        for idx, msk, rows in buckets:
            if use_kernel:
                g = fused_gather_gram(x, idx, msk, bl=bl,
                                      interpret=interpret)
            else:
                g = fused_gather_gram_streamed(x, idx, msk, bl=bl)
            mb = msk.astype(bool)
            per_bucket.append(((idx, mb, rows),
                               _finish_fused_blocks(g, mb, metric)))
        if postprocess is not None:
            return postprocess(per_bucket, pp_arg)
        if combine == "buckets":
            return [g for _, g in per_bucket]
        # dense combine: scatter bucket blocks (padded to the dense width)
        # into original reducer order; padding rows land in the extra row R
        acc = jnp.zeros((R + 1, L, L), jnp.float32)
        for (idx, msk, rows), g in per_bucket:
            Lb = g.shape[1]
            gp = jnp.pad(g, ((0, 0), (0, L - Lb), (0, L - Lb)))
            acc = acc.at[rows].set(gp)
        return acc[:R]

    if mesh is None:
        return jax.jit(run, static_argnums=(3, 4))
    red_sharding, rep = _shardings(mesh, shard_axes)
    return jax.jit(run, in_shardings=(rep, red_sharding, rep),
                   static_argnums=(3, 4))


def _make_fused_rect_jitted(metric, mesh, shard_axes, use_kernel,
                            interpret, bl):
    from repro.kernels.pairwise.fused_gather_gram import (
        fused_gather_gram_rect,
        fused_gather_gram_rect_streamed,
    )

    def run(xt, yt, buckets, srcmap):
        n2x = jnp.sum(xt.astype(jnp.float32) ** 2, axis=-1)   # (mx,)
        n2y = jnp.sum(yt.astype(jnp.float32) ** 2, axis=-1)   # (my,)
        vals = [jnp.zeros((1,), jnp.float32)]
        for xidx, xmsk, yidx, ymsk in buckets:
            if use_kernel:
                g = fused_gather_gram_rect(xt, yt, xidx, xmsk, yidx, ymsk,
                                           bl=bl, interpret=interpret)
            else:
                g = fused_gather_gram_rect_streamed(xt, yt, xidx, xmsk,
                                                    yidx, ymsk, bl=bl)
            g = _finish_rect_blocks(g, xidx, xmsk.astype(bool),
                                    yidx, ymsk.astype(bool), n2x, n2y,
                                    metric)
            vals.append(g.reshape(-1))
        # rectangular inverse shuffle: ONE assembly gather through the
        # host-precomputed source map (slot 0 -> 0.0 for uncovered cells)
        return jnp.take(jnp.concatenate(vals), srcmap, axis=0)

    if mesh is None:
        return jax.jit(run)
    red_sharding, rep = _shardings(mesh, shard_axes)
    return jax.jit(run, in_shardings=(rep, rep, red_sharding, rep))


class FusedExecutor(Executor):
    """Fused shuffle execution: the gathered block stays out of HBM.

    Per capacity bucket, the plan's ``idx``/``mask`` rows drive the fused
    gather+Gram Pallas kernel (``use_kernel=True``; scalar-prefetched rows,
    table rows DMA'd HBM->VMEM, fp32 MXU accumulation — gathered rows live
    only in VMEM scratch) or its jnp twin with the same tile dataflow
    (``use_kernel=False``, the non-TPU default) — the twin still gathers
    ``(Rb, bl, d)`` tiles as XLA intermediates, but a multi-tile bucket
    never materializes its full ``(Rb, Lb, d)`` block and no bucket ever
    materializes the dense ``(R, L, d)`` one.  *All* buckets execute
    inside ONE jitted program, so a request pays a single dispatch instead
    of one per bucket.

    Only Gram-block reducers are fusable: ``reducer_fn`` must carry a
    ``fused_metric`` attribute (see ``allpairs._block_fn``).  Any other
    reducer — and bucketless plans — falls back to the bucketed executor
    with identical outputs; fallbacks are counted in this instance's
    ``stats()``.
    """

    name = "fused"

    def _fresh_stats(self) -> dict:
        return {"calls": 0, "kernel": 0, "streamed": 0, "fallbacks": 0}

    def run(self, inputs, plan, reducer_fn, *, mesh=None, shard_axes=None,
            combine: str = "dense", postprocess: Optional[Callable] = None,
            postprocess_arg=None, use_kernel: Optional[bool] = None,
            interpret: bool = False, bl: int = 128):
        """``combine`` follows the bucketed executor ('dense' / 'buckets');
        ``postprocess(per_bucket, postprocess_arg)`` — a *stable* function
        object, traced into the same program — lets applications fuse their
        assembly step too (allpairs passes its inverse-shuffle gather map).
        ``use_kernel=None`` auto-selects: Pallas on TPU, streamed jnp
        elsewhere."""
        assert combine in ("dense", "buckets"), combine
        self._count("calls")
        metric = getattr(reducer_fn, "fused_metric", None)
        if metric is None or not plan.buckets:
            self._count_fallback(
                "non_gram_reducer" if metric is None else "no_buckets")
            out = run_reducers_bucketed(
                inputs, plan, reducer_fn, mesh=mesh, shard_axes=shard_axes,
                combine="buckets" if postprocess is not None else combine)
            if postprocess is not None:
                # honor the postprocess contract on the fallback path (eager)
                per_bucket = [((jnp.asarray(b.idx), jnp.asarray(b.mask),
                                jnp.asarray(_scatter_rows(b, plan.R))),
                               blocks)
                              for b, blocks in out]
                return postprocess(per_bucket, postprocess_arg)
            return out

        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self._count("kernel" if use_kernel else "streamed")
        shard_axes = tuple(shard_axes) if shard_axes is not None else None
        fn = _cache_get(
            ("fused", metric, combine, postprocess, mesh, shard_axes,
             bool(use_kernel), bool(interpret), bl),
            lambda: _make_fused_jitted(metric, combine, mesh, shard_axes,
                                       use_kernel, interpret, bl,
                                       postprocess))
        buckets = tuple(
            (jnp.asarray(b.idx), jnp.asarray(b.mask),
             jnp.asarray(_scatter_rows(b, plan.R)))
            for b in plan.buckets)
        return fn(inputs, buckets, postprocess_arg, plan.R, plan.L)

    def run_pairs(self, x, plan, reducer_fn, m, *, mesh=None,
                  use_kernel=False, interpret=False):
        from .allpairs import _assemble_from_srcmap, _pair_source_map
        # reconcile here, not in run(): the delegation below must not
        # double-record the request
        self._reconcile(plan, "pairs", x,
                        measured_slots=_bucket_valid_slots(plan))
        srcmap = jnp.asarray(_pair_source_map(plan, m))
        return self.run(
            x, plan, reducer_fn, mesh=mesh,
            postprocess=_assemble_from_srcmap, postprocess_arg=srcmap,
            use_kernel=(True if use_kernel else None), interpret=interpret)

    def run_x2y(self, tables, plan, reducer_fn, shape, *, mesh=None,
                use_kernel=False, interpret=False, bl: int = 128):
        """Rectangular fused path: per rect bucket, independent X/Y gather
        maps drive the rectangular gather+Gram kernel (streamed jnp twin
        off-TPU), and ONE inverse-shuffle gather assembles the (mx, my)
        matrix.  Non-Gram reducers fall back to the rect-bucketed path
        (identical outputs; counted)."""
        from .allpairs import (
            _pair_source_map_rect,
            assemble_x2y_matrix_bucketed,
        )
        self._count("calls")
        self._reconcile(plan, "x2y", _as_tables(tables)[0],
                        measured_slots=_bucket_valid_slots(plan))
        metric = getattr(reducer_fn, "fused_metric", None)
        if metric is None or not plan.buckets:
            self._count_fallback(
                "non_gram_reducer" if metric is None else "no_buckets")
            per_bucket = run_reducers_x2y_bucketed(
                tables, plan, reducer_fn, mesh=mesh, combine="buckets")
            return assemble_x2y_matrix_bucketed(per_bucket, shape)
        uk = True if use_kernel else jax.default_backend() == "tpu"
        self._count("kernel" if uk else "streamed")
        srcmap = jnp.asarray(_pair_source_map_rect(plan, *shape))
        fn = _cache_get(
            ("fused-x2y", metric, mesh, None, bool(uk), bool(interpret),
             bl),
            lambda: _make_fused_rect_jitted(metric, mesh, None, uk,
                                            interpret, bl))
        buckets = tuple(
            (jnp.asarray(b.idx), jnp.asarray(b.mask),
             jnp.asarray(b.yidx), jnp.asarray(b.ymask))
            for b in plan.buckets)
        xt, yt = _as_tables(tables)
        return fn(xt, yt, buckets, srcmap)

    def lower(self, input_shape, plan, *, reducer_fn=None, metric=None,
              mesh=None, dtype=jnp.float32, shard_axes=None,
              combine: str = "buckets", use_kernel: bool = False,
              bl: int = 128, **kwargs):
        """Lower the single all-bucket program (no execution).  Defaults to
        the streamed (jnp) lowering so the dry-run works on any backend; on
        this path the program is directly comparable with the bucketed
        lowering — same math, one program, no materialized gather for
        multi-tile widths.  Returns one ``Lowered``."""
        if metric is None:
            metric = getattr(reducer_fn, "fused_metric", None)
        assert metric is not None, "fused lowering needs a Gram metric"
        shard_axes = tuple(shard_axes) if shard_axes is not None else None
        fn = _make_fused_jitted(metric, combine, mesh, shard_axes,
                                use_kernel, False, bl, None)
        x = jax.ShapeDtypeStruct(input_shape, dtype)
        buckets = tuple(
            (jax.ShapeDtypeStruct(b.idx.shape, jnp.int32),
             jax.ShapeDtypeStruct(b.mask.shape, jnp.bool_),
             jax.ShapeDtypeStruct((b.R,), jnp.int32))
            for b in plan.buckets)
        return fn.lower(x, buckets, None, plan.R, plan.L)


# ---------------------------------------------------------------------------
# sharded (LPT-balanced multi-device) executor
# ---------------------------------------------------------------------------
def _shard_mesh(mesh, shard_axes):
    """(mesh, axes, num_shards): the mesh + axis names the sharded executor
    partitions over.  ``mesh=None`` builds a 1-D mesh over all local
    devices (the CPU test path under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    if mesh is None:
        mesh = make_mesh((len(jax.devices()),), ("shard",))
        axes = ("shard",)
    else:
        axes = tuple(shard_axes) if shard_axes else tuple(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    num_shards = int(np.prod([sizes[a] for a in axes]))
    return mesh, axes, num_shards


def _stacked_groups(plan: ReducerPlan, part: PlanPartition,
                    rows_by_shard=None):
    """Stack the partition into uniform per-width device arrays.

    For every execution width ``w`` appearing in the partition, build
    ``idx (S, Rw, w)`` / ``mask (S, Rw, w)`` / ``rows (S, Rw)`` where
    ``Rw = max_s |shard s's width-w reducers|`` — each shard's rows padded
    (masked, rows -> plan.R) to the common count so ``shard_map`` can split
    the leading axis across the mesh.  LPT balances total work, so the
    cross-shard padding this stacking adds is small exactly when the
    balance factor is small.  Returns ``[(idx, mask, rows), ...]`` with
    widths ascending (numpy; the executor converts once per plan).

    ``rows_by_shard`` overrides the per-shard row sets (default: the
    partition's primary ``shard_rows``) — the coded executor passes
    ``part.replica_rows`` so every shard's stack holds all of its
    replicas, not just its primary assignment.
    """
    S = part.num_shards
    R0 = plan.num_reducers
    widths = part.widths
    if rows_by_shard is None:
        rows_by_shard = part.shard_rows
    # per-global-row source arrays at the row's execution width
    if plan.buckets:
        src_idx = {}
        src_mask = {}
        for b in plan.buckets:
            rows = np.asarray(b.rows)
            for i, g in enumerate(rows):
                if 0 <= g < R0:
                    src_idx[int(g)] = np.asarray(b.idx)[i]
                    src_mask[int(g)] = np.asarray(b.mask)[i]
    else:
        src_idx = {r: np.asarray(plan.idx)[r] for r in range(R0)}
        src_mask = {r: np.asarray(plan.mask)[r] for r in range(R0)}

    groups = []
    for w in sorted(set(int(x) for x in widths)) if R0 else []:
        per_shard = [rows[widths[rows] == w] for rows in rows_by_shard]
        Rw = max((len(p) for p in per_shard), default=0)
        if Rw == 0:
            continue
        idx = np.zeros((S, Rw, w), np.int32)
        mask = np.zeros((S, Rw, w), bool)
        rows_out = np.full((S, Rw), plan.R, np.int32)   # padding -> row R
        for s, p in enumerate(per_shard):
            for k, g in enumerate(p):
                idx[s, k, :] = src_idx[int(g)][:w]
                mask[s, k, :] = src_mask[int(g)][:w]
                rows_out[s, k] = int(g)
        groups.append((idx, mask, rows_out))
    return groups


def _sharded_srcmap(groups, m: int) -> np.ndarray:
    """Inverse-shuffle map for the cross-shard assembly gather: (m, m)
    int32 positions into ``[0.0, group_0.ravel(), group_1.ravel(), ...]``
    of the stacked per-width Gram outputs (each ``(S, Rw, w, w)``).
    Uncovered cells and the diagonal point at slot 0 (-> 0.0)."""
    srcmap = np.zeros((m, m), np.int32)
    base = 1
    for idx, mask, _rows in groups:
        S, Rw, w = idx.shape
        flat_idx = idx.reshape(S * Rw, w)
        flat_mask = mask.reshape(S * Rw, w)
        rows = np.broadcast_to(flat_idx[:, :, None], (S * Rw, w, w))
        cols = np.broadcast_to(flat_idx[:, None, :], (S * Rw, w, w))
        valid = flat_mask[:, :, None] & flat_mask[:, None, :]
        pos = np.arange(base, base + S * Rw * w * w,
                        dtype=np.int64).reshape(S * Rw, w, w)
        srcmap[rows[valid], cols[valid]] = pos[valid]
        base += S * Rw * w * w
    np.fill_diagonal(srcmap, 0)
    return srcmap


def _stacked_rect_groups(plan: ReducerPlan, part: PlanPartition,
                         rows_by_shard=None):
    """Rectangular analogue of :func:`_stacked_groups`: groups keyed by the
    (wx, wy) execution-width *pair*, each stacked into
    ``xidx/xmask (S, Rw, wx)``, ``yidx/ymask (S, Rw, wy)``, ``rows (S, Rw)``
    device arrays (padding rows masked, rows -> plan.R).  ``rows_by_shard``
    overrides the per-shard row sets as in :func:`_stacked_groups`."""
    S = part.num_shards
    R0 = plan.num_reducers
    widths = part.widths
    ywidths = part.ywidths
    if rows_by_shard is None:
        rows_by_shard = part.shard_rows
    src = {}
    if plan.buckets:
        for b in plan.buckets:
            rows = np.asarray(b.rows)
            for i, g in enumerate(rows):
                if 0 <= g < R0:
                    src[int(g)] = (np.asarray(b.idx)[i],
                                   np.asarray(b.mask)[i],
                                   np.asarray(b.yidx)[i],
                                   np.asarray(b.ymask)[i])
    else:
        for r in range(R0):
            src[r] = (np.asarray(plan.idx)[r], np.asarray(plan.mask)[r],
                      np.asarray(plan.yidx)[r], np.asarray(plan.ymask)[r])

    keys = sorted({(int(widths[r]), int(ywidths[r]))
                   for r in range(R0)}) if R0 else []
    groups = []
    for wx, wy in keys:
        per_shard = [rows[(widths[rows] == wx) & (ywidths[rows] == wy)]
                     for rows in rows_by_shard]
        Rw = max((len(p) for p in per_shard), default=0)
        if Rw == 0:
            continue
        xidx = np.zeros((S, Rw, wx), np.int32)
        xmask = np.zeros((S, Rw, wx), bool)
        yidx = np.zeros((S, Rw, wy), np.int32)
        ymask = np.zeros((S, Rw, wy), bool)
        rows_out = np.full((S, Rw), plan.R, np.int32)   # padding -> row R
        for s, p in enumerate(per_shard):
            for k, g in enumerate(p):
                xi, xm, yi, ym = src[int(g)]
                xidx[s, k, :] = xi[:wx]
                xmask[s, k, :] = xm[:wx]
                yidx[s, k, :] = yi[:wy]
                ymask[s, k, :] = ym[:wy]
                rows_out[s, k] = int(g)
        groups.append((xidx, xmask, yidx, ymask, rows_out))
    return groups


def _sharded_rect_srcmap(groups, shape: tuple[int, int]) -> np.ndarray:
    """Rectangular cross-shard assembly map: (mx, my) int32 positions into
    ``[0.0, group_0.ravel(), ...]`` of the stacked per-(wx, wy) cross-Gram
    outputs (each ``(S, Rw, wx, wy)``).  No diagonal to zero — an (x, y)
    pair is never a self-pair; uncovered cells point at slot 0."""
    mx, my = shape
    srcmap = np.zeros((mx, my), np.int32)
    base = 1
    for xidx, xmask, yidx, ymask, _rows in groups:
        S, Rw, wx = xidx.shape
        wy = yidx.shape[2]
        fx = xidx.reshape(S * Rw, wx)
        fxm = xmask.reshape(S * Rw, wx)
        fy = yidx.reshape(S * Rw, wy)
        fym = ymask.reshape(S * Rw, wy)
        rows = np.broadcast_to(fx[:, :, None], (S * Rw, wx, wy))
        cols = np.broadcast_to(fy[:, None, :], (S * Rw, wx, wy))
        valid = fxm[:, :, None] & fym[:, None, :]
        pos = np.arange(base, base + S * Rw * wx * wy,
                        dtype=np.int64).reshape(S * Rw, wx, wy)
        srcmap[rows[valid], cols[valid]] = pos[valid]
        base += S * Rw * wx * wy
    return srcmap


def _make_sharded_jitted(metric, combine, mesh, axes, use_kernel,
                         interpret, bl):
    from repro.kernels.pairwise.fused_gather_gram import (
        fused_gather_gram,
        fused_gather_gram_streamed,
    )

    P = jax.sharding.PartitionSpec

    def per_shard_fn(x, idx, msk):
        # local shapes: x (m, d) replicated, idx/msk (1, Rw, w)
        if use_kernel:
            g = fused_gather_gram(x, idx[0], msk[0], bl=bl,
                                  interpret=interpret)
        else:
            g = fused_gather_gram_streamed(x, idx[0], msk[0], bl=bl)
        mb = msk[0].astype(bool)
        return _finish_fused_blocks(g, mb, metric)[None]   # (1, Rw, w, w)

    def run(x, groups, srcmap, R, L):
        outs = []
        for idx, msk, rows in groups:
            g = shard_map(per_shard_fn, mesh=mesh,
                          in_specs=(P(), P(axes), P(axes)),
                          out_specs=P(axes))(x, idx, msk)
            outs.append((rows, g))
        if combine == "pairs":
            # ONE cross-shard assembly gather: concatenate the sharded
            # Gram stacks and gather the replicated (m, m) matrix through
            # the host-precomputed source map (XLA inserts the all-gather
            # here — the only cross-shard communication in the program)
            vals = [jnp.zeros((1,), jnp.float32)]
            vals += [g.reshape(-1) for _, g in outs]
            return jnp.take(jnp.concatenate(vals), srcmap, axis=0)
        # dense combine: scatter shard blocks (padded to the dense width)
        # back into original reducer order; padding rows drop into row R
        acc = jnp.zeros((R + 1, L, L), jnp.float32)
        for rows, g in outs:
            w = g.shape[-1]
            gp = jnp.pad(g, ((0, 0), (0, 0), (0, L - w), (0, L - w)))
            acc = acc.at[rows.reshape(-1)].set(gp.reshape(-1, L, L))
        return acc[:R]

    return jax.jit(run, static_argnums=(3, 4))


def _make_sharded_rect_jitted(metric, mesh, axes, use_kernel, interpret,
                              bl):
    from repro.kernels.pairwise.fused_gather_gram import (
        fused_gather_gram_rect,
        fused_gather_gram_rect_streamed,
    )

    P = jax.sharding.PartitionSpec

    def per_shard_fn(xt, yt, n2x, n2y, xidx, xmsk, yidx, ymsk):
        # local shapes: xt/yt/n2x/n2y replicated, idx/msk (1, Rw, w)
        if use_kernel:
            g = fused_gather_gram_rect(xt, yt, xidx[0], xmsk[0], yidx[0],
                                       ymsk[0], bl=bl, interpret=interpret)
        else:
            g = fused_gather_gram_rect_streamed(xt, yt, xidx[0], xmsk[0],
                                                yidx[0], ymsk[0], bl=bl)
        return _finish_rect_blocks(g, xidx[0], xmsk[0].astype(bool),
                                   yidx[0], ymsk[0].astype(bool),
                                   n2x, n2y, metric)[None]  # (1, Rw, wx, wy)

    def run(xt, yt, groups, srcmap):
        n2x = jnp.sum(xt.astype(jnp.float32) ** 2, axis=-1)
        n2y = jnp.sum(yt.astype(jnp.float32) ** 2, axis=-1)
        vals = [jnp.zeros((1,), jnp.float32)]
        for xidx, xmsk, yidx, ymsk, _rows in groups:
            g = shard_map(per_shard_fn, mesh=mesh,
                          in_specs=(P(), P(), P(), P(), P(axes), P(axes),
                                    P(axes), P(axes)),
                          out_specs=P(axes))(
                xt, yt, n2x, n2y, xidx, xmsk, yidx, ymsk)
            vals.append(g.reshape(-1))
        # ONE cross-shard assembly gather of the (mx, my) matrix — the
        # only cross-shard communication in the program
        return jnp.take(jnp.concatenate(vals), srcmap, axis=0)

    return jax.jit(run)


class ShardedExecutor(Executor):
    """Shard-balanced multi-device execution of a reducer plan.

    ``repro.core.planner.partition_plan`` LPT-balances the plan's reducers
    (weighted by per-reducer gather+FLOP work at their capacity-bucket
    width) into one compact sub-plan per shard of the mesh's reducer axis.
    The sub-plans are stacked into uniform per-width arrays and executed
    under ``shard_map``: every device runs the fused gather+Gram tile
    pipeline (streamed jnp twin off-TPU) over exactly its LPT-assigned
    reducers — instead of XLA's blind even row-split of a skew-ordered
    plan — and the only cross-shard communication is the single assembly
    gather of the (m, m) pair matrix at the end (``run_pairs``) or the
    dense scatter (``run``).

    ``mesh=None`` builds a 1-D mesh over all local devices — on CPU, run
    tests/benches under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    to get an 8-shard mesh.  Like the fused executor, only Gram-block
    reducers (``fused_metric`` tag) take the sharded path; anything else
    falls back to the bucketed executor (counted in ``stats()``).
    """

    name = "sharded"

    def _fresh_stats(self) -> dict:
        return {"calls": 0, "sharded": 0, "fallbacks": 0, "num_shards": 0,
                "balance_factor": 0.0}

    # -- partition plumbing (host-side static artifacts, cached on plan) --
    def partition(self, plan: ReducerPlan,
                  num_shards: int) -> PlanPartition:
        """The plan's LPT partition for ``num_shards`` (cached on the plan
        like the index matrix: a static artifact reused across waves)."""
        cache = plan.__dict__.get("_shard_partition_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_shard_partition_cache", cache)
        part = cache.get(num_shards)
        if part is None:
            part = partition_plan(plan, num_shards)
            cache[num_shards] = part
        return part

    def _groups_for(self, plan, part):
        cache = plan.__dict__.get("_shard_groups_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_shard_groups_cache", cache)
        groups = cache.get(part.num_shards)
        if groups is None:
            groups = _stacked_groups(plan, part)
            cache[part.num_shards] = groups
        return groups

    def _srcmap_for(self, plan, groups, num_shards: int, m: int):
        cache = plan.__dict__.get("_shard_srcmap_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_shard_srcmap_cache", cache)
        srcmap = cache.get((num_shards, m))
        if srcmap is None:
            srcmap = _sharded_srcmap(groups, m)
            cache[(num_shards, m)] = srcmap
        return srcmap

    def _rect_groups_for(self, plan, part):
        cache = plan.__dict__.get("_shard_rect_groups_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_shard_rect_groups_cache", cache)
        groups = cache.get(part.num_shards)
        if groups is None:
            groups = _stacked_rect_groups(plan, part)
            cache[part.num_shards] = groups
        return groups

    def _rect_srcmap_for(self, plan, groups, num_shards: int, shape):
        cache = plan.__dict__.get("_shard_rect_srcmap_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_shard_rect_srcmap_cache", cache)
        srcmap = cache.get((num_shards, shape))
        if srcmap is None:
            srcmap = _sharded_rect_srcmap(groups, shape)
            cache[(num_shards, shape)] = srcmap
        return srcmap

    def _note(self, part: PlanPartition) -> None:
        self._stats["num_shards"] = part.num_shards
        self._stats["balance_factor"] = float(part.balance_factor)
        _REGISTRY_OBS.gauge("executor.num_shards",
                            executor=self.name).set(part.num_shards)
        _REGISTRY_OBS.gauge("executor.balance_factor",
                            executor=self.name).set(part.balance_factor)

    def _dispatch(self, x, plan, metric, combine, srcmap_m, mesh,
                  shard_axes, use_kernel, interpret, bl,
                  workload: str = "reduce"):
        mesh, axes, S = _shard_mesh(mesh, shard_axes)
        part = self.partition(plan, S)
        groups = self._groups_for(plan, part)
        self._count("sharded")
        self._note(part)
        if _obs_config.ENABLED:
            assembled = 0
            meta = {"num_shards": S, "combine": combine}
            if combine == "pairs":
                _d, isz = _row_bytes(x)
                per_shard = int(_group_gram_entries(
                    plan, ("gram", S), groups) * isz * (S - 1) / S)
                assembled = S * per_shard
                meta["assembly_bytes_per_shard"] = per_shard
            self._reconcile(
                plan, workload, x,
                measured_slots=_group_valid_slots(
                    plan, ("sharded", S), groups, count_y=False),
                assembled_bytes=assembled, meta=meta)
        if combine == "pairs":
            srcmap = jnp.asarray(
                self._srcmap_for(plan, groups, S, srcmap_m))
        else:
            srcmap = jnp.zeros((1,), jnp.int32)      # unused placeholder
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        fn = _cache_get(
            ("sharded", metric, combine, mesh, axes, bool(use_kernel),
             bool(interpret), bl),
            lambda: _make_sharded_jitted(metric, combine, mesh, axes,
                                         use_kernel, interpret, bl))
        jgroups = tuple((jnp.asarray(i), jnp.asarray(k), jnp.asarray(r))
                        for i, k, r in groups)
        return fn(x, jgroups, srcmap, plan.R, plan.L)

    # -- protocol ----------------------------------------------------------
    def run(self, inputs, plan, reducer_fn, *, mesh=None, shard_axes=None,
            combine: str = "dense", use_kernel: Optional[bool] = None,
            interpret: bool = False, bl: int = 128, **kwargs):
        """Dense-combine semantics match ``run_reducers`` for Gram-block
        reducers; non-Gram reducers fall back to the bucketed executor
        (identical outputs — sharding is a pure execution-plan change)."""
        assert combine == "dense", combine
        self._count("calls")
        metric = getattr(reducer_fn, "fused_metric", None)
        if metric is None or plan.num_reducers == 0:
            self._count_fallback(
                "non_gram_reducer" if metric is None else "empty_plan")
            return run_reducers_bucketed(inputs, plan, reducer_fn,
                                         mesh=mesh, combine=combine)
        return self._dispatch(inputs, plan, metric, "dense", None, mesh,
                              shard_axes, use_kernel, interpret, bl)

    def run_pairs(self, x, plan, reducer_fn, m, *, mesh=None,
                  use_kernel=False, interpret=False):
        from .allpairs import assemble_pair_matrix_bucketed
        self._count("calls")
        metric = getattr(reducer_fn, "fused_metric", None)
        if metric is None or plan.num_reducers == 0:
            self._count_fallback(
                "non_gram_reducer" if metric is None else "empty_plan")
            self._reconcile(plan, "pairs", x,
                            measured_slots=_bucket_valid_slots(plan))
            per_bucket = run_reducers_bucketed(x, plan, reducer_fn,
                                               mesh=mesh, combine="buckets")
            return assemble_pair_matrix_bucketed(per_bucket, m)
        return self._dispatch(x, plan, metric, "pairs", m, mesh, None,
                              (True if use_kernel else None), interpret,
                              128, workload="pairs")

    def run_x2y(self, tables, plan, reducer_fn, shape, *, mesh=None,
                use_kernel=False, interpret=False, bl: int = 128):
        """LPT-balance the rectangular plan over the mesh (per-reducer work
        = wx + wy + flop·wx·wy), run the rectangular gather+Gram tile
        pipeline per shard under ``shard_map``, and assemble the (mx, my)
        matrix with ONE cross-shard gather.  Non-Gram reducers fall back
        to the rect-bucketed path (counted)."""
        from .allpairs import assemble_x2y_matrix_bucketed
        self._count("calls")
        metric = getattr(reducer_fn, "fused_metric", None)
        if metric is None or plan.num_reducers == 0:
            self._count_fallback(
                "non_gram_reducer" if metric is None else "empty_plan")
            self._reconcile(plan, "x2y", _as_tables(tables)[0],
                            measured_slots=_bucket_valid_slots(plan))
            per_bucket = run_reducers_x2y_bucketed(
                tables, plan, reducer_fn, mesh=mesh, combine="buckets")
            return assemble_x2y_matrix_bucketed(per_bucket, shape)
        mesh, axes, S = _shard_mesh(mesh, None)
        part = self.partition(plan, S)
        groups = self._rect_groups_for(plan, part)
        self._count("sharded")
        self._note(part)
        if _obs_config.ENABLED:
            xt0 = _as_tables(tables)[0]
            _d, isz = _row_bytes(xt0)
            per_shard = int(_group_gram_entries(
                plan, ("gram_rect", S), groups) * isz * (S - 1) / S)
            self._reconcile(
                plan, "x2y", xt0,
                measured_slots=_group_valid_slots(
                    plan, ("sharded_rect", S), groups, count_y=True),
                assembled_bytes=S * per_shard,
                meta={"num_shards": S,
                      "assembly_bytes_per_shard": per_shard})
        srcmap = jnp.asarray(
            self._rect_srcmap_for(plan, groups, S, tuple(shape)))
        uk = True if use_kernel else jax.default_backend() == "tpu"
        fn = _cache_get(
            ("sharded-x2y", metric, mesh, axes, bool(uk), bool(interpret),
             bl),
            lambda: _make_sharded_rect_jitted(metric, mesh, axes, uk,
                                              interpret, bl))
        jgroups = tuple(
            tuple(jnp.asarray(a) for a in grp) for grp in groups)
        xt, yt = _as_tables(tables)
        return fn(xt, yt, jgroups, srcmap)

    def lower(self, input_shape, plan, *, reducer_fn=None, metric=None,
              mesh=None, dtype=jnp.float32, shard_axes=None,
              combine: str = "pairs", m: Optional[int] = None,
              use_kernel: bool = False, bl: int = 128, **kwargs):
        """Lower the sharded program (no execution) for dry-run/roofline.

        ``combine='pairs'`` (default) lowers the full pipeline including
        the cross-shard assembly gather of the ``(m, m)`` matrix
        (``m`` defaults to ``input_shape[0]``); ``combine='dense'`` lowers
        the dense-combine scatter form.  Returns one ``Lowered``.
        """
        if metric is None:
            metric = getattr(reducer_fn, "fused_metric", None)
        assert metric is not None, "sharded lowering needs a Gram metric"
        mesh, axes, S = _shard_mesh(mesh, shard_axes)
        part = self.partition(plan, S)
        groups = self._groups_for(plan, part)
        if combine == "pairs":
            mm = m if m is not None else input_shape[0]
            srcmap = jax.ShapeDtypeStruct((mm, mm), jnp.int32)
        else:
            srcmap = jax.ShapeDtypeStruct((1,), jnp.int32)
        fn = _make_sharded_jitted(metric, combine, mesh, axes,
                                  use_kernel, False, bl)
        x = jax.ShapeDtypeStruct(input_shape, dtype)
        sgroups = tuple(
            (jax.ShapeDtypeStruct(i.shape, jnp.int32),
             jax.ShapeDtypeStruct(k.shape, jnp.bool_),
             jax.ShapeDtypeStruct(r.shape, jnp.int32))
            for i, k, r in groups)
        return fn.lower(x, sgroups, srcmap, plan.R, plan.L)


# ---------------------------------------------------------------------------
# coded (replicated shuffle) executor
# ---------------------------------------------------------------------------
def _coded_maps(groups, shape: tuple[int, int], row_block: int,
                zero_diag: bool):
    """Host-side maps for the coded combining stage.

    ``groups`` are replica-stacked rect groups
    ``[(xidx (S,Rw,wx), xmask, yidx (S,Rw,wy), ymask, rows (S,Rw)), ...]``
    where ``rows`` holds each shard's full replica set (padding slots have
    all-false masks and are skipped).  The output ``(mx, my)`` matrix is
    row-sliced: shard ``s`` owns rows ``[s*row_block, (s+1)*row_block)``.

    Per output cell the serving Gram entry is resolved to either a
    position in the owning shard's *local* value vector (a replica is
    held: zero traffic) or a slot in the residual exchange: for every
    (block, destination) pair with no local replica, the block rows whose
    output rows fall in the destination's slice — never the whole block —
    are stride-split across ALL replica holders (least-filled lane
    first), so each holder ships ~1/r of the residual and the exchange
    lanes shrink as replication grows.  The residual is batched into
    per-destination lanes and moved by ONE tiled all-to-all sized by the
    maximum lane.

    Returns ``(sendmap (S, S, E) int32`` into the shard-local value
    vector, ``srcmap (S, row_block, my) int32`` into
    ``[vals_local (Lv), recv (S*E)]``, and a stats dict).  Slot 0 of the
    value vector is 0.0 (uncovered cells, padding lanes, the diagonal).
    """
    mx, my = shape
    S = groups[0][0].shape[0] if groups else 1
    bases = []
    Lv = 1
    for xidx, _xm, yidx, _ym, _rows in groups:
        bases.append(Lv)
        Lv += xidx.shape[1] * xidx.shape[2] * yidx.shape[2]

    # holders: global row -> [(shard, group, slot), ...] (replica set)
    holders: dict[int, list] = {}
    for gi, (_xi, xmask, _yi, ymask, rows) in enumerate(groups):
        live = xmask.any(axis=2) & ymask.any(axis=2)      # (S, Rw)
        for s, k in np.argwhere(live):
            holders.setdefault(int(rows[s, k]), []).append(
                (int(s), gi, int(k)))

    send: list[list[list]] = [[[] for _ in range(S)] for _ in range(S)]
    cnt = np.zeros((S, S), dtype=np.int64)
    recv_fill: list[list] = [[] for _ in range(S)]
    srcmap = np.zeros((S, row_block, my), dtype=np.int64)
    local_entries = 0
    for _b, hl in holders.items():
        s0, gi, k0 = hl[0]
        xidx, xmask, yidx, ymask, _rows = groups[gi]
        wx, wy = xidx.shape[2], yidx.shape[2]
        pv = np.flatnonzero(xmask[s0, k0])
        qv = np.flatnonzero(ymask[s0, k0])
        if not pv.size or not qv.size:
            continue
        gx = xidx[s0, k0][pv].astype(np.int64)
        gy = yidx[s0, k0][qv].astype(np.int64)
        ds = gx // row_block
        hpos = {s: bases[g] + k * wx * wy for s, g, k in hl}
        for s in np.unique(ds):
            s = int(s)
            sel = ds == s
            p_s, gx_s = pv[sel], gx[sel]
            if s in hpos:                      # local replica: no traffic
                pos = hpos[s] + (p_s[:, None] * wy + qv[None, :])
                srcmap[s][np.ix_(gx_s - s * row_block, gy)] = pos
                local_entries += pos.size
            else:                              # residual: split over holders
                hs = sorted(hpos, key=lambda tt: cnt[tt, s])
                for j, t in enumerate(hs):
                    p_j, gx_j = p_s[j::len(hs)], gx_s[j::len(hs)]
                    if not p_j.size:
                        continue
                    pos = hpos[t] + (p_j[:, None] * wy + qv[None, :])
                    send[t][s].append(pos.ravel())
                    recv_fill[s].append((t, int(cnt[t, s]), gx_j, gy))
                    cnt[t, s] += pos.size
    E = max(1, int(cnt.max(initial=0)))
    sendmap = np.zeros((S, S, E), dtype=np.int64)
    for t in range(S):
        for s in range(S):
            if send[t][s]:
                v = np.concatenate(send[t][s])
                sendmap[t, s, :len(v)] = v
    for s in range(S):
        for t, e0, gx_s, gy in recv_fill[s]:
            e = e0 + np.arange(len(gx_s) * len(gy), dtype=np.int64)
            srcmap[s][np.ix_(gx_s - s * row_block, gy)] = (
                Lv + t * E + e.reshape(len(gx_s), len(gy)))
    if zero_diag:
        for s in range(S):
            d = np.arange(s * row_block, min((s + 1) * row_block, mx))
            srcmap[s, d - s * row_block, d] = 0
    stats = {
        "local_entries": int(local_entries),
        "residual_entries": int(cnt.sum()),
        "lane_max": E,
        "lane_fill": float(cnt.sum() / max(S * S * E, 1)),
        "vals_len": int(Lv),
    }
    return (sendmap.astype(np.int32), srcmap.astype(np.int32), stats)


def _make_coded_jitted(metric, mesh, axes, use_kernel, interpret, bl):
    from repro.compat import all_to_all
    from repro.kernels.pairwise.fused_gather_gram import (
        fused_gather_gram_rect,
        fused_gather_gram_rect_streamed,
    )

    P = jax.sharding.PartitionSpec

    def per_shard_fn(xt, yt, n2x, n2y, groups, sendmap, srcmap):
        # local shapes: tables/norms replicated, stacks (1, Rw, w),
        # sendmap (1, S, E), srcmap (1, row_block, my)
        vals = [jnp.zeros((1,), jnp.float32)]
        for xidx, xmsk, yidx, ymsk in groups:
            if use_kernel:
                g = fused_gather_gram_rect(xt, yt, xidx[0], xmsk[0],
                                           yidx[0], ymsk[0], bl=bl,
                                           interpret=interpret)
            else:
                g = fused_gather_gram_rect_streamed(xt, yt, xidx[0],
                                                    xmsk[0], yidx[0],
                                                    ymsk[0], bl=bl)
            g = _finish_rect_blocks(g, xidx[0], xmsk[0].astype(bool),
                                    yidx[0], ymsk[0].astype(bool),
                                    n2x, n2y, metric)
            vals.append(g.reshape(-1))
        vloc = jnp.concatenate(vals)
        # coded combining: replicas serve locally through srcmap; ONLY the
        # residual lanes cross shards, in one batched tiled all-to-all —
        # there is no all-gather of the Gram stacks in this program
        send = jnp.take(vloc, sendmap[0], axis=0)          # (S, E)
        recv = all_to_all(send, axes)                      # (S, E)
        full = jnp.concatenate([vloc, recv.reshape(-1)])
        return jnp.take(full, srcmap[0], axis=0)[None]     # (1, rb, my)

    def run(xt, yt, groups, sendmap, srcmap):
        n2x = jnp.sum(xt.astype(jnp.float32) ** 2, axis=-1)
        n2y = jnp.sum(yt.astype(jnp.float32) ** 2, axis=-1)
        out = shard_map(per_shard_fn, mesh=mesh,
                        in_specs=(P(), P(), P(), P(), P(axes), P(axes),
                                  P(axes)),
                        out_specs=P(axes))(
            xt, yt, n2x, n2y, groups, sendmap, srcmap)
        return out.reshape(-1, out.shape[-1])   # (S*rb, my); caller trims

    return jax.jit(run)


class CodedExecutor(ShardedExecutor):
    """Coded shuffle execution: trade replication for cross-shard traffic.

    The sharded executor pays ONE cross-shard all-gather to assemble the
    replicated (m, m) matrix — every shard receives every Gram stack.  The
    coded executor (the coded-MapReduce tradeoff of Afrati et al.,
    arXiv:1206.4377) spends replication to cut that traffic:
    ``partition_plan(..., replication=r)`` materializes each reducer's
    sub-plan on r LPT-chosen shards, the output matrix is row-sliced
    across shards, and assembly becomes a coded combining stage — a shard
    holding a replica serves its slice's cells from local Gram entries
    (zero traffic), and only the residual entries (block rows owned by a
    slice with no replica) are exchanged, batched into per-destination
    lanes and moved by ONE tiled all-to-all.  Per shard the residual is
    ~``2G/S * (1 - r/S)`` entries (G = total Gram entries) vs ~``G`` for
    the uncoded all-gather, so measured assembly bytes collapse and keep
    falling as r grows; ``choose_replication`` picks the knee of the
    replication-vs-communication frontier.

    Same fallback rules as the sharded executor (Gram-block reducers
    only); ``replication`` is clamped to the mesh's shard count.
    """

    name = "coded"

    def __init__(self, stats: Optional[dict] = None, replication: int = 2):
        super().__init__(stats=stats)
        self.replication = int(replication)

    def _fresh_stats(self) -> dict:
        return {"calls": 0, "coded": 0, "fallbacks": 0, "num_shards": 0,
                "balance_factor": 0.0, "replication": 0,
                "local_entries": 0, "residual_entries": 0,
                "local_fraction": 0.0}

    # -- replication-aware partition plumbing (cached on the plan) --------
    def partition_coded(self, plan: ReducerPlan, num_shards: int,
                        replication: Optional[int] = None) -> PlanPartition:
        r = min(self.replication if replication is None else int(replication),
                num_shards)
        cache = plan.__dict__.get("_coded_partition_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_coded_partition_cache", cache)
        part = cache.get((num_shards, r))
        if part is None:
            part = partition_plan(plan, num_shards, replication=r)
            cache[(num_shards, r)] = part
        return part

    def _coded_groups_for(self, plan, part, rect: bool):
        cache = plan.__dict__.get("_coded_groups_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_coded_groups_cache", cache)
        key = (part.num_shards, part.replication, rect)
        groups = cache.get(key)
        if groups is None:
            if rect:
                groups = _stacked_rect_groups(
                    plan, part, rows_by_shard=part.replica_rows)
            else:
                groups = [(i, k, i, k, r) for i, k, r in _stacked_groups(
                    plan, part, rows_by_shard=part.replica_rows)]
            cache[key] = groups
        return groups

    def _coded_maps_for(self, plan, groups, part, shape, zero_diag: bool):
        cache = plan.__dict__.get("_coded_maps_cache")
        if cache is None:
            cache = {}
            object.__setattr__(plan, "_coded_maps_cache", cache)
        key = (part.num_shards, part.replication, tuple(shape), zero_diag)
        maps = cache.get(key)
        if maps is None:
            rb = -(-shape[0] // part.num_shards)
            maps = _coded_maps(groups, tuple(shape), rb, zero_diag)
            cache[key] = maps
        return maps

    def _note_coded(self, part: PlanPartition, mstats: dict) -> None:
        self._note(part)
        self._stats["replication"] = int(part.replication)
        self._stats["local_entries"] = mstats["local_entries"]
        self._stats["residual_entries"] = mstats["residual_entries"]
        tot = mstats["local_entries"] + mstats["residual_entries"]
        self._stats["local_fraction"] = (
            mstats["local_entries"] / tot if tot else 1.0)
        _REGISTRY_OBS.gauge("executor.replication",
                            executor=self.name).set(part.replication)
        _REGISTRY_OBS.gauge("executor.local_fraction",
                            executor=self.name).set(
                                self._stats["local_fraction"])

    def _coded_dispatch(self, xt, yt, plan, metric, shape, zero_diag,
                        mesh, shard_axes, use_kernel, interpret, bl,
                        rect: bool, workload: str = "pairs"):
        mesh, axes, S = _shard_mesh(mesh, shard_axes)
        part = self.partition_coded(plan, S)
        groups = self._coded_groups_for(plan, part, rect)
        sendmap, srcmap, mstats = self._coded_maps_for(
            plan, groups, part, shape, zero_diag)
        self._count("coded")
        self._note_coded(part, mstats)
        if _obs_config.ENABLED:
            # identical ring accounting to ``coded_assembly_model``:
            # residual lanes x itemsize x (S-1)/S per shard
            _d, isz = _row_bytes(xt)
            frac = (S - 1) / S if S > 1 else 0.0
            per_shard = int(sendmap.shape[1] * sendmap.shape[2]
                            * isz * frac)
            self._reconcile(
                plan, workload, xt,
                measured_slots=_group_valid_slots(
                    plan, ("coded", S, part.replication, rect), groups,
                    count_y=rect),
                replication=float(part.replication),
                assembled_bytes=S * per_shard,
                local_bytes=int(mstats["local_entries"]) * isz,
                residual_bytes=int(mstats["residual_entries"]) * isz,
                meta={"num_shards": S,
                      "replication": int(part.replication),
                      "assembly_bytes_per_shard": per_shard,
                      "lane_max": mstats["lane_max"]})
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        fn = _cache_get(
            ("coded", metric, mesh, axes, bool(use_kernel),
             bool(interpret), bl),
            lambda: _make_coded_jitted(metric, mesh, axes, use_kernel,
                                       interpret, bl))
        jgroups = tuple(
            (jnp.asarray(xi), jnp.asarray(xm), jnp.asarray(yi),
             jnp.asarray(ym))
            for xi, xm, yi, ym, _rows in groups)
        out = fn(xt, yt, jgroups, jnp.asarray(sendmap),
                 jnp.asarray(srcmap))
        return out[:shape[0]]

    # -- protocol ----------------------------------------------------------
    def run_pairs(self, x, plan, reducer_fn, m, *, mesh=None,
                  use_kernel=False, interpret=False):
        from .allpairs import assemble_pair_matrix_bucketed
        self._count("calls")
        metric = getattr(reducer_fn, "fused_metric", None)
        if metric is None or plan.num_reducers == 0:
            self._count_fallback(
                "non_gram_reducer" if metric is None else "empty_plan")
            self._reconcile(plan, "pairs", x,
                            measured_slots=_bucket_valid_slots(plan))
            per_bucket = run_reducers_bucketed(x, plan, reducer_fn,
                                               mesh=mesh, combine="buckets")
            return assemble_pair_matrix_bucketed(per_bucket, m)
        x = jnp.asarray(x)
        return self._coded_dispatch(
            x, x, plan, metric, (m, m), True, mesh, None,
            (True if use_kernel else None), interpret, 128, rect=False,
            workload="pairs")

    def run_x2y(self, tables, plan, reducer_fn, shape, *, mesh=None,
                use_kernel=False, interpret=False, bl: int = 128):
        from .allpairs import assemble_x2y_matrix_bucketed
        self._count("calls")
        metric = getattr(reducer_fn, "fused_metric", None)
        if metric is None or plan.num_reducers == 0:
            self._count_fallback(
                "non_gram_reducer" if metric is None else "empty_plan")
            self._reconcile(plan, "x2y", _as_tables(tables)[0],
                            measured_slots=_bucket_valid_slots(plan))
            per_bucket = run_reducers_x2y_bucketed(
                tables, plan, reducer_fn, mesh=mesh, combine="buckets")
            return assemble_x2y_matrix_bucketed(per_bucket, shape)
        uk = True if use_kernel else None
        xt, yt = _as_tables(tables)
        return self._coded_dispatch(
            xt, yt, plan, metric, tuple(shape), False, mesh, None, uk,
            interpret, bl, rect=True, workload="x2y")

    def lower(self, input_shape, plan, *, reducer_fn=None, metric=None,
              mesh=None, dtype=jnp.float32, shard_axes=None,
              m: Optional[int] = None, replication: Optional[int] = None,
              use_kernel: bool = False, bl: int = 128, **kwargs):
        """Lower the coded all-pairs program (no execution) for dry-run /
        roofline: per-shard rect tile pipeline + the residual all-to-all.
        ``replication`` overrides the instance rate (clamped to the
        mesh's shard count); the send/recv lane sizes baked into the
        lowered shapes are the real host-computed ones, so HLO collective
        bytes measure the actual coded exchange."""
        if metric is None:
            metric = getattr(reducer_fn, "fused_metric", None)
        assert metric is not None, "coded lowering needs a Gram metric"
        mesh, axes, S = _shard_mesh(mesh, shard_axes)
        part = self.partition_coded(plan, S, replication)
        groups = self._coded_groups_for(plan, part, rect=False)
        mm = m if m is not None else input_shape[0]
        sendmap, srcmap, _ = self._coded_maps_for(
            plan, groups, part, (mm, mm), True)
        fn = _make_coded_jitted(metric, mesh, axes, use_kernel, False, bl)
        x = jax.ShapeDtypeStruct(input_shape, dtype)
        sgroups = tuple(
            (jax.ShapeDtypeStruct(xi.shape, jnp.int32),
             jax.ShapeDtypeStruct(xm.shape, jnp.bool_),
             jax.ShapeDtypeStruct(yi.shape, jnp.int32),
             jax.ShapeDtypeStruct(ym.shape, jnp.bool_))
            for xi, xm, yi, ym, _rows in groups)
        return fn.lower(x, x, sgroups,
                        jax.ShapeDtypeStruct(sendmap.shape, jnp.int32),
                        jax.ShapeDtypeStruct(srcmap.shape, jnp.int32))


def coded_assembly_model(plan, num_shards: int, replication: int, m: int,
                         *, itemsize: int = 4) -> dict:
    """Analytic bytes of the coded combining stage at replication ``r`` —
    host-only (builds the real send/recv maps, lowers nothing).

    ``assembly_bytes_per_shard`` uses the same ring accounting as the
    roofline HLO parser (result bytes x (S-1)/S for the tiled
    all-to-all), so model and measured numbers are directly comparable;
    ``uncoded_assembly_bytes_per_shard`` is the sharded executor's
    all-gather of the full primary Gram stacks under the same accounting.
    """
    S = int(num_shards)
    r = min(int(replication), S)
    part = partition_plan(plan, S, replication=r)
    sq = _stacked_groups(plan, part, rows_by_shard=part.replica_rows)
    groups = [(i, k, i, k, rows) for i, k, rows in sq]
    rb = -(-int(m) // S)
    sendmap, _srcmap, st = _coded_maps(groups, (int(m), int(m)), rb, True)
    frac = (S - 1) / S if S > 1 else 0.0
    primary = _stacked_groups(plan, part)
    gram_entries = sum(int(np.prod(i.shape[:2])) * i.shape[2] ** 2
                       for i, _k, _r in primary)
    return {
        "replication": r,
        "num_shards": S,
        "local_entries": st["local_entries"],
        "residual_entries": st["residual_entries"],
        "local_fraction": (
            st["local_entries"]
            / max(st["local_entries"] + st["residual_entries"], 1)),
        "lane_max": st["lane_max"],
        "lane_fill": st["lane_fill"],
        "assembly_bytes_per_shard": int(sendmap.shape[1] * sendmap.shape[2]
                                        * itemsize * frac),
        "uncoded_assembly_bytes_per_shard": int(gram_entries * itemsize
                                                * frac),
        "replica_slots": [int(x) for x in part.replica_slots],
    }


def choose_replication(plan, num_shards: int, m: int, d: int, *,
                       itemsize: int = 4,
                       candidates=None) -> tuple[int, list[dict]]:
    """Auto-``r``: sweep the replication-vs-communication frontier and
    pick the knee for ``num_shards`` shards.

    Total cluster communication at replication r =
    ``r x shipped input bytes`` (every replica shard receives its
    sub-plan's input rows: the paper's map->reduce cost scales linearly
    with r) ``+ S x assembly bytes per shard`` (falls with r as replicas
    serve locally).  The knee is the argmin of that total — past it,
    extra replicas ship more input rows than they save in assembly.
    Returns ``(best_r, frontier)`` with one model row per candidate,
    each including the total and both terms.
    """
    S = int(num_shards)
    if candidates is None:
        candidates = []
        r = 1
        while r <= S:
            candidates.append(r)
            r *= 2
    shipped_bytes = float(plan.comm_cost) * d * itemsize
    frontier = []
    for r in sorted(set(min(int(c), S) for c in candidates)):
        rec = coded_assembly_model(plan, S, r, m, itemsize=itemsize)
        rec["shipped_bytes"] = r * shipped_bytes
        rec["total_comm_bytes"] = (rec["shipped_bytes"]
                                   + S * rec["assembly_bytes_per_shard"])
        frontier.append(rec)
    best = min(frontier, key=lambda rec: rec["total_comm_bytes"])
    return best["replication"], frontier


# ---------------------------------------------------------------------------
# default registry instances
# ---------------------------------------------------------------------------
# Every default instance owns its counters (no shared module-level dicts:
# a service resetting its own executor can never zero another caller's
# telemetry).  ``engine.fused_stats()`` stays live as the documented
# aggregate view — ``_count`` publishes each increment into the obs
# registry's per-executor-name series, which that shim reads.
register_executor(DenseExecutor())
register_executor(BucketedExecutor())
register_executor(FusedExecutor())
register_executor(ShardedExecutor())
register_executor(CodedExecutor())
