"""Mapping schema -> static gather plan -> sharded reducer execution.

The MapReduce shuffle of the paper is adapted to TPU/JAX as follows
(DESIGN.md "hardware adaptation"):

  * reducers become *reducer slots*, a leading array dimension sharded across
    the device mesh;
  * the map->reduce shuffle becomes ``jnp.take`` from the input array with a
    static index matrix computed from the schema — XLA lowers this to
    all-gather/collective traffic whose volume is the schema's communication
    cost (this is what the roofline benchmark measures);
  * the reduce function is vmapped over slots, so every device processes its
    slots in parallel (the MXU does the per-reducer all-pairs work through
    the Pallas ``pairwise`` kernel).

Three executors share the plan format:

``run_reducers``           — the dense path: one gather padded to the global
                             max slot count.  Simple, one XLA program, but a
                             single heavy reducer forces every other reducer
                             to pad to its width — quadratic waste for
                             reducer functions like the all-pairs Gram block.
``run_reducers_bucketed``  — the skew-aware path (DESIGN.md "bucketed shuffle
                             execution"): reducers are grouped into capacity
                             buckets (powers-of-two over per-reducer slot
                             counts, ``repro.core.planner.compute_buckets``),
                             one vmapped gather+reduce per bucket, each
                             padded only to its own bucket width, outputs
                             reassembled in original reducer order.
``run_reducers_fused``     — the fused path (DESIGN.md "fused shuffle
                             execution"): for Gram-block reducers the
                             shuffle streams straight into the MXU through
                             the fused gather+Gram Pallas kernel — the
                             padded gather never round-trips through HBM,
                             and all buckets run in one program.  Non-Gram
                             reducers fall back to the bucketed path.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import compute_buckets
from repro.core.schema import MappingSchema

__all__ = [
    "ReducerBucket",
    "ReducerPlan",
    "build_plan",
    "run_reducers",
    "run_reducers_bucketed",
    "run_reducers_fused",
    "lower_reducers",
    "lower_reducers_bucketed",
    "lower_reducers_fused",
    "jit_cache_stats",
    "fused_stats",
    "reset_fused_stats",
]


@dataclasses.dataclass(frozen=True)
class ReducerBucket:
    """One capacity bucket of the plan: reducers padded to a shared width.

    rows  (Rb,) int64 — original plan-row ids in bucket order; -1 marks a
          padding row added so the bucket divides the device count.
    idx   (Rb, width) int32 / mask (Rb, width) bool — same layout as the
          dense plan, but only ``width`` slots wide.
    """

    width: int
    rows: np.ndarray
    idx: np.ndarray
    mask: np.ndarray

    @property
    def R(self) -> int:
        return int(self.idx.shape[0])

    @property
    def num_real(self) -> int:
        return int(np.sum(self.rows >= 0))

    @property
    def padded_elements(self) -> int:
        return self.R * self.width


@dataclasses.dataclass(frozen=True)
class ReducerPlan:
    """Static arrays derived from a MappingSchema.

    idx   (R, L) int32 — input ids per reducer slot; padded entries point at
          input 0 and are masked out.
    mask  (R, L) bool  — slot validity.
    buckets — capacity buckets over the same reducers (skew-aware executor);
          every real reducer row appears in exactly one bucket.

    The plan also carries the schema's provenance so downstream telemetry
    (benchmarks, serving dashboards) can report which registry strategy
    produced the traffic and how far it sits from the paper's
    replication-rate lower bound.
    """

    idx: np.ndarray
    mask: np.ndarray
    num_reducers: int          # before padding
    comm_cost: float           # schema communication cost (weighted bytes)
    max_inputs: int
    algorithm: str = "unknown"             # winning strategy (provenance)
    lower_bound: Optional[float] = None    # paper's comm lower bound
    buckets: tuple[ReducerBucket, ...] = ()

    @property
    def R(self) -> int:
        return int(self.idx.shape[0])

    @property
    def L(self) -> int:
        return int(self.idx.shape[1])

    @property
    def optimality_gap(self) -> Optional[float]:
        """comm_cost / lower_bound (>= 1.0), or None without a bound."""
        if self.lower_bound is None or self.lower_bound <= 0.0:
            return None
        return self.comm_cost / self.lower_bound

    # ---------------------------------------------------------- telemetry
    @property
    def dense_padded_elements(self) -> int:
        """Gather slots the dense executor materializes (R x L)."""
        return self.R * self.L

    @property
    def bucketed_padded_elements(self) -> int:
        """Gather slots the bucketed executor materializes."""
        if not self.buckets:
            return self.dense_padded_elements
        return sum(b.padded_elements for b in self.buckets)

    @property
    def padding_savings(self) -> float:
        """dense / bucketed padded elements (>= 1.0 up to row padding)."""
        return self.dense_padded_elements / max(self.bucketed_padded_elements,
                                                1)

    def bucket_widths(self) -> list[int]:
        return [b.width for b in self.buckets]


def _build_buckets(expanded: list[list[int]], *, pad_slots_to: int,
                   pad_reducers_to: int,
                   max_buckets: int) -> tuple[ReducerBucket, ...]:
    """Capacity buckets over expanded reducers (original row order kept
    within each bucket; rows padded to a multiple of ``pad_reducers_to``)."""
    counts = [len(ids) for ids in expanded]
    out = []
    for width, rows in compute_buckets(counts, pad_slots_to=pad_slots_to,
                                       max_buckets=max_buckets):
        Rb = -(-max(len(rows), 1) // pad_reducers_to) * pad_reducers_to
        idx = np.zeros((Rb, width), dtype=np.int32)
        mask = np.zeros((Rb, width), dtype=bool)
        rows_padded = np.full(Rb, -1, dtype=np.int64)
        rows_padded[: len(rows)] = rows
        for i, r in enumerate(rows):
            ids = expanded[r]
            idx[i, : len(ids)] = ids
            mask[i, : len(ids)] = True
        out.append(ReducerBucket(width=width, rows=rows_padded, idx=idx,
                                 mask=mask))
    return tuple(out)


def build_plan(schema: MappingSchema, *, pad_reducers_to: int = 1,
               pad_slots_to: int = 1, max_buckets: int = 8) -> ReducerPlan:
    """Flatten a schema into (idx, mask) plus capacity buckets.

    ``pad_reducers_to`` rounds reducer counts up to a multiple (device
    count) — applied to the dense plan and to every bucket independently;
    ``pad_slots_to`` rounds slot counts (kernel tile alignment);
    ``max_buckets`` bounds the number of capacity buckets (dispatch
    overhead of the bucketed executor)."""
    expanded = schema.expand()
    R0 = len(expanded)
    L0 = max((len(ids) for ids in expanded), default=1)
    L = -(-L0 // pad_slots_to) * pad_slots_to
    R = -(-max(R0, 1) // pad_reducers_to) * pad_reducers_to
    idx = np.zeros((R, L), dtype=np.int32)
    mask = np.zeros((R, L), dtype=bool)
    for r, ids in enumerate(expanded):
        idx[r, : len(ids)] = ids
        mask[r, : len(ids)] = True
    buckets = _build_buckets(expanded, pad_slots_to=pad_slots_to,
                             pad_reducers_to=pad_reducers_to,
                             max_buckets=max_buckets)
    return ReducerPlan(idx=idx, mask=mask, num_reducers=R0,
                       comm_cost=schema.communication_cost(), max_inputs=L0,
                       algorithm=schema.algorithm,
                       lower_bound=schema.lower_bound,
                       buckets=buckets)


def _shardings(mesh, shard_axes):
    axes = shard_axes if shard_axes is not None else mesh.axis_names
    P = jax.sharding.PartitionSpec
    red = jax.sharding.NamedSharding(mesh, P(axes))
    rep = jax.sharding.NamedSharding(mesh, P())
    return red, rep


def _gather_reduce(x, idx, mask, reducer_fn):
    gathered = jnp.take(x, idx, axis=0)          # (R, L, d) — the shuffle
    gathered = jnp.where(mask[..., None], gathered, 0)
    return jax.vmap(reducer_fn)(gathered, mask)


# One jitted executable per (reducer_fn, mesh, shard_axes): repeated calls —
# a serving loop, the benchmark's timed iterations, every bucket of a
# bucketed run — reuse the XLA compile cache instead of re-tracing through
# a fresh jax.jit wrapper each time.  Callers enable reuse by passing the
# *same* reducer_fn object (see allpairs._block_fn).
#
# The cache is a bounded LRU: a long-running PairwiseService loop that keeps
# constructing *fresh* reducer closures (defeating the reuse contract) evicts
# its oldest entries instead of growing without limit.  ``jit_cache_stats``
# feeds the serving telemetry.
_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_MAX = 64
_JIT_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _cache_get(key, factory):
    fn = _JIT_CACHE.get(key)
    if fn is None:
        _JIT_CACHE_STATS["misses"] += 1
        fn = factory()
        _JIT_CACHE[key] = fn
        while len(_JIT_CACHE) > _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
            _JIT_CACHE_STATS["evictions"] += 1
    else:
        _JIT_CACHE_STATS["hits"] += 1
        _JIT_CACHE.move_to_end(key)
    return fn


def jit_cache_stats() -> dict:
    """Engine jit-cache counters (size / hits / misses / evictions)."""
    return {**_JIT_CACHE_STATS, "size": len(_JIT_CACHE),
            "max_size": _JIT_CACHE_MAX}


def _get_jitted(reducer_fn, mesh, shard_axes):
    def factory():
        run = partial(_gather_reduce, reducer_fn=reducer_fn)
        if mesh is None:
            return jax.jit(run)
        red_sharding, rep = _shardings(mesh, shard_axes)
        return jax.jit(run,
                       in_shardings=(rep, red_sharding, red_sharding),
                       out_shardings=red_sharding)
    return _cache_get((reducer_fn, mesh, shard_axes), factory)


def run_reducers(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    donate: bool = False,
):
    """Execute ``reducer_fn(block (L, d), mask (L,)) -> pytree`` per reducer.

    With a mesh, reducer slots are sharded over ``shard_axes`` (all mesh axes
    by default) and the input table is left replicated — the gather *is* the
    map->reduce shuffle.  Without a mesh, runs locally (CPU tests).
    """
    idx = jnp.asarray(plan.idx)
    mask = jnp.asarray(plan.mask)
    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted(reducer_fn, mesh, shard_axes)
    return fn(inputs, idx, mask)


# ---------------------------------------------------------------------------
# bucketed (skew-aware) executor
# ---------------------------------------------------------------------------
def _dense_out_shapes(plan: ReducerPlan, reducer_fn, inputs):
    """Per-reducer output ShapeDtypes at the dense width L."""
    blk = jax.ShapeDtypeStruct((plan.L,) + inputs.shape[1:], inputs.dtype)
    msk = jax.ShapeDtypeStruct((plan.L,), jnp.bool_)
    return jax.eval_shape(reducer_fn, blk, msk)


def _pad_leaf_to(leaf, target_shape):
    """Zero-pad trailing extents of ``leaf`` (past its leading batch axis)
    up to ``target_shape`` — the slot-sized axes grow from bucket width to
    the dense width; equal axes are untouched."""
    pads = [(0, 0)]
    for have, want in zip(leaf.shape[1:], target_shape):
        assert have <= want, (leaf.shape, target_shape)
        pads.append((0, want - have))
    if any(p != (0, 0) for p in pads):
        leaf = jnp.pad(leaf, pads)
    return leaf


def run_reducers_bucketed(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    combine: str = "dense",
):
    """Skew-aware execution: one vmapped gather+reduce per capacity bucket.

    Each bucket pads only to its own width, so a single heavy reducer no
    longer inflates every light reducer to the global max slot count — on a
    Zipf-sized schema this cuts the gathered elements (and the quadratic
    reducer FLOPs of block reducers) by the plan's ``padding_savings``.

    combine='dense'    — return one pytree shaped exactly like the dense
        ``run_reducers`` output: bucket outputs are zero-padded along their
        slot-sized axes to the dense width and scattered back into original
        reducer order.  Rows past ``plan.num_reducers`` (mesh padding) are
        zeros, so ``reducer_fn`` must zero its masked-out output entries for
        the two executors to agree there (all shipped reducer functions do).
    combine='buckets'  — return ``[(bucket, out_pytree), ...]`` unpadded;
        downstream consumers (e.g. the per-bucket pair-matrix assembler)
        keep the memory win end-to-end.

    ``reducer_fn`` must be shape-polymorphic over the slot count L — it is
    traced once per bucket width.
    """
    assert combine in ("dense", "buckets"), combine
    buckets = plan.buckets
    if not buckets:
        # plans built before bucketing / empty schemas: dense semantics
        out = run_reducers(inputs, plan, reducer_fn, mesh=mesh,
                           shard_axes=shard_axes)
        return out if combine == "dense" else []

    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted(reducer_fn, mesh, shard_axes)

    per_bucket = [
        (b, fn(inputs, jnp.asarray(b.idx), jnp.asarray(b.mask)))
        for b in buckets
    ]
    if combine == "buckets":
        return per_bucket

    dense_shapes = _dense_out_shapes(plan, reducer_fn, inputs)
    leaves_t, treedef = jax.tree.flatten(dense_shapes)
    acc = [jnp.zeros((plan.R,) + t.shape, t.dtype) for t in leaves_t]
    for b, out in per_bucket:
        valid = b.rows >= 0                      # static numpy mask
        rows = jnp.asarray(b.rows[valid])
        for i, leaf in enumerate(jax.tree.flatten(out)[0]):
            padded = _pad_leaf_to(leaf, leaves_t[i].shape)
            acc[i] = acc[i].at[rows].set(padded[np.flatnonzero(valid)])
    return jax.tree.unflatten(treedef, acc)


# ---------------------------------------------------------------------------
# fused (gather+Gram megakernel) executor
# ---------------------------------------------------------------------------
# The fused path only serves *Gram-block* reducers — reducer functions
# tagged with a ``fused_metric`` attribute ("dot" / "l2" / "cosine", see
# allpairs._block_fn).  Anything else falls back to the bucketed executor;
# the counters below are the serving-telemetry source of truth.
FUSED_STATS = {"calls": 0, "kernel": 0, "streamed": 0, "fallbacks": 0}


def fused_stats() -> dict:
    """Snapshot of the fused-executor dispatch counters."""
    return dict(FUSED_STATS)


def reset_fused_stats() -> None:
    for k in FUSED_STATS:
        FUSED_STATS[k] = 0


def _finish_fused_blocks(g, mask, metric: str):
    """Metric post-processing of a masked per-reducer Gram stack.

    Mirrors ``allpairs.block_similarity`` exactly: norms are the Gram
    diagonal (masked rows were zeroed at gather time, so their norms are 0),
    invalid pairs -> 0.
    """
    if metric != "dot":
        n2 = jnp.diagonal(g, axis1=1, axis2=2)            # (Rb, Lb)
        if metric == "l2":
            g = n2[:, :, None] + n2[:, None, :] - 2.0 * g
        elif metric == "cosine":
            nrm = jnp.sqrt(n2 + 1e-9)
            g = g / (nrm[:, :, None] * nrm[:, None, :])
        else:
            raise ValueError(metric)
    valid = mask[:, :, None] & mask[:, None, :]
    return jnp.where(valid, g, 0.0)


def _make_fused_jitted(metric, combine, mesh, shard_axes, use_kernel,
                       interpret, bl, postprocess):
    from repro.kernels.pairwise.fused_gather_gram import (
        fused_gather_gram,
        fused_gather_gram_streamed,
    )

    def run(x, buckets, pp_arg, R, L):
        per_bucket = []
        for idx, msk, rows in buckets:
            if use_kernel:
                g = fused_gather_gram(x, idx, msk, bl=bl,
                                      interpret=interpret)
            else:
                g = fused_gather_gram_streamed(x, idx, msk, bl=bl)
            mb = msk.astype(bool)
            per_bucket.append(((idx, mb, rows),
                               _finish_fused_blocks(g, mb, metric)))
        if postprocess is not None:
            return postprocess(per_bucket, pp_arg)
        if combine == "buckets":
            return [g for _, g in per_bucket]
        # dense combine: scatter bucket blocks (padded to the dense width)
        # into original reducer order; padding rows land in the extra row R
        acc = jnp.zeros((R + 1, L, L), jnp.float32)
        for (idx, msk, rows), g in per_bucket:
            Lb = g.shape[1]
            gp = jnp.pad(g, ((0, 0), (0, L - Lb), (0, L - Lb)))
            acc = acc.at[rows].set(gp)
        return acc[:R]

    if mesh is None:
        return jax.jit(run, static_argnums=(3, 4))
    red_sharding, rep = _shardings(mesh, shard_axes)
    return jax.jit(run, in_shardings=(rep, red_sharding, rep),
                   static_argnums=(3, 4))


def run_reducers_fused(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    combine: str = "dense",
    postprocess: Optional[Callable] = None,
    postprocess_arg=None,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    bl: int = 128,
):
    """Fused shuffle execution: the gathered block stays out of HBM.

    Per capacity bucket, the plan's ``idx``/``mask`` rows drive the fused
    gather+Gram Pallas kernel (``use_kernel=True``; scalar-prefetched rows,
    table rows DMA'd HBM->VMEM, fp32 MXU accumulation — gathered rows live
    only in VMEM scratch) or its jnp twin with the same tile dataflow
    (``use_kernel=False``, the non-TPU default) — the twin still gathers
    ``(Rb, bl, d)`` tiles as XLA intermediates, but a multi-tile bucket
    never materializes its full ``(Rb, Lb, d)`` block and no bucket ever
    materializes the dense ``(R, L, d)`` one.  *All* buckets execute
    inside ONE jitted program, so a request pays a single dispatch instead
    of one per bucket.

    Only Gram-block reducers are fusable: ``reducer_fn`` must carry a
    ``fused_metric`` attribute (see ``allpairs._block_fn``).  Any other
    reducer — and bucketless plans — falls back to
    :func:`run_reducers_bucketed` with identical outputs (``FUSED_STATS``
    counts the fallbacks for serving telemetry).

    ``combine`` follows the bucketed executor ('dense' / 'buckets');
    ``postprocess(per_bucket, postprocess_arg)`` — a *stable* function
    object, traced into the same program — lets applications fuse their
    assembly step too (allpairs passes its inverse-shuffle gather map).
    ``use_kernel=None`` auto-selects: Pallas on TPU, streamed jnp elsewhere.
    """
    assert combine in ("dense", "buckets"), combine
    FUSED_STATS["calls"] += 1
    metric = getattr(reducer_fn, "fused_metric", None)
    if metric is None or not plan.buckets:
        FUSED_STATS["fallbacks"] += 1
        out = run_reducers_bucketed(
            inputs, plan, reducer_fn, mesh=mesh, shard_axes=shard_axes,
            combine="buckets" if postprocess is not None else combine)
        if postprocess is not None:
            # honor the postprocess contract on the fallback path (eager)
            per_bucket = [((jnp.asarray(b.idx), jnp.asarray(b.mask),
                            jnp.asarray(_scatter_rows(b, plan.R))), blocks)
                          for b, blocks in out]
            return postprocess(per_bucket, postprocess_arg)
        return out

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    FUSED_STATS["kernel" if use_kernel else "streamed"] += 1
    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _cache_get(
        ("fused", metric, combine, postprocess, mesh, shard_axes,
         bool(use_kernel), bool(interpret), bl),
        lambda: _make_fused_jitted(metric, combine, mesh, shard_axes,
                                   use_kernel, interpret, bl, postprocess))
    buckets = tuple(
        (jnp.asarray(b.idx), jnp.asarray(b.mask),
         jnp.asarray(_scatter_rows(b, plan.R)))
        for b in plan.buckets)
    return fn(inputs, buckets, postprocess_arg, plan.R, plan.L)


def _scatter_rows(bucket: ReducerBucket, R: int) -> np.ndarray:
    """Bucket rows for drop-style scatter: padding rows (-1) -> row R."""
    return np.where(bucket.rows >= 0, bucket.rows, R).astype(np.int32)


def lower_reducers(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    reducer_fn: Callable,
    mesh: jax.sharding.Mesh,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
):
    """Lower (no execution) for dry-run / roofline analysis.

    ``mesh=None`` lowers the unsharded single-program form (used by the
    benchmark's HLO buffer checks)."""
    idx = jax.ShapeDtypeStruct(plan.idx.shape, jnp.int32)
    mask = jax.ShapeDtypeStruct(plan.mask.shape, jnp.bool_)
    x = jax.ShapeDtypeStruct(input_shape, dtype)

    _run = partial(_gather_reduce, reducer_fn=reducer_fn)
    if mesh is None:
        return jax.jit(_run).lower(x, idx, mask)
    red_sharding, rep = _shardings(mesh, shard_axes)
    fn = jax.jit(
        _run,
        in_shardings=(rep, red_sharding, red_sharding),
        out_shardings=red_sharding,
    )
    return fn.lower(x, idx, mask)


def lower_reducers_bucketed(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    reducer_fn: Callable,
    mesh: jax.sharding.Mesh,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
) -> list:
    """Lower every bucket program (no execution) for dry-run / roofline.

    Returns ``[(bucket, lowered), ...]``; per-device roofline terms add up
    across buckets (the programs run back-to-back on the same mesh)."""
    x = jax.ShapeDtypeStruct(input_shape, dtype)
    _run = partial(_gather_reduce, reducer_fn=reducer_fn)
    red_sharding, rep = _shardings(mesh, shard_axes)
    fn = jax.jit(_run, in_shardings=(rep, red_sharding, red_sharding),
                 out_shardings=red_sharding)
    out = []
    for b in plan.buckets:
        idx = jax.ShapeDtypeStruct(b.idx.shape, jnp.int32)
        mask = jax.ShapeDtypeStruct(b.mask.shape, jnp.bool_)
        out.append((b, fn.lower(x, idx, mask)))
    return out


def lower_reducers_fused(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    metric: str,
    mesh: Optional[jax.sharding.Mesh] = None,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
    combine: str = "buckets",
    use_kernel: bool = False,
    bl: int = 128,
):
    """Lower the fused executor's single all-bucket program (no execution).

    Defaults to the streamed (jnp) lowering so the dry-run works on any
    backend; on this path the program is directly comparable with
    ``lower_reducers_bucketed`` — same math, one program, no materialized
    gather for multi-tile widths.  Returns one ``Lowered``.
    """
    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _make_fused_jitted(metric, combine, mesh, shard_axes, use_kernel,
                            False, bl, None)
    x = jax.ShapeDtypeStruct(input_shape, dtype)
    buckets = tuple(
        (jax.ShapeDtypeStruct(b.idx.shape, jnp.int32),
         jax.ShapeDtypeStruct(b.mask.shape, jnp.bool_),
         jax.ShapeDtypeStruct((b.R,), jnp.int32))
        for b in plan.buckets)
    return fn.lower(x, buckets, None, plan.R, plan.L)
