"""Mapping schema -> static gather plan -> sharded reducer execution.

The MapReduce shuffle of the paper is adapted to TPU/JAX as follows
(DESIGN.md "hardware adaptation"):

  * reducers become *reducer slots*, a leading array dimension sharded across
    the device mesh;
  * the map->reduce shuffle becomes ``jnp.take`` from the input array with a
    static index matrix computed from the schema — XLA lowers this to
    all-gather/collective traffic whose volume is the schema's communication
    cost (this is what the roofline benchmark measures);
  * the reduce function is vmapped over slots, so every device processes its
    slots in parallel (the MXU does the per-reducer all-pairs work through
    the Pallas ``pairwise`` kernel).

Executors share the plan format and are registered by name in
``repro.mapreduce.executors`` (the Executor protocol + registry; DESIGN.md
"executor registry").  This module is the shared substrate: the plan
builder, the bounded jit cache, and the dense/bucketed implementations the
executor classes wrap.  The historical entry points below stay as thin
shims over the registry so existing callers keep working:

``run_reducers``           — the dense path: one gather padded to the global
                             max slot count.  Simple, one XLA program, but a
                             single heavy reducer forces every other reducer
                             to pad to its width — quadratic waste for
                             reducer functions like the all-pairs Gram block.
``run_reducers_bucketed``  — the skew-aware path (DESIGN.md "bucketed shuffle
                             execution"): reducers are grouped into capacity
                             buckets (powers-of-two over per-reducer slot
                             counts, ``repro.core.planner.compute_buckets``),
                             one vmapped gather+reduce per bucket, each
                             padded only to its own bucket width, outputs
                             reassembled in original reducer order.
``run_reducers_fused``     — shim over ``get_executor("fused")`` (DESIGN.md
                             "fused shuffle execution"): for Gram-block
                             reducers the shuffle streams straight into the
                             MXU through the fused gather+Gram Pallas
                             kernel — the padded gather never round-trips
                             through HBM, and all buckets run in one
                             program.  Non-Gram reducers fall back to the
                             bucketed path.
``run_reducers_sharded``   — shim over ``get_executor("sharded")`` (DESIGN.md
                             "sharded execution"): the plan is LPT-balanced
                             into per-shard sub-plans
                             (``repro.core.planner.partition_plan``) and the
                             fused/bucketed pipeline runs per shard under
                             ``shard_map`` over the mesh's reducer axis.
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import compute_buckets, compute_rect_buckets
from repro.core.schema import MappingSchema
from repro.obs import EVENTS as _OBS_EVENTS
from repro.obs import REGISTRY as _OBS_REGISTRY
from repro.obs import span as _obs_span

__all__ = [
    "ReducerBucket",
    "ReducerPlan",
    "SparsePlan",
    "build_plan",
    "build_sparse_plan",
    "block_subplan",
    "build_x2y_plan",
    "build_x2y_plan_arrays",
    "run_reducers",
    "run_reducers_bucketed",
    "run_reducers_x2y",
    "run_reducers_x2y_bucketed",
    "run_reducers_fused",
    "run_reducers_sharded",
    "lower_reducers",
    "lower_reducers_bucketed",
    "lower_reducers_fused",
    "jit_cache_stats",
    "configure_jit_cache",
    "block_cache_stats",
    "configure_block_cache",
    "fused_stats",
    "reset_fused_stats",
]


@dataclasses.dataclass(frozen=True)
class ReducerBucket:
    """One capacity bucket of the plan: reducers padded to a shared width.

    rows  (Rb,) int64 — original plan-row ids in bucket order; -1 marks a
          padding row added so the bucket divides the device count.
    idx   (Rb, width) int32 / mask (Rb, width) bool — same layout as the
          dense plan, but only ``width`` slots wide.

    Rectangular (X2Y) buckets additionally carry the Y side: ``yidx`` /
    ``ymask`` are (Rb, ywidth) gather rows into the *Y table* (``idx``
    then indexes the X table); ``yidx is None`` marks the square all-pairs
    case, where ``idx`` serves both block axes.
    """

    width: int
    rows: np.ndarray
    idx: np.ndarray
    mask: np.ndarray
    ywidth: int = 0
    yidx: Optional[np.ndarray] = None
    ymask: Optional[np.ndarray] = None

    @property
    def R(self) -> int:
        return int(self.idx.shape[0])

    @property
    def is_rect(self) -> bool:
        return self.yidx is not None

    @property
    def num_real(self) -> int:
        return int(np.sum(self.rows >= 0))

    @property
    def padded_elements(self) -> int:
        """Gather slots this bucket materializes (both sides for rect)."""
        if self.is_rect:
            return self.R * (self.width + self.ywidth)
        return self.R * self.width


@dataclasses.dataclass(frozen=True)
class ReducerPlan:
    """Static arrays derived from a MappingSchema.

    idx   (R, L) int32 — input ids per reducer slot; padded entries point at
          input 0 and are masked out.
    mask  (R, L) bool  — slot validity.
    buckets — capacity buckets over the same reducers (skew-aware executor);
          every real reducer row appears in exactly one bucket.

    The plan also carries the schema's provenance so downstream telemetry
    (benchmarks, serving dashboards) can report which registry strategy
    produced the traffic and how far it sits from the paper's
    replication-rate lower bound.
    """

    idx: np.ndarray
    mask: np.ndarray
    num_reducers: int          # before padding
    comm_cost: float           # schema communication cost (weighted bytes)
    max_inputs: int
    algorithm: str = "unknown"             # winning strategy (provenance)
    lower_bound: Optional[float] = None    # paper's comm lower bound
    buckets: tuple[ReducerBucket, ...] = ()
    # rectangular (X2Y) extension: per-reducer Y-side gather rows.  When
    # ``yidx is None`` the plan is the square all-pairs degenerate case
    # (X == Y) and ``idx``/``mask`` drive both block axes; otherwise
    # ``idx`` indexes the X table and ``yidx`` the Y table, and reducer
    # outputs are (Lx, Ly) cross blocks assembled into an (num_x, num_y)
    # matrix.
    yidx: Optional[np.ndarray] = None      # (R, Ly) int32 Y-table rows
    ymask: Optional[np.ndarray] = None     # (R, Ly) bool Y-slot validity
    max_y_inputs: int = 0
    num_x: int = 0                         # X-table size (rect plans)
    num_y: int = 0                         # Y-table size (rect plans)

    @property
    def R(self) -> int:
        return int(self.idx.shape[0])

    @property
    def L(self) -> int:
        return int(self.idx.shape[1])

    @property
    def is_rect(self) -> bool:
        """True for rectangular (X2Y) plans carrying a Y side."""
        return self.yidx is not None

    @property
    def Ly(self) -> int:
        """Dense Y-side slot count (== L for square plans)."""
        return int(self.yidx.shape[1]) if self.is_rect else self.L

    @property
    def optimality_gap(self) -> Optional[float]:
        """comm_cost / lower_bound (>= 1.0), or None without a bound."""
        if self.lower_bound is None or self.lower_bound <= 0.0:
            return None
        return self.comm_cost / self.lower_bound

    # ---------------------------------------------------------- telemetry
    @property
    def dense_padded_elements(self) -> int:
        """Gather slots the dense executor materializes (R x L; both sides
        for rectangular plans)."""
        if self.is_rect:
            return self.R * (self.L + self.Ly)
        return self.R * self.L

    @property
    def bucketed_padded_elements(self) -> int:
        """Gather slots the bucketed executor materializes."""
        if not self.buckets:
            return self.dense_padded_elements
        return sum(b.padded_elements for b in self.buckets)

    @property
    def padding_savings(self) -> float:
        """dense / bucketed padded elements (>= 1.0 up to row padding)."""
        return self.dense_padded_elements / max(self.bucketed_padded_elements,
                                                1)

    def bucket_widths(self) -> list[int]:
        return [b.width for b in self.buckets]


def _build_buckets(expanded: list[list[int]], *, pad_slots_to: int,
                   pad_reducers_to: int,
                   max_buckets: int) -> tuple[ReducerBucket, ...]:
    """Capacity buckets over expanded reducers (original row order kept
    within each bucket; rows padded to a multiple of ``pad_reducers_to``)."""
    counts = [len(ids) for ids in expanded]
    out = []
    for width, rows in compute_buckets(counts, pad_slots_to=pad_slots_to,
                                       max_buckets=max_buckets):
        Rb = -(-max(len(rows), 1) // pad_reducers_to) * pad_reducers_to
        idx = np.zeros((Rb, width), dtype=np.int32)
        mask = np.zeros((Rb, width), dtype=bool)
        rows_padded = np.full(Rb, -1, dtype=np.int64)
        rows_padded[: len(rows)] = rows
        for i, r in enumerate(rows):
            ids = expanded[r]
            idx[i, : len(ids)] = ids
            mask[i, : len(ids)] = True
        out.append(ReducerBucket(width=width, rows=rows_padded, idx=idx,
                                 mask=mask))
    return tuple(out)


def build_plan(schema: MappingSchema, *, pad_reducers_to: int = 1,
               pad_slots_to: int = 1, max_buckets: int = 8) -> ReducerPlan:
    """Flatten a schema into (idx, mask) plus capacity buckets.

    ``pad_reducers_to`` rounds reducer counts up to a multiple (device
    count) — applied to the dense plan and to every bucket independently;
    ``pad_slots_to`` rounds slot counts (kernel tile alignment);
    ``max_buckets`` bounds the number of capacity buckets (dispatch
    overhead of the bucketed executor)."""
    expanded = schema.expand()
    R0 = len(expanded)
    L0 = max((len(ids) for ids in expanded), default=1)
    L = -(-L0 // pad_slots_to) * pad_slots_to
    R = -(-max(R0, 1) // pad_reducers_to) * pad_reducers_to
    idx = np.zeros((R, L), dtype=np.int32)
    mask = np.zeros((R, L), dtype=bool)
    for r, ids in enumerate(expanded):
        idx[r, : len(ids)] = ids
        mask[r, : len(ids)] = True
    buckets = _build_buckets(expanded, pad_slots_to=pad_slots_to,
                             pad_reducers_to=pad_reducers_to,
                             max_buckets=max_buckets)
    return ReducerPlan(idx=idx, mask=mask, num_reducers=R0,
                       comm_cost=schema.communication_cost(), max_inputs=L0,
                       algorithm=schema.algorithm,
                       lower_bound=schema.lower_bound,
                       buckets=buckets)


# ---------------------------------------------------------------------------
# rectangular (X2Y) plans: per-reducer X-side and Y-side index lists
# ---------------------------------------------------------------------------
def _build_rect_buckets(xs: list[list[int]], ys: list[list[int]], *,
                        pad_slots_to: int, pad_reducers_to: int,
                        max_buckets: int) -> tuple[ReducerBucket, ...]:
    """Rectangular capacity buckets: reducers grouped by (wx, wy) width
    pairs (``compute_rect_buckets``), each side padded to its own
    power-of-two width; rows padded to a multiple of ``pad_reducers_to``."""
    out = []
    for wx, wy, rows in compute_rect_buckets(
            [len(a) for a in xs], [len(a) for a in ys],
            pad_slots_to=pad_slots_to, max_buckets=max_buckets):
        Rb = -(-max(len(rows), 1) // pad_reducers_to) * pad_reducers_to
        idx = np.zeros((Rb, wx), dtype=np.int32)
        mask = np.zeros((Rb, wx), dtype=bool)
        yidx = np.zeros((Rb, wy), dtype=np.int32)
        ymask = np.zeros((Rb, wy), dtype=bool)
        rows_padded = np.full(Rb, -1, dtype=np.int64)
        rows_padded[: len(rows)] = rows
        for i, r in enumerate(rows):
            a, b = xs[r], ys[r]
            idx[i, : len(a)] = a
            mask[i, : len(a)] = True
            yidx[i, : len(b)] = b
            ymask[i, : len(b)] = True
        out.append(ReducerBucket(width=wx, rows=rows_padded, idx=idx,
                                 mask=mask, ywidth=wy, yidx=yidx,
                                 ymask=ymask))
    return tuple(out)


def build_x2y_plan_arrays(
    xs: list[list[int]],               # per-reducer X-table row ids
    ys: list[list[int]],               # per-reducer Y-table row ids
    *,
    num_x: int,
    num_y: int,
    comm_cost: float = 0.0,
    algorithm: str = "x2y",
    lower_bound: Optional[float] = None,
    pad_reducers_to: int = 1,
    pad_slots_to: int = 1,
    max_buckets: int = 8,
) -> ReducerPlan:
    """Rectangular plan from explicit per-reducer X/Y id lists.

    The low-level builder ``build_x2y_plan`` and the streaming X2Y planner
    share: reducer ``r`` gathers ``xs[r]`` from the X table and ``ys[r]``
    from the Y table and emits the (|xs[r]|, |ys[r]|) cross block."""
    assert len(xs) == len(ys), (len(xs), len(ys))
    R0 = len(xs)
    Lx0 = max((len(a) for a in xs), default=1)
    Ly0 = max((len(a) for a in ys), default=1)
    Lx = -(-Lx0 // pad_slots_to) * pad_slots_to
    Ly = -(-Ly0 // pad_slots_to) * pad_slots_to
    R = -(-max(R0, 1) // pad_reducers_to) * pad_reducers_to
    idx = np.zeros((R, Lx), dtype=np.int32)
    mask = np.zeros((R, Lx), dtype=bool)
    yidx = np.zeros((R, Ly), dtype=np.int32)
    ymask = np.zeros((R, Ly), dtype=bool)
    for r in range(R0):
        a, b = xs[r], ys[r]
        idx[r, : len(a)] = a
        mask[r, : len(a)] = True
        yidx[r, : len(b)] = b
        ymask[r, : len(b)] = True
    buckets = _build_rect_buckets(xs, ys, pad_slots_to=pad_slots_to,
                                  pad_reducers_to=pad_reducers_to,
                                  max_buckets=max_buckets)
    return ReducerPlan(
        idx=idx, mask=mask, num_reducers=R0, comm_cost=float(comm_cost),
        max_inputs=Lx0, algorithm=algorithm, lower_bound=lower_bound,
        buckets=buckets, yidx=yidx, ymask=ymask, max_y_inputs=Ly0,
        num_x=int(num_x), num_y=int(num_y))


# ---------------------------------------------------------------------------
# sparse plans: CSR gather maps for block-addressed serving (no O(m^2) host)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SparsePlan:
    """CSR view of a schema for block-addressed execution.

    ``build_plan`` expands reducer -> original input ids, which at m = 10^6
    with thousands of inputs per reducer is ~10^9 host entries before a
    single gather runs.  The sparse plan stays at the schema's own
    granularity — three CSR maps totaling O(m + assignments):

      bin_indptr / bin_inputs    — bin -> original input ids (disjoint);
      bin_of                     — input -> bin (inverse of the above);
      red_indptr / red_bins      — reducer -> bin ids;
      binred_indptr / bin_reds   — bin -> reducer ids (inverse shuffle).

    ``block_subplan`` materializes only the reducers a requested
    ``[i0:i1) x [j0:j1)`` output block needs, as a rectangular
    :class:`ReducerPlan` in block-local coordinates, so every registry
    executor serves blocks through its existing ``run_x2y`` path.  Built
    sub-plans are LRU-cached on the instance (``_block_cache``) because
    the fused/sharded executors cache their inverse-shuffle srcmaps on the
    plan object.
    """

    num_inputs: int
    q: float
    bin_indptr: np.ndarray
    bin_inputs: np.ndarray
    bin_of: np.ndarray
    red_indptr: np.ndarray
    red_bins: np.ndarray
    binred_indptr: np.ndarray
    bin_reds: np.ndarray
    comm_cost: float = 0.0
    lower_bound: Optional[float] = None
    algorithm: str = "unknown"

    @property
    def num_bins(self) -> int:
        return int(len(self.bin_indptr) - 1)

    @property
    def num_reducers(self) -> int:
        return int(len(self.red_indptr) - 1)

    @property
    def host_entries(self) -> int:
        """Total host-side index entries — o(m^2) by construction."""
        return int(self.bin_inputs.size + self.bin_of.size
                   + 2 * self.red_bins.size)

    @property
    def optimality_gap(self) -> Optional[float]:
        if self.lower_bound is None or self.lower_bound <= 0.0:
            return None
        return self.comm_cost / self.lower_bound


def build_sparse_plan(schema: MappingSchema) -> SparsePlan:
    """CSR maps from a disjoint-bins schema, no per-input Python loops.

    Raises on overlapping-bin schemas (hybrid / big-input paths): those are
    small-m constructions that the dense ``build_plan`` already serves.
    """
    if schema.meta.get("bins_overlap", False):
        raise ValueError(
            "sparse plans require disjoint bins; use build_plan for the "
            "overlapping hybrid/big-input schemas")
    m = schema.m
    nb = len(schema.bins)
    bin_counts = np.asarray([len(b) for b in schema.bins], dtype=np.int64)
    bin_inputs = (np.concatenate(
        [np.asarray(b, dtype=np.int64) for b in schema.bins])
        if nb else np.zeros(0, dtype=np.int64))
    bin_indptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(bin_counts, out=bin_indptr[1:])
    bin_of = np.full(m, -1, dtype=np.int64)
    bin_of[bin_inputs] = np.repeat(
        np.arange(nb, dtype=np.int64), bin_counts)

    nr = len(schema.reducers)
    red_counts = np.asarray([len(r) for r in schema.reducers],
                            dtype=np.int64)
    red_bins = (np.concatenate(
        [np.asarray(r, dtype=np.int64) for r in schema.reducers])
        if nr else np.zeros(0, dtype=np.int64))
    red_indptr = np.zeros(nr + 1, dtype=np.int64)
    np.cumsum(red_counts, out=red_indptr[1:])

    # invert to bin -> reducers (the inverse-shuffle direction)
    red_of = np.repeat(np.arange(nr, dtype=np.int64), red_counts)
    order = np.lexsort((red_of, red_bins))
    binred_indptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(np.bincount(red_bins, minlength=nb), out=binred_indptr[1:])
    return SparsePlan(
        num_inputs=m, q=float(schema.q), bin_indptr=bin_indptr,
        bin_inputs=bin_inputs, bin_of=bin_of, red_indptr=red_indptr,
        red_bins=red_bins, binred_indptr=binred_indptr,
        bin_reds=red_of[order], comm_cost=schema.communication_cost(),
        lower_bound=schema.lower_bound, algorithm=schema.algorithm)


def _gather_csr(indptr: np.ndarray, data: np.ndarray,
                keys: np.ndarray) -> np.ndarray:
    """Concatenate ``data[indptr[k]:indptr[k+1]]`` over ``keys``."""
    if keys.size == 0:
        return np.zeros(0, dtype=data.dtype)
    return np.concatenate(
        [data[indptr[k]:indptr[k + 1]] for k in keys])


def block_subplan(sparse: SparsePlan, i0: int, i1: int, j0: int, j1: int,
                  *, pad_reducers_to: int = 1, pad_slots_to: int = 1,
                  max_buckets: int = 8,
                  cache_size: Optional[int] = None) -> Optional[ReducerPlan]:
    """Rectangular sub-plan serving output block ``[i0:i1) x [j0:j1)``.

    Selects exactly the reducers hosting at least one row bin *and* one
    column bin — for any required pair (i, j) in the block, the reducer
    the schema covers it with hosts ``bin_of[i]`` (a row bin) and
    ``bin_of[j]`` (a column bin), so it is selected and the block inherits
    the schema's full coverage.  Each selected reducer is restricted to
    the block-local X / Y ids it actually hosts; the result is an ordinary
    rectangular plan any executor runs via ``run_x2y``.  Returns ``None``
    for a block no reducer touches (empty ranges).  LRU-cached on the
    sparse plan so repeated requests reuse executor-side srcmaps;
    ``cache_size=None`` (default) takes the shared cap set by
    ``REPRO_BLOCK_CACHE_SIZE`` / :func:`configure_block_cache`, and
    hit/miss/evict counters feed :func:`block_cache_stats`.
    """
    if cache_size is None:
        cache_size = _BLOCK_CACHE_MAX
    if not (0 <= i0 <= i1 <= sparse.num_inputs
            and 0 <= j0 <= j1 <= sparse.num_inputs):
        raise IndexError(
            f"block [{i0}:{i1}) x [{j0}:{j1}) outside "
            f"m={sparse.num_inputs}")
    key = (i0, i1, j0, j1, pad_reducers_to, pad_slots_to, max_buckets)
    cache = sparse.__dict__.get("_block_cache")
    if cache is None:
        cache = OrderedDict()
        object.__setattr__(sparse, "_block_cache", cache)
    if key in cache:
        cache.move_to_end(key)
        _BLOCK_CACHE_STATS["hits"] += 1
        _OBS_REGISTRY.counter("cache.hits", cache="block").inc()
        return cache[key]
    _BLOCK_CACHE_STATS["misses"] += 1
    _OBS_REGISTRY.counter("cache.misses", cache="block").inc()

    row_bins = np.unique(sparse.bin_of[i0:i1])
    col_bins = np.unique(sparse.bin_of[j0:j1])
    row_bins = row_bins[row_bins >= 0]
    col_bins = col_bins[col_bins >= 0]
    row_reds = np.unique(
        _gather_csr(sparse.binred_indptr, sparse.bin_reds, row_bins))
    col_reds = np.unique(
        _gather_csr(sparse.binred_indptr, sparse.bin_reds, col_bins))
    cand = np.intersect1d(row_reds, col_reds, assume_unique=True)

    xs: list[np.ndarray] = []
    ys: list[np.ndarray] = []
    for r in cand:
        bins_r = sparse.red_bins[
            sparse.red_indptr[r]:sparse.red_indptr[r + 1]]
        inputs_r = _gather_csr(sparse.bin_indptr, sparse.bin_inputs, bins_r)
        xr = inputs_r[(inputs_r >= i0) & (inputs_r < i1)] - i0
        yr = inputs_r[(inputs_r >= j0) & (inputs_r < j1)] - j0
        if xr.size and yr.size:
            xs.append(xr)
            ys.append(yr)
    if not xs:
        plan = None
    else:
        plan = build_x2y_plan_arrays(
            xs, ys, num_x=i1 - i0, num_y=j1 - j0,
            comm_cost=float(sum(len(a) + len(b)
                                for a, b in zip(xs, ys))),
            algorithm=f"block+{sparse.algorithm}",
            pad_reducers_to=pad_reducers_to, pad_slots_to=pad_slots_to,
            max_buckets=max_buckets)
    cache[key] = plan
    while len(cache) > cache_size:
        evicted, _ = cache.popitem(last=False)
        _BLOCK_CACHE_STATS["evictions"] += 1
        _OBS_REGISTRY.counter("cache.evictions", cache="block").inc()
        _OBS_EVENTS.emit("cache_eviction", cache="block",
                         key=str(evicted))
    return plan


def build_x2y_plan(schema: MappingSchema, num_x: int, *,
                   pad_reducers_to: int = 1, pad_slots_to: int = 1,
                   max_buckets: int = 8) -> ReducerPlan:
    """Flatten an X2Y schema (``plan_x2y`` convention: global ids
    ``0..num_x-1`` are X, ``num_x..`` are Y) into a rectangular plan:
    each reducer's expanded ids are split at the X/Y boundary, Y ids are
    re-based to Y-table-local rows, and capacity buckets group reducers by
    (wx, wy) power-of-two width pairs."""
    expanded = schema.expand()
    xs = [[i for i in ids if i < num_x] for ids in expanded]
    ys = [[i - num_x for i in ids if i >= num_x] for ids in expanded]
    return build_x2y_plan_arrays(
        xs, ys, num_x=num_x, num_y=len(schema.weights) - num_x,
        comm_cost=schema.communication_cost(), algorithm=schema.algorithm,
        lower_bound=schema.lower_bound, pad_reducers_to=pad_reducers_to,
        pad_slots_to=pad_slots_to, max_buckets=max_buckets)


def _shardings(mesh, shard_axes):
    axes = shard_axes if shard_axes is not None else mesh.axis_names
    P = jax.sharding.PartitionSpec
    red = jax.sharding.NamedSharding(mesh, P(axes))
    rep = jax.sharding.NamedSharding(mesh, P())
    return red, rep


def _gather_reduce(x, idx, mask, reducer_fn):
    gathered = jnp.take(x, idx, axis=0)          # (R, L, d) — the shuffle
    gathered = jnp.where(mask[..., None], gathered, 0)
    return jax.vmap(reducer_fn)(gathered, mask)


# One jitted executable per (reducer_fn, mesh, shard_axes): repeated calls —
# a serving loop, the benchmark's timed iterations, every bucket of a
# bucketed run — reuse the XLA compile cache instead of re-tracing through
# a fresh jax.jit wrapper each time.  Callers enable reuse by passing the
# *same* reducer_fn object (see allpairs._block_fn).
#
# The cache is a bounded LRU: a long-running PairwiseService loop that keeps
# constructing *fresh* reducer closures (defeating the reuse contract) evicts
# its oldest entries instead of growing without limit.  The cap is
# configurable via the ``REPRO_JIT_CACHE_SIZE`` environment variable (read
# at import and by ``configure_jit_cache()``); ``jit_cache_stats`` feeds the
# serving telemetry, including per-key hit counts.
def _env_cache_size(default: int = 64,
                    var: str = "REPRO_JIT_CACHE_SIZE") -> int:
    """``var`` as a cap >= 1; malformed or non-positive values fall back
    to the default (a cap of 0 would evict every insert immediately —
    unbounded retracing, the exact cost the cache exists to prevent)."""
    raw = os.environ.get(var, "")
    try:
        size = int(raw)
    except ValueError:
        return default
    return size if size >= 1 else default


_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_MAX = _env_cache_size()
_JIT_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0,
                    "shape_hits": 0, "shape_misses": 0}
_JIT_CACHE_HITS: dict = {}                    # key -> hit count (live entries)
_JIT_SHAPES: dict = {}                # key -> arg-shape signatures seen


def configure_jit_cache(max_size: Optional[int] = None) -> int:
    """Set the jit-cache LRU cap; with no argument, re-read
    ``REPRO_JIT_CACHE_SIZE`` from the environment (default 64).  Evicts
    oldest entries immediately if the cache exceeds the new cap.  Returns
    the active cap."""
    global _JIT_CACHE_MAX
    if max_size is None:
        max_size = _env_cache_size()
    assert max_size >= 1, max_size
    _JIT_CACHE_MAX = max_size
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _evict_oldest()
    return _JIT_CACHE_MAX


def _evict_oldest():
    key, _ = _JIT_CACHE.popitem(last=False)
    _JIT_CACHE_HITS.pop(key, None)
    _JIT_SHAPES.pop(key, None)
    _JIT_CACHE_STATS["evictions"] += 1
    _OBS_REGISTRY.counter("cache.evictions", cache="jit").inc()
    _OBS_EVENTS.emit("cache_eviction", cache="jit", key=_key_label(key))


def _record_shapes(key, args) -> None:
    """Shape-level compile telemetry.  ``jax.jit`` caches compilations per
    argument shape, so a jit-cache *key* hit can still pay a compile when
    the call carries a shape the entry has not seen.  Tracking signatures
    per key makes that visible: a new signature is a ``shape_miss`` (a
    retrace/compile happened), a repeat is a ``shape_hit`` — the counter
    the streaming warm-path tests pin (a warmed first edit must add zero
    shape_misses).  Recorded at the call sites, not by wrapping the jitted
    fn, so ``.lower()`` on cache entries keeps working."""
    sig = tuple((tuple(a.shape), str(a.dtype)) for a in args)
    seen = _JIT_SHAPES.setdefault(key, set())
    if sig in seen:
        _JIT_CACHE_STATS["shape_hits"] += 1
        _OBS_REGISTRY.counter("cache.shape_hits", cache="jit").inc()
    else:
        seen.add(sig)
        _JIT_CACHE_STATS["shape_misses"] += 1
        _OBS_REGISTRY.counter("cache.shape_misses", cache="jit").inc()


def _cache_get(key, factory):
    fn = _JIT_CACHE.get(key)
    if fn is None:
        _JIT_CACHE_STATS["misses"] += 1
        _OBS_REGISTRY.counter("cache.misses", cache="jit").inc()
        with _obs_span("compile", cache="jit", key=_key_label(key)):
            fn = factory()
        _JIT_CACHE[key] = fn
        _JIT_CACHE_HITS[key] = 0
        while len(_JIT_CACHE) > _JIT_CACHE_MAX:
            _evict_oldest()
    else:
        _JIT_CACHE_STATS["hits"] += 1
        _OBS_REGISTRY.counter("cache.hits", cache="jit").inc()
        _JIT_CACHE_HITS[key] = _JIT_CACHE_HITS.get(key, 0) + 1
        _JIT_CACHE.move_to_end(key)
    return fn


def _key_label(key) -> str:
    """Short human-readable label for a jit-cache key (telemetry only)."""
    if isinstance(key, tuple):
        return "|".join(_key_label(k) for k in key)
    name = getattr(key, "__name__", None)
    if isinstance(name, str):
        return name
    if key is None or isinstance(key, (str, int, bool, float)):
        return str(key)
    return type(key).__name__


def jit_cache_stats() -> dict:
    """Engine jit-cache counters (size / hits / misses / evictions), plus
    per-key hit counts for the live entries (labels are best-effort
    summaries of the cache key; colliding labels sum their hits)."""
    per_key: dict = {}
    for key, hits in _JIT_CACHE_HITS.items():
        label = _key_label(key)
        per_key[label] = per_key.get(label, 0) + hits
    return {**_JIT_CACHE_STATS, "size": len(_JIT_CACHE),
            "max_size": _JIT_CACHE_MAX, "per_key": per_key}


# The block sub-plan LRU (``block_subplan``) lives per SparsePlan instance
# but all instances share one configurable cap and one set of counters,
# mirroring the jit cache above: ``REPRO_BLOCK_CACHE_SIZE`` /
# ``configure_block_cache()`` set the cap, ``block_cache_stats()`` feeds
# the serving telemetry.  The cap is applied at insert time, so lowering
# it trims each plan's cache on that plan's next block request.
_BLOCK_CACHE_MAX = _env_cache_size(var="REPRO_BLOCK_CACHE_SIZE")
_BLOCK_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def configure_block_cache(max_size: Optional[int] = None) -> int:
    """Set the block sub-plan LRU cap; with no argument, re-read
    ``REPRO_BLOCK_CACHE_SIZE`` from the environment (default 64).
    Returns the active cap."""
    global _BLOCK_CACHE_MAX
    if max_size is None:
        max_size = _env_cache_size(var="REPRO_BLOCK_CACHE_SIZE")
    assert max_size >= 1, max_size
    _BLOCK_CACHE_MAX = max_size
    return _BLOCK_CACHE_MAX


def block_cache_stats() -> dict:
    """Block sub-plan cache counters (shared across all SparsePlans)."""
    return {**_BLOCK_CACHE_STATS, "max_size": _BLOCK_CACHE_MAX}


def _get_jitted(reducer_fn, mesh, shard_axes):
    def factory():
        run = partial(_gather_reduce, reducer_fn=reducer_fn)
        if mesh is None:
            return jax.jit(run)
        red_sharding, rep = _shardings(mesh, shard_axes)
        return jax.jit(run,
                       in_shardings=(rep, red_sharding, red_sharding),
                       out_shardings=red_sharding)
    return _cache_get((reducer_fn, mesh, shard_axes), factory)


def run_reducers(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    donate: bool = False,
):
    """Execute ``reducer_fn(block (L, d), mask (L,)) -> pytree`` per reducer.

    With a mesh, reducer slots are sharded over ``shard_axes`` (all mesh axes
    by default) and the input table is left replicated — the gather *is* the
    map->reduce shuffle.  Without a mesh, runs locally (CPU tests).
    """
    idx = jnp.asarray(plan.idx)
    mask = jnp.asarray(plan.mask)
    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted(reducer_fn, mesh, shard_axes)
    _record_shapes((reducer_fn, mesh, shard_axes), (inputs, idx, mask))
    return fn(inputs, idx, mask)


# ---------------------------------------------------------------------------
# bucketed (skew-aware) executor
# ---------------------------------------------------------------------------
def _dense_out_shapes(plan: ReducerPlan, reducer_fn, inputs):
    """Per-reducer output ShapeDtypes at the dense width L."""
    blk = jax.ShapeDtypeStruct((plan.L,) + inputs.shape[1:], inputs.dtype)
    msk = jax.ShapeDtypeStruct((plan.L,), jnp.bool_)
    return jax.eval_shape(reducer_fn, blk, msk)


def _pad_leaf_to(leaf, target_shape):
    """Zero-pad trailing extents of ``leaf`` (past its leading batch axis)
    up to ``target_shape`` — the slot-sized axes grow from bucket width to
    the dense width; equal axes are untouched."""
    pads = [(0, 0)]
    for have, want in zip(leaf.shape[1:], target_shape):
        assert have <= want, (leaf.shape, target_shape)
        pads.append((0, want - have))
    if any(p != (0, 0) for p in pads):
        leaf = jnp.pad(leaf, pads)
    return leaf


def run_reducers_bucketed(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    combine: str = "dense",
):
    """Skew-aware execution: one vmapped gather+reduce per capacity bucket.

    Each bucket pads only to its own width, so a single heavy reducer no
    longer inflates every light reducer to the global max slot count — on a
    Zipf-sized schema this cuts the gathered elements (and the quadratic
    reducer FLOPs of block reducers) by the plan's ``padding_savings``.

    combine='dense'    — return one pytree shaped exactly like the dense
        ``run_reducers`` output: bucket outputs are zero-padded along their
        slot-sized axes to the dense width and scattered back into original
        reducer order.  Rows past ``plan.num_reducers`` (mesh padding) are
        zeros, so ``reducer_fn`` must zero its masked-out output entries for
        the two executors to agree there (all shipped reducer functions do).
    combine='buckets'  — return ``[(bucket, out_pytree), ...]`` unpadded;
        downstream consumers (e.g. the per-bucket pair-matrix assembler)
        keep the memory win end-to-end.

    ``reducer_fn`` must be shape-polymorphic over the slot count L — it is
    traced once per bucket width.
    """
    assert combine in ("dense", "buckets"), combine
    buckets = plan.buckets
    if not buckets:
        # plans built before bucketing / empty schemas: dense semantics
        out = run_reducers(inputs, plan, reducer_fn, mesh=mesh,
                           shard_axes=shard_axes)
        return out if combine == "dense" else []

    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted(reducer_fn, mesh, shard_axes)

    per_bucket = []
    for b in buckets:
        idx, mask = jnp.asarray(b.idx), jnp.asarray(b.mask)
        _record_shapes((reducer_fn, mesh, shard_axes), (inputs, idx, mask))
        per_bucket.append((b, fn(inputs, idx, mask)))
    if combine == "buckets":
        return per_bucket

    dense_shapes = _dense_out_shapes(plan, reducer_fn, inputs)
    leaves_t, treedef = jax.tree.flatten(dense_shapes)
    acc = [jnp.zeros((plan.R,) + t.shape, t.dtype) for t in leaves_t]
    for b, out in per_bucket:
        valid = b.rows >= 0                      # static numpy mask
        rows = jnp.asarray(b.rows[valid])
        for i, leaf in enumerate(jax.tree.flatten(out)[0]):
            padded = _pad_leaf_to(leaf, leaves_t[i].shape)
            acc[i] = acc[i].at[rows].set(padded[np.flatnonzero(valid)])
    return jax.tree.unflatten(treedef, acc)


# ---------------------------------------------------------------------------
# rectangular (X2Y) runners
# ---------------------------------------------------------------------------
def _gather_reduce_x2y(xt, yt, xidx, xmask, yidx, ymask, reducer_fn):
    gx = jnp.take(xt, xidx, axis=0)              # (R, Lx, d) — X-side shuffle
    gx = jnp.where(xmask[..., None], gx, 0)
    gy = jnp.take(yt, yidx, axis=0)              # (R, Ly, d) — Y-side shuffle
    gy = jnp.where(ymask[..., None], gy, 0)
    return jax.vmap(reducer_fn)(gx, xmask, gy, ymask)


def _get_jitted_x2y(reducer_fn, mesh, shard_axes):
    def factory():
        run = partial(_gather_reduce_x2y, reducer_fn=reducer_fn)
        if mesh is None:
            return jax.jit(run)
        red_sharding, rep = _shardings(mesh, shard_axes)
        return jax.jit(run,
                       in_shardings=(rep, rep, red_sharding, red_sharding,
                                     red_sharding, red_sharding),
                       out_shardings=red_sharding)
    return _cache_get(("x2y", reducer_fn, mesh, shard_axes), factory)


def _as_tables(tables):
    """(x_table, y_table) from a pair or a single shared table (X == Y)."""
    if isinstance(tables, (tuple, list)):
        xt, yt = tables
    else:
        xt = yt = tables
    return jnp.asarray(xt), jnp.asarray(yt)


def run_reducers_x2y(
    tables,                                # (x (mx, dx), y (my, dy)) pair
    plan: ReducerPlan,
    reducer_fn: Callable,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
):
    """Dense rectangular execution: ``reducer_fn(xblock (Lx, dx),
    xmask (Lx,), yblock (Ly, dy), ymask (Ly,)) -> pytree`` per reducer.

    The two gathers are the bipartite shuffle — X rows and Y rows ship to
    their reducer slots independently.  ``tables`` may be one array (shared
    table) or an (x, y) pair."""
    assert plan.is_rect, "run_reducers_x2y needs a rectangular plan"
    xt, yt = _as_tables(tables)
    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted_x2y(reducer_fn, mesh, shard_axes)
    args = (xt, yt, jnp.asarray(plan.idx), jnp.asarray(plan.mask),
            jnp.asarray(plan.yidx), jnp.asarray(plan.ymask))
    _record_shapes(("x2y", reducer_fn, mesh, shard_axes), args)
    return fn(*args)


def _dense_out_shapes_x2y(plan: ReducerPlan, reducer_fn, xt, yt):
    xb = jax.ShapeDtypeStruct((plan.L,) + xt.shape[1:], xt.dtype)
    xm = jax.ShapeDtypeStruct((plan.L,), jnp.bool_)
    yb = jax.ShapeDtypeStruct((plan.Ly,) + yt.shape[1:], yt.dtype)
    ym = jax.ShapeDtypeStruct((plan.Ly,), jnp.bool_)
    return jax.eval_shape(reducer_fn, xb, xm, yb, ym)


def run_reducers_x2y_bucketed(
    tables,
    plan: ReducerPlan,
    reducer_fn: Callable,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    combine: str = "dense",
):
    """Skew-aware rectangular execution: one vmapped double-gather+reduce
    per (wx, wy) capacity bucket.  Semantics mirror
    :func:`run_reducers_bucketed`: ``combine='dense'`` scatters bucket
    outputs (padded on both slot axes to the dense (Lx, Ly)) back into
    original reducer order; ``combine='buckets'`` returns
    ``[(bucket, out_pytree), ...]`` unpadded."""
    assert combine in ("dense", "buckets"), combine
    assert plan.is_rect, "run_reducers_x2y_bucketed needs a rect plan"
    buckets = plan.buckets
    if not buckets:
        out = run_reducers_x2y(tables, plan, reducer_fn, mesh=mesh,
                               shard_axes=shard_axes)
        return out if combine == "dense" else []

    xt, yt = _as_tables(tables)
    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted_x2y(reducer_fn, mesh, shard_axes)

    per_bucket = []
    for b in buckets:
        args = (xt, yt, jnp.asarray(b.idx), jnp.asarray(b.mask),
                jnp.asarray(b.yidx), jnp.asarray(b.ymask))
        _record_shapes(("x2y", reducer_fn, mesh, shard_axes), args)
        per_bucket.append((b, fn(*args)))
    if combine == "buckets":
        return per_bucket

    dense_shapes = _dense_out_shapes_x2y(plan, reducer_fn, xt, yt)
    leaves_t, treedef = jax.tree.flatten(dense_shapes)
    acc = [jnp.zeros((plan.R,) + t.shape, t.dtype) for t in leaves_t]
    for b, out in per_bucket:
        valid = b.rows >= 0                      # static numpy mask
        rows = jnp.asarray(b.rows[valid])
        for i, leaf in enumerate(jax.tree.flatten(out)[0]):
            padded = _pad_leaf_to(leaf, leaves_t[i].shape)
            acc[i] = acc[i].at[rows].set(padded[np.flatnonzero(valid)])
    return jax.tree.unflatten(treedef, acc)


# ---------------------------------------------------------------------------
# fused + sharded executors: thin shims over the executor registry
# ---------------------------------------------------------------------------
# The implementations live in ``repro.mapreduce.executors`` as registry
# objects with instance-scoped ``stats()``/``reset()``.  ``fused_stats()``
# below is the documented *aggregate* view: every ``FusedExecutor``
# instance publishes its increments into the obs registry's
# ``executor.<key>{executor=fused}`` series (one series per executor name,
# shared by all instances), and this shim sums them.  ``FUSED_STATS`` is
# retained as a legacy name only — it is no longer wired to any instance
# (the old shared-dict default made ``service.reset_stats()`` silently
# zero other callers' telemetry).
FUSED_STATS = {"calls": 0, "kernel": 0, "streamed": 0, "fallbacks": 0}

_FUSED_KEYS = ("calls", "kernel", "streamed", "fallbacks")


def fused_stats() -> dict:
    """Aggregate fused dispatch counters across every ``FusedExecutor``
    instance (the default registry instance and all ``make_executor``
    copies), read from the observability registry."""
    return {k: int(_OBS_REGISTRY.counter_total(f"executor.{k}",
                                               executor="fused"))
            for k in _FUSED_KEYS}


def reset_fused_stats() -> None:
    """Zero the aggregate fused counters (all instances' published
    series)."""
    for k in _FUSED_KEYS:
        _OBS_REGISTRY.reset_counters(f"executor.{k}", executor="fused")
    for k in FUSED_STATS:
        FUSED_STATS[k] = 0


def run_reducers_fused(inputs, plan, reducer_fn, **kwargs):
    """Fused shuffle execution: the gathered block stays out of HBM.

    Shim over ``get_executor("fused").run`` — see
    :class:`repro.mapreduce.executors.FusedExecutor` for the full contract
    (per-bucket fused gather+Gram kernel / jnp tile-twin, one jitted program
    for all buckets, bucketed fallback for non-Gram reducers).
    """
    from .executors import get_executor
    return get_executor("fused").run(inputs, plan, reducer_fn, **kwargs)


def run_reducers_sharded(inputs, plan, reducer_fn, **kwargs):
    """Shard-balanced multi-device execution (DESIGN.md "sharded execution").

    Shim over ``get_executor("sharded").run`` — see
    :class:`repro.mapreduce.executors.ShardedExecutor`: the plan is
    LPT-partitioned into per-shard sub-plans and the fused/bucketed pipeline
    runs per shard under ``shard_map`` over the mesh's reducer axis.
    """
    from .executors import get_executor
    return get_executor("sharded").run(inputs, plan, reducer_fn, **kwargs)


def lower_reducers(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    reducer_fn: Callable,
    mesh: jax.sharding.Mesh,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
):
    """Lower (no execution) for dry-run / roofline analysis.

    ``mesh=None`` lowers the unsharded single-program form (used by the
    benchmark's HLO buffer checks)."""
    idx = jax.ShapeDtypeStruct(plan.idx.shape, jnp.int32)
    mask = jax.ShapeDtypeStruct(plan.mask.shape, jnp.bool_)
    x = jax.ShapeDtypeStruct(input_shape, dtype)

    _run = partial(_gather_reduce, reducer_fn=reducer_fn)
    if mesh is None:
        return jax.jit(_run).lower(x, idx, mask)
    red_sharding, rep = _shardings(mesh, shard_axes)
    fn = jax.jit(
        _run,
        in_shardings=(rep, red_sharding, red_sharding),
        out_shardings=red_sharding,
    )
    return fn.lower(x, idx, mask)


def lower_reducers_bucketed(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    reducer_fn: Callable,
    mesh: jax.sharding.Mesh,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
) -> list:
    """Lower every bucket program (no execution) for dry-run / roofline.

    Returns ``[(bucket, lowered), ...]``; per-device roofline terms add up
    across buckets (the programs run back-to-back on the same mesh).
    ``mesh=None`` lowers the unsharded single-program form of each bucket
    (the streaming dry-run's delta-vs-replan byte comparison)."""
    x = jax.ShapeDtypeStruct(input_shape, dtype)
    _run = partial(_gather_reduce, reducer_fn=reducer_fn)
    if mesh is None:
        fn = jax.jit(_run)
    else:
        red_sharding, rep = _shardings(mesh, shard_axes)
        fn = jax.jit(_run, in_shardings=(rep, red_sharding, red_sharding),
                     out_shardings=red_sharding)
    out = []
    for b in plan.buckets:
        idx = jax.ShapeDtypeStruct(b.idx.shape, jnp.int32)
        mask = jax.ShapeDtypeStruct(b.mask.shape, jnp.bool_)
        out.append((b, fn.lower(x, idx, mask)))
    return out


def lower_reducers_fused(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    metric: str,
    mesh: Optional[jax.sharding.Mesh] = None,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
    combine: str = "buckets",
    use_kernel: bool = False,
    bl: int = 128,
):
    """Lower the fused executor's single all-bucket program (no execution).

    Shim over ``get_executor("fused").lower``; defaults to the streamed
    (jnp) lowering so the dry-run works on any backend.  Returns one
    ``Lowered``."""
    from .executors import get_executor
    return get_executor("fused").lower(
        input_shape, plan, metric=metric, mesh=mesh, dtype=dtype,
        shard_axes=shard_axes, combine=combine, use_kernel=use_kernel, bl=bl)
