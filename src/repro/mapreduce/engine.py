"""Mapping schema -> static gather plan -> sharded reducer execution.

The MapReduce shuffle of the paper is adapted to TPU/JAX as follows
(DESIGN.md "hardware adaptation"):

  * reducers become *reducer slots*, a leading array dimension sharded across
    the device mesh;
  * the map->reduce shuffle becomes ``jnp.take`` from the input array with a
    static index matrix computed from the schema — XLA lowers this to
    all-gather/collective traffic whose volume is the schema's communication
    cost (this is what the roofline benchmark measures);
  * the reduce function is vmapped over slots, so every device processes its
    slots in parallel (the MXU does the per-reducer all-pairs work through
    the Pallas ``pairwise`` kernel).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schema import MappingSchema

__all__ = ["ReducerPlan", "build_plan", "run_reducers"]


@dataclasses.dataclass(frozen=True)
class ReducerPlan:
    """Static arrays derived from a MappingSchema.

    idx   (R, L) int32 — input ids per reducer slot; padded entries point at
          input 0 and are masked out.
    mask  (R, L) bool  — slot validity.

    The plan also carries the schema's provenance so downstream telemetry
    (benchmarks, serving dashboards) can report which registry strategy
    produced the traffic and how far it sits from the paper's
    replication-rate lower bound.
    """

    idx: np.ndarray
    mask: np.ndarray
    num_reducers: int          # before padding
    comm_cost: float           # schema communication cost (weighted bytes)
    max_inputs: int
    algorithm: str = "unknown"             # winning strategy (provenance)
    lower_bound: Optional[float] = None    # paper's comm lower bound

    @property
    def R(self) -> int:
        return int(self.idx.shape[0])

    @property
    def L(self) -> int:
        return int(self.idx.shape[1])

    @property
    def optimality_gap(self) -> Optional[float]:
        """comm_cost / lower_bound (>= 1.0), or None without a bound."""
        if self.lower_bound is None or self.lower_bound <= 0.0:
            return None
        return self.comm_cost / self.lower_bound


def build_plan(schema: MappingSchema, *, pad_reducers_to: int = 1,
               pad_slots_to: int = 1) -> ReducerPlan:
    """Flatten a schema into (idx, mask).  ``pad_reducers_to`` rounds the
    reducer count up to a multiple (device count), ``pad_slots_to`` rounds the
    per-reducer slot count (kernel tile alignment)."""
    expanded = schema.expand()
    R0 = len(expanded)
    L0 = max((len(ids) for ids in expanded), default=1)
    L = -(-L0 // pad_slots_to) * pad_slots_to
    R = -(-max(R0, 1) // pad_reducers_to) * pad_reducers_to
    idx = np.zeros((R, L), dtype=np.int32)
    mask = np.zeros((R, L), dtype=bool)
    for r, ids in enumerate(expanded):
        idx[r, : len(ids)] = ids
        mask[r, : len(ids)] = True
    return ReducerPlan(idx=idx, mask=mask, num_reducers=R0,
                       comm_cost=schema.communication_cost(), max_inputs=L0,
                       algorithm=schema.algorithm,
                       lower_bound=schema.lower_bound)


def run_reducers(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    donate: bool = False,
):
    """Execute ``reducer_fn(block (L, d), mask (L,)) -> pytree`` per reducer.

    With a mesh, reducer slots are sharded over ``shard_axes`` (all mesh axes
    by default) and the input table is left replicated — the gather *is* the
    map->reduce shuffle.  Without a mesh, runs locally (CPU tests).
    """
    idx = jnp.asarray(plan.idx)
    mask = jnp.asarray(plan.mask)

    def _run(x, idx, mask):
        gathered = jnp.take(x, idx, axis=0)          # (R, L, d) — the shuffle
        gathered = jnp.where(mask[..., None], gathered, 0)
        return jax.vmap(reducer_fn)(gathered, mask)

    if mesh is None:
        return jax.jit(_run)(inputs, idx, mask)

    axes = shard_axes if shard_axes is not None else mesh.axis_names
    P = jax.sharding.PartitionSpec
    red_sharding = jax.sharding.NamedSharding(mesh, P(axes))
    rep = jax.sharding.NamedSharding(mesh, P())
    fn = jax.jit(
        _run,
        in_shardings=(rep, red_sharding, red_sharding),
        out_shardings=red_sharding,
    )
    return fn(inputs, idx, mask)


def lower_reducers(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    reducer_fn: Callable,
    mesh: jax.sharding.Mesh,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
):
    """Lower (no execution) for dry-run / roofline analysis."""
    idx = jax.ShapeDtypeStruct(plan.idx.shape, jnp.int32)
    mask = jax.ShapeDtypeStruct(plan.mask.shape, jnp.bool_)
    x = jax.ShapeDtypeStruct(input_shape, dtype)

    def _run(x, idx, mask):
        gathered = jnp.take(x, idx, axis=0)
        gathered = jnp.where(mask[..., None], gathered, 0)
        return jax.vmap(reducer_fn)(gathered, mask)

    axes = shard_axes if shard_axes is not None else mesh.axis_names
    P = jax.sharding.PartitionSpec
    red_sharding = jax.sharding.NamedSharding(mesh, P(axes))
    rep = jax.sharding.NamedSharding(mesh, P())
    fn = jax.jit(
        _run,
        in_shardings=(rep, red_sharding, red_sharding),
        out_shardings=red_sharding,
    )
    return fn.lower(x, idx, mask)
