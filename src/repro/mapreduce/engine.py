"""Mapping schema -> static gather plan -> sharded reducer execution.

The MapReduce shuffle of the paper is adapted to TPU/JAX as follows
(DESIGN.md "hardware adaptation"):

  * reducers become *reducer slots*, a leading array dimension sharded across
    the device mesh;
  * the map->reduce shuffle becomes ``jnp.take`` from the input array with a
    static index matrix computed from the schema — XLA lowers this to
    all-gather/collective traffic whose volume is the schema's communication
    cost (this is what the roofline benchmark measures);
  * the reduce function is vmapped over slots, so every device processes its
    slots in parallel (the MXU does the per-reducer all-pairs work through
    the Pallas ``pairwise`` kernel).

Two executors share the plan format:

``run_reducers``           — the dense path: one gather padded to the global
                             max slot count.  Simple, one XLA program, but a
                             single heavy reducer forces every other reducer
                             to pad to its width — quadratic waste for
                             reducer functions like the all-pairs Gram block.
``run_reducers_bucketed``  — the skew-aware path (DESIGN.md "bucketed shuffle
                             execution"): reducers are grouped into capacity
                             buckets (powers-of-two over per-reducer slot
                             counts, ``repro.core.planner.compute_buckets``),
                             one vmapped gather+reduce per bucket, each
                             padded only to its own bucket width, outputs
                             reassembled in original reducer order.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import compute_buckets
from repro.core.schema import MappingSchema

__all__ = [
    "ReducerBucket",
    "ReducerPlan",
    "build_plan",
    "run_reducers",
    "run_reducers_bucketed",
    "lower_reducers",
    "lower_reducers_bucketed",
]


@dataclasses.dataclass(frozen=True)
class ReducerBucket:
    """One capacity bucket of the plan: reducers padded to a shared width.

    rows  (Rb,) int64 — original plan-row ids in bucket order; -1 marks a
          padding row added so the bucket divides the device count.
    idx   (Rb, width) int32 / mask (Rb, width) bool — same layout as the
          dense plan, but only ``width`` slots wide.
    """

    width: int
    rows: np.ndarray
    idx: np.ndarray
    mask: np.ndarray

    @property
    def R(self) -> int:
        return int(self.idx.shape[0])

    @property
    def num_real(self) -> int:
        return int(np.sum(self.rows >= 0))

    @property
    def padded_elements(self) -> int:
        return self.R * self.width


@dataclasses.dataclass(frozen=True)
class ReducerPlan:
    """Static arrays derived from a MappingSchema.

    idx   (R, L) int32 — input ids per reducer slot; padded entries point at
          input 0 and are masked out.
    mask  (R, L) bool  — slot validity.
    buckets — capacity buckets over the same reducers (skew-aware executor);
          every real reducer row appears in exactly one bucket.

    The plan also carries the schema's provenance so downstream telemetry
    (benchmarks, serving dashboards) can report which registry strategy
    produced the traffic and how far it sits from the paper's
    replication-rate lower bound.
    """

    idx: np.ndarray
    mask: np.ndarray
    num_reducers: int          # before padding
    comm_cost: float           # schema communication cost (weighted bytes)
    max_inputs: int
    algorithm: str = "unknown"             # winning strategy (provenance)
    lower_bound: Optional[float] = None    # paper's comm lower bound
    buckets: tuple[ReducerBucket, ...] = ()

    @property
    def R(self) -> int:
        return int(self.idx.shape[0])

    @property
    def L(self) -> int:
        return int(self.idx.shape[1])

    @property
    def optimality_gap(self) -> Optional[float]:
        """comm_cost / lower_bound (>= 1.0), or None without a bound."""
        if self.lower_bound is None or self.lower_bound <= 0.0:
            return None
        return self.comm_cost / self.lower_bound

    # ---------------------------------------------------------- telemetry
    @property
    def dense_padded_elements(self) -> int:
        """Gather slots the dense executor materializes (R x L)."""
        return self.R * self.L

    @property
    def bucketed_padded_elements(self) -> int:
        """Gather slots the bucketed executor materializes."""
        if not self.buckets:
            return self.dense_padded_elements
        return sum(b.padded_elements for b in self.buckets)

    @property
    def padding_savings(self) -> float:
        """dense / bucketed padded elements (>= 1.0 up to row padding)."""
        return self.dense_padded_elements / max(self.bucketed_padded_elements,
                                                1)

    def bucket_widths(self) -> list[int]:
        return [b.width for b in self.buckets]


def _build_buckets(expanded: list[list[int]], *, pad_slots_to: int,
                   pad_reducers_to: int,
                   max_buckets: int) -> tuple[ReducerBucket, ...]:
    """Capacity buckets over expanded reducers (original row order kept
    within each bucket; rows padded to a multiple of ``pad_reducers_to``)."""
    counts = [len(ids) for ids in expanded]
    out = []
    for width, rows in compute_buckets(counts, pad_slots_to=pad_slots_to,
                                       max_buckets=max_buckets):
        Rb = -(-max(len(rows), 1) // pad_reducers_to) * pad_reducers_to
        idx = np.zeros((Rb, width), dtype=np.int32)
        mask = np.zeros((Rb, width), dtype=bool)
        rows_padded = np.full(Rb, -1, dtype=np.int64)
        rows_padded[: len(rows)] = rows
        for i, r in enumerate(rows):
            ids = expanded[r]
            idx[i, : len(ids)] = ids
            mask[i, : len(ids)] = True
        out.append(ReducerBucket(width=width, rows=rows_padded, idx=idx,
                                 mask=mask))
    return tuple(out)


def build_plan(schema: MappingSchema, *, pad_reducers_to: int = 1,
               pad_slots_to: int = 1, max_buckets: int = 8) -> ReducerPlan:
    """Flatten a schema into (idx, mask) plus capacity buckets.

    ``pad_reducers_to`` rounds reducer counts up to a multiple (device
    count) — applied to the dense plan and to every bucket independently;
    ``pad_slots_to`` rounds slot counts (kernel tile alignment);
    ``max_buckets`` bounds the number of capacity buckets (dispatch
    overhead of the bucketed executor)."""
    expanded = schema.expand()
    R0 = len(expanded)
    L0 = max((len(ids) for ids in expanded), default=1)
    L = -(-L0 // pad_slots_to) * pad_slots_to
    R = -(-max(R0, 1) // pad_reducers_to) * pad_reducers_to
    idx = np.zeros((R, L), dtype=np.int32)
    mask = np.zeros((R, L), dtype=bool)
    for r, ids in enumerate(expanded):
        idx[r, : len(ids)] = ids
        mask[r, : len(ids)] = True
    buckets = _build_buckets(expanded, pad_slots_to=pad_slots_to,
                             pad_reducers_to=pad_reducers_to,
                             max_buckets=max_buckets)
    return ReducerPlan(idx=idx, mask=mask, num_reducers=R0,
                       comm_cost=schema.communication_cost(), max_inputs=L0,
                       algorithm=schema.algorithm,
                       lower_bound=schema.lower_bound,
                       buckets=buckets)


def _shardings(mesh, shard_axes):
    axes = shard_axes if shard_axes is not None else mesh.axis_names
    P = jax.sharding.PartitionSpec
    red = jax.sharding.NamedSharding(mesh, P(axes))
    rep = jax.sharding.NamedSharding(mesh, P())
    return red, rep


def _gather_reduce(x, idx, mask, reducer_fn):
    gathered = jnp.take(x, idx, axis=0)          # (R, L, d) — the shuffle
    gathered = jnp.where(mask[..., None], gathered, 0)
    return jax.vmap(reducer_fn)(gathered, mask)


# One jitted executable per (reducer_fn, mesh, shard_axes): repeated calls —
# a serving loop, the benchmark's timed iterations, every bucket of a
# bucketed run — reuse the XLA compile cache instead of re-tracing through
# a fresh jax.jit wrapper each time.  Callers enable reuse by passing the
# *same* reducer_fn object (see allpairs._block_fn).
_JIT_CACHE: dict = {}


def _get_jitted(reducer_fn, mesh, shard_axes):
    key = (reducer_fn, mesh, shard_axes)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        run = partial(_gather_reduce, reducer_fn=reducer_fn)
        if mesh is None:
            fn = jax.jit(run)
        else:
            red_sharding, rep = _shardings(mesh, shard_axes)
            fn = jax.jit(run,
                         in_shardings=(rep, red_sharding, red_sharding),
                         out_shardings=red_sharding)
        _JIT_CACHE[key] = fn
    return fn


def run_reducers(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    donate: bool = False,
):
    """Execute ``reducer_fn(block (L, d), mask (L,)) -> pytree`` per reducer.

    With a mesh, reducer slots are sharded over ``shard_axes`` (all mesh axes
    by default) and the input table is left replicated — the gather *is* the
    map->reduce shuffle.  Without a mesh, runs locally (CPU tests).
    """
    idx = jnp.asarray(plan.idx)
    mask = jnp.asarray(plan.mask)
    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted(reducer_fn, mesh, shard_axes)
    return fn(inputs, idx, mask)


# ---------------------------------------------------------------------------
# bucketed (skew-aware) executor
# ---------------------------------------------------------------------------
def _dense_out_shapes(plan: ReducerPlan, reducer_fn, inputs):
    """Per-reducer output ShapeDtypes at the dense width L."""
    blk = jax.ShapeDtypeStruct((plan.L,) + inputs.shape[1:], inputs.dtype)
    msk = jax.ShapeDtypeStruct((plan.L,), jnp.bool_)
    return jax.eval_shape(reducer_fn, blk, msk)


def _pad_leaf_to(leaf, target_shape):
    """Zero-pad trailing extents of ``leaf`` (past its leading batch axis)
    up to ``target_shape`` — the slot-sized axes grow from bucket width to
    the dense width; equal axes are untouched."""
    pads = [(0, 0)]
    for have, want in zip(leaf.shape[1:], target_shape):
        assert have <= want, (leaf.shape, target_shape)
        pads.append((0, want - have))
    if any(p != (0, 0) for p in pads):
        leaf = jnp.pad(leaf, pads)
    return leaf


def run_reducers_bucketed(
    inputs: jax.Array,                     # (m, d) one row per input
    plan: ReducerPlan,
    reducer_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    shard_axes: Optional[tuple[str, ...]] = None,
    combine: str = "dense",
):
    """Skew-aware execution: one vmapped gather+reduce per capacity bucket.

    Each bucket pads only to its own width, so a single heavy reducer no
    longer inflates every light reducer to the global max slot count — on a
    Zipf-sized schema this cuts the gathered elements (and the quadratic
    reducer FLOPs of block reducers) by the plan's ``padding_savings``.

    combine='dense'    — return one pytree shaped exactly like the dense
        ``run_reducers`` output: bucket outputs are zero-padded along their
        slot-sized axes to the dense width and scattered back into original
        reducer order.  Rows past ``plan.num_reducers`` (mesh padding) are
        zeros, so ``reducer_fn`` must zero its masked-out output entries for
        the two executors to agree there (all shipped reducer functions do).
    combine='buckets'  — return ``[(bucket, out_pytree), ...]`` unpadded;
        downstream consumers (e.g. the per-bucket pair-matrix assembler)
        keep the memory win end-to-end.

    ``reducer_fn`` must be shape-polymorphic over the slot count L — it is
    traced once per bucket width.
    """
    assert combine in ("dense", "buckets"), combine
    buckets = plan.buckets
    if not buckets:
        # plans built before bucketing / empty schemas: dense semantics
        out = run_reducers(inputs, plan, reducer_fn, mesh=mesh,
                           shard_axes=shard_axes)
        return out if combine == "dense" else []

    shard_axes = tuple(shard_axes) if shard_axes is not None else None
    fn = _get_jitted(reducer_fn, mesh, shard_axes)

    per_bucket = [
        (b, fn(inputs, jnp.asarray(b.idx), jnp.asarray(b.mask)))
        for b in buckets
    ]
    if combine == "buckets":
        return per_bucket

    dense_shapes = _dense_out_shapes(plan, reducer_fn, inputs)
    leaves_t, treedef = jax.tree.flatten(dense_shapes)
    acc = [jnp.zeros((plan.R,) + t.shape, t.dtype) for t in leaves_t]
    for b, out in per_bucket:
        valid = b.rows >= 0                      # static numpy mask
        rows = jnp.asarray(b.rows[valid])
        for i, leaf in enumerate(jax.tree.flatten(out)[0]):
            padded = _pad_leaf_to(leaf, leaves_t[i].shape)
            acc[i] = acc[i].at[rows].set(padded[np.flatnonzero(valid)])
    return jax.tree.unflatten(treedef, acc)


def lower_reducers(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    reducer_fn: Callable,
    mesh: jax.sharding.Mesh,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
):
    """Lower (no execution) for dry-run / roofline analysis."""
    idx = jax.ShapeDtypeStruct(plan.idx.shape, jnp.int32)
    mask = jax.ShapeDtypeStruct(plan.mask.shape, jnp.bool_)
    x = jax.ShapeDtypeStruct(input_shape, dtype)

    _run = partial(_gather_reduce, reducer_fn=reducer_fn)
    red_sharding, rep = _shardings(mesh, shard_axes)
    fn = jax.jit(
        _run,
        in_shardings=(rep, red_sharding, red_sharding),
        out_shardings=red_sharding,
    )
    return fn.lower(x, idx, mask)


def lower_reducers_bucketed(
    input_shape: tuple[int, int],
    plan: ReducerPlan,
    reducer_fn: Callable,
    mesh: jax.sharding.Mesh,
    dtype=jnp.float32,
    shard_axes: Optional[tuple[str, ...]] = None,
) -> list:
    """Lower every bucket program (no execution) for dry-run / roofline.

    Returns ``[(bucket, lowered), ...]``; per-device roofline terms add up
    across buckets (the programs run back-to-back on the same mesh)."""
    x = jax.ShapeDtypeStruct(input_shape, dtype)
    _run = partial(_gather_reduce, reducer_fn=reducer_fn)
    red_sharding, rep = _shardings(mesh, shard_axes)
    fn = jax.jit(_run, in_shardings=(rep, red_sharding, red_sharding),
                 out_shardings=red_sharding)
    out = []
    for b in plan.buckets:
        idx = jax.ShapeDtypeStruct(b.idx.shape, jnp.int32)
        mask = jax.ShapeDtypeStruct(b.mask.shape, jnp.bool_)
        out.append((b, fn.lower(x, idx, mask)))
    return out
