"""A2A applications: all-pairs similarity (common friends, drug interaction).

Every input is a feature row (multi-hot friend vector, patient-history
embedding, ...).  The planner guarantees each pair of rows meets at >= 1
reducer; reducers compute the dense pairwise block with the MXU-friendly
``pairwise`` kernel; results are scattered back into the (m, m) matrix.

``some_pairs_similarity`` is the sparse variant (Ullman & Ullman's
some-pairs problem): only an explicit pair set must meet, the planner
ships only pair-incident inputs, and the result is masked to the
requested pairs.
"""

from __future__ import annotations

import functools
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import span as _obs_span

from repro.core import (plan_a2a, plan_a2a_hierarchical, plan_some_pairs,
                        plan_x2y)
from repro.core.schema import MappingSchema

from .engine import (ReducerPlan, SparsePlan, build_plan,
                     build_sparse_plan, build_x2y_plan)
from .executors import get_executor

__all__ = [
    "pairwise_similarity",
    "pairwise_similarity_block",
    "some_pairs_similarity",
    "x2y_similarity",
    "assemble_pair_matrix",
    "assemble_pair_matrix_bucketed",
    "assemble_x2y_matrix_bucketed",
    "block_similarity",
    "block_similarity_x2y",
]


def block_similarity(block: jax.Array, mask: jax.Array, *,
                     metric: str = "dot", use_kernel: bool = False):
    """(L, d), (L,) -> (L, L) similarity of the valid rows; invalid -> 0."""
    if use_kernel:
        from repro.kernels.pairwise.ops import pairwise_kernel
        sims = pairwise_kernel(block, metric=metric, interpret=True)
    else:
        if metric == "dot":
            sims = block @ block.T
        elif metric == "l2":
            n2 = jnp.sum(block * block, axis=-1)
            sims = n2[:, None] + n2[None, :] - 2.0 * (block @ block.T)
        elif metric == "cosine":
            nrm = jnp.sqrt(jnp.sum(block * block, axis=-1) + 1e-9)
            sims = (block @ block.T) / (nrm[:, None] * nrm[None, :])
        else:
            raise ValueError(metric)
    valid = mask[:, None] & mask[None, :]
    return jnp.where(valid, sims, 0.0)


@functools.lru_cache(maxsize=None)
def _block_fn(metric: str, use_kernel: bool):
    """Memoized reducer: the same (metric, use_kernel) must map to the
    *same* function object so the engine's jit cache is hit across calls
    instead of re-tracing every request.  The ``fused_metric`` tag is what
    lets the fused executor recognize this reducer as a Gram block and
    compute it without materializing the gather (non-tagged reducers fall
    back to the bucketed path)."""
    def fn(block, mask):
        return block_similarity(block, mask, metric=metric,
                                use_kernel=use_kernel)
    fn.__name__ = f"block_similarity_{metric}"
    fn.fused_metric = metric
    return fn


def block_similarity_x2y(xblock: jax.Array, xmask: jax.Array,
                         yblock: jax.Array, ymask: jax.Array, *,
                         metric: str = "dot"):
    """(Lx, d), (Lx,), (Ly, d), (Ly,) -> (Lx, Ly) cross similarity of the
    valid rows; invalid pairs -> 0.  The rectangular analogue of
    :func:`block_similarity` (which is the degenerate X == Y case)."""
    if metric == "dot":
        sims = xblock @ yblock.T
    elif metric == "l2":
        n2x = jnp.sum(xblock * xblock, axis=-1)
        n2y = jnp.sum(yblock * yblock, axis=-1)
        sims = n2x[:, None] + n2y[None, :] - 2.0 * (xblock @ yblock.T)
    elif metric == "cosine":
        nx = jnp.sqrt(jnp.sum(xblock * xblock, axis=-1) + 1e-9)
        ny = jnp.sqrt(jnp.sum(yblock * yblock, axis=-1) + 1e-9)
        sims = (xblock @ yblock.T) / (nx[:, None] * ny[None, :])
    else:
        raise ValueError(metric)
    valid = xmask[:, None] & ymask[None, :]
    return jnp.where(valid, sims, 0.0)


@functools.lru_cache(maxsize=None)
def _block_fn_x2y(metric: str):
    """Memoized two-sided reducer (same reuse contract as ``_block_fn``).
    The ``fused_metric`` tag lets the fused/sharded executors run the
    rectangular gather+Gram path instead of materializing the gathers."""
    def fn(xblock, xmask, yblock, ymask):
        return block_similarity_x2y(xblock, xmask, yblock, ymask,
                                    metric=metric)
    fn.__name__ = f"block_similarity_x2y_{metric}"
    fn.fused_metric = metric
    return fn


def _plan_for(schema, *, pad_reducers_to: int, pad_slots_to: int):
    """``build_plan`` memoized on the schema object.

    Plans are pure functions of (schema, padding); caching them on the
    schema keeps the per-request host work O(1) for repeated profiles —
    the same static-plan reuse contract as ``repro.core.PLAN_CACHE``."""
    key = (pad_reducers_to, pad_slots_to)
    cache = schema.__dict__.setdefault("_reducer_plan_cache", {})
    plan = cache.get(key)
    if plan is None:
        plan = build_plan(schema, pad_reducers_to=pad_reducers_to,
                          pad_slots_to=pad_slots_to)
        cache[key] = plan
    return plan


def _x2y_plan_for(schema, num_x: int, *, pad_reducers_to: int,
                  pad_slots_to: int):
    """``build_x2y_plan`` memoized on the schema object (same contract as
    ``_plan_for``)."""
    key = ("x2y", num_x, pad_reducers_to, pad_slots_to)
    cache = schema.__dict__.setdefault("_reducer_plan_cache", {})
    plan = cache.get(key)
    if plan is None:
        plan = build_x2y_plan(schema, num_x,
                              pad_reducers_to=pad_reducers_to,
                              pad_slots_to=pad_slots_to)
        cache[key] = plan
    return plan


def _pair_source_map_rect(plan: ReducerPlan, mx: int,
                          my: int) -> np.ndarray:
    """Rectangular inverse-shuffle map: (mx, my) int32 positions into the
    concatenation ``[0.0, blocks_0.ravel(), ...]`` of per-bucket cross-Gram
    stacks.  Like :func:`_pair_source_map` with decoupled axes — rows come
    from each bucket's X-side ids, columns from its Y-side ids, and there
    is no diagonal to zero (an (x, y) pair is never a self-pair).
    Uncovered cells point at slot 0 (-> 0.0).  Cached on the plan."""
    cached = plan.__dict__.get("_pair_srcmap_rect")
    if cached is not None and cached[0] == (mx, my):
        return cached[1]
    srcmap = np.zeros((mx, my), np.int32)
    base = 1
    for b in plan.buckets:
        Rb, Lx = b.idx.shape
        Ly = b.yidx.shape[1]
        rows = np.broadcast_to(b.idx[:, :, None], (Rb, Lx, Ly))
        cols = np.broadcast_to(b.yidx[:, None, :], (Rb, Lx, Ly))
        valid = b.mask[:, :, None] & b.ymask[:, None, :]
        pos = np.arange(base, base + Rb * Lx * Ly,
                        dtype=np.int64).reshape(Rb, Lx, Ly)
        srcmap[rows[valid], cols[valid]] = pos[valid]
        base += Rb * Lx * Ly
    object.__setattr__(plan, "_pair_srcmap_rect", ((mx, my), srcmap))
    return srcmap


def _scatter_blocks_x2y(out: jax.Array, blocks: jax.Array, xidx: jax.Array,
                        xmask: jax.Array, yidx: jax.Array,
                        ymask: jax.Array) -> jax.Array:
    """max-scatter (R, Lx, Ly) cross blocks into the running (mx, my)
    matrix (initialized to -inf); duplicates agree, so max is
    deterministic.  The streaming patch path relies on the max-combine
    (clean cells keep their value after -inf invalidation)."""
    Ly = yidx.shape[1]
    Lx = xidx.shape[1]
    rows = jnp.repeat(xidx[:, :, None], Ly, axis=2)    # (R, Lx, Ly)
    cols = jnp.repeat(yidx[:, None, :], Lx, axis=1)
    valid = xmask[:, :, None] & ymask[:, None, :]
    flat_vals = jnp.where(valid, blocks, -jnp.inf).reshape(-1)
    return out.at[rows.reshape(-1), cols.reshape(-1)].max(flat_vals)


def _finish_x2y_matrix(out: jax.Array) -> jax.Array:
    """Uncovered / invalidated cells -> 0 (no diagonal to zero: an (x, y)
    pair is never a self-pair)."""
    return jnp.where(jnp.isneginf(out), 0.0, out)


def assemble_x2y_matrix_bucketed(per_bucket, shape: tuple[int, int]):
    """Scatter per-bucket (Rb, Lx, Ly[, c]) cross blocks into the global
    (mx, my[, c]) output.

    ``per_bucket`` is ``run_reducers_x2y_bucketed(..., combine='buckets')``
    output.  Invalid slots drop into a scratch row (duplicate covered
    cells agree exactly, so plain ``set`` is deterministic), which also
    handles payload-carrying blocks — the skew-join's (Lx, Ly, dx+dy)
    concat outputs assemble through the same path as similarity
    matrices."""
    mx, my = shape
    if not per_bucket:
        return jnp.zeros((mx, my), dtype=jnp.float32)
    out = None
    for b, blocks in per_bucket:
        trailing = blocks.shape[3:]
        if out is None:
            out = jnp.zeros((mx + 1, max(my, 1)) + trailing, blocks.dtype)
        xidx = jnp.asarray(b.idx)
        yidx = jnp.asarray(b.yidx)
        valid = jnp.asarray(b.mask)[:, :, None] \
            & jnp.asarray(b.ymask)[:, None, :]
        rows = jnp.where(valid, xidx[:, :, None], mx)   # invalid -> scratch
        cols = jnp.where(valid, yidx[:, None, :], 0)
        out = out.at[rows.reshape(-1), cols.reshape(-1)].set(
            blocks.reshape((-1,) + trailing))
    return out[:mx, :my]


def _pair_source_map(plan: ReducerPlan, m: int) -> np.ndarray:
    """Inverse-shuffle map for fused assembly: (m, m) int32 positions into
    the concatenation ``[0.0, blocks_0.ravel(), blocks_1.ravel(), ...]`` of
    per-bucket Gram stacks (bucket order = ``plan.buckets``).

    A pair covered by several reducers keeps one (deterministic) source —
    duplicate block values agree exactly, so assembly becomes a gather
    instead of the bucketed path's max-combine scatter.  Uncovered cells
    and the diagonal point at slot 0 (-> 0.0).  Cached on the plan: like
    the index matrix itself, it is a static artifact reused across waves.
    """
    cached = plan.__dict__.get("_pair_srcmap")
    if cached is not None and cached[0] == m:
        return cached[1]
    srcmap = np.zeros((m, m), np.int32)
    base = 1
    for b in plan.buckets:
        Rb, Lb = b.idx.shape
        rows = np.broadcast_to(b.idx[:, :, None], (Rb, Lb, Lb))
        cols = np.broadcast_to(b.idx[:, None, :], (Rb, Lb, Lb))
        valid = b.mask[:, :, None] & b.mask[:, None, :]
        pos = np.arange(base, base + Rb * Lb * Lb,
                        dtype=np.int64).reshape(Rb, Lb, Lb)
        srcmap[rows[valid], cols[valid]] = pos[valid]
        base += Rb * Lb * Lb
    np.fill_diagonal(srcmap, 0)
    object.__setattr__(plan, "_pair_srcmap", (m, srcmap))
    return srcmap


def _assemble_from_srcmap(per_bucket, srcmap):
    """Traced fused-assembly step: gather the (m, m) matrix from the
    concatenated bucket blocks through the inverse-shuffle map."""
    vals = [jnp.zeros((1,), jnp.float32)]
    vals += [g.reshape(-1) for _, g in per_bucket]
    return jnp.take(jnp.concatenate(vals), srcmap, axis=0)


def _run_and_assemble(x, plan, fn, m, mesh, executor,
                      use_kernel: bool = False, interpret: bool = False):
    """Single dispatch point: ``executor`` is a registry name ("dense",
    "bucketed", "fused", "sharded", "streaming") or an
    :class:`Executor` instance (the serving tier passes its own so
    telemetry stays instance-scoped)."""
    return get_executor(executor).run_pairs(
        x, plan, fn, m, mesh=mesh, use_kernel=use_kernel,
        interpret=interpret)


def pairwise_similarity(
    x: jax.Array,                       # (m, d)
    *,
    q: float,
    weights=None,                       # per-input sizes; default: uniform
    schema: Optional[MappingSchema] = None,
    metric: str = "dot",
    mesh=None,
    use_kernel: bool = False,
    pad_slots_to: int = 1,
    executor: str = "bucketed",
    interpret: bool = False,
):
    """All-pairs similarity executed through a mapping schema.

    ``executor='bucketed'`` (default) runs the skew-aware capacity-bucket
    executor — each reducer pads only to its bucket width, and per-bucket
    blocks are scattered straight into the (m, m) matrix so the padding
    saving survives end-to-end.  ``executor='dense'`` is the one-program
    global-max-padded path (differential-test oracle).

    ``executor='fused'`` streams the shuffle straight into the Gram
    computation (DESIGN.md "fused shuffle execution"): all capacity buckets
    plus the pair-matrix assembly run in one program, and the gathered
    block is never materialized in HBM.  On TPU (or with
    ``use_kernel=True``) the fused gather+Gram Pallas kernel does the work;
    set ``interpret=True`` to run that kernel on CPU.  Non-Gram reducers
    and bucketless plans silently fall back to the bucketed executor.

    ``executor='sharded'`` LPT-balances the reducers across the local
    device mesh and runs the fused pipeline per shard under ``shard_map``
    (DESIGN.md "sharded execution") with one cross-shard assembly gather.

    ``executor`` may also be an :class:`repro.mapreduce.executors.Executor`
    instance (instance-scoped telemetry); dispatch goes through the
    executor registry either way.  Returns (sims (m, m) with zero
    diagonal, plan, schema)."""
    m = x.shape[0]
    with _obs_span("plan", workload="pairs", m=m):
        if schema is None:
            w = (np.full(m, 1.0) if weights is None
                 else np.asarray(weights, float))
            schema = plan_a2a(w, q)
        plan = _plan_for(
            schema,
            pad_reducers_to=(mesh.devices.size if mesh is not None else 1),
            pad_slots_to=pad_slots_to,
        )
    fn = _block_fn(metric, use_kernel)
    with _obs_span("execute", workload="pairs",
                   reducers=plan.num_reducers):
        sims = _run_and_assemble(x, plan, fn, m, mesh, executor,
                                 use_kernel=use_kernel, interpret=interpret)
    return sims, plan, schema


def _sparse_plan_for(schema) -> SparsePlan:
    """Memoized CSR plan for a schema (same caching contract as
    ``_plan_for``: one sparse plan per schema object, shared across block
    requests so executor-side srcmaps and the sub-plan LRU persist)."""
    cached = schema.__dict__.get("_sparse_plan")
    if cached is None:
        cached = build_sparse_plan(schema)
        schema.__dict__["_sparse_plan"] = cached
    return cached


def pairwise_similarity_block(
    x: jax.Array,                       # (m, d)
    i0: int, i1: int, j0: int, j1: int,
    *,
    q: Optional[float] = None,
    weights=None,                       # per-input sizes; default: uniform
    schema: Optional[MappingSchema] = None,
    metric: str = "dot",
    mesh=None,
    pad_slots_to: int = 1,
    executor: str = "bucketed",
    interpret: bool = False,
):
    """One ``[i0:i1) x [j0:j1)`` sub-block of the all-pairs similarity
    matrix, without materializing (m, m) anywhere.

    The schema is planned hierarchically (``plan_a2a_hierarchical`` — the
    flat planner at small m, two-level super-input packing at large m) and
    lowered once to a CSR :class:`~repro.mapreduce.engine.SparsePlan`
    cached on the schema; each block request then routes through the
    executor's ``run_block`` — the registry default selects only the
    reducers covering the block and serves them via ``run_x2y``, so
    per-block work scales with the block, not with m.  Global-diagonal
    cells inside the block are zeroed, matching ``pairwise_similarity``.

    Returns (block (i1-i0, j1-j0), sparse plan, schema)."""
    m = x.shape[0]
    if schema is None:
        if q is None:
            raise ValueError("pass q or a pre-planned schema")
        w = np.full(m, 1.0) if weights is None else np.asarray(weights, float)
        schema = plan_a2a_hierarchical(w, q)
    sparse = _sparse_plan_for(schema)
    fn = _block_fn_x2y(metric)
    block = get_executor(executor).run_block(
        x, sparse, fn, int(i0), int(i1), int(j0), int(j1), mesh=mesh,
        interpret=interpret, pad_slots_to=pad_slots_to)
    return block, sparse, schema


def some_pairs_similarity(
    x: jax.Array,                       # (m, d)
    pairs: Sequence[tuple[int, int]],   # required pairs (i, j)
    *,
    q: float,
    weights=None,                       # per-input sizes; default: uniform
    schema: Optional[MappingSchema] = None,
    metric: str = "dot",
    mesh=None,
    use_kernel: bool = False,
    pad_slots_to: int = 1,
    executor: str = "bucketed",
    interpret: bool = False,
):
    """Similarity for an explicit pair set through a some-pairs schema.

    Unlike :func:`pairwise_similarity`, only inputs incident to a required
    pair are shipped to reducers (the planner's sparse strategies leave the
    rest unplaced), and the returned matrix is masked to the required pairs
    (symmetric).  ``executor='fused'`` serves the some-pairs (X2Y) workload
    on the same fused gather+Gram path as A2A.  Returns
    (sims (m, m), plan, schema).
    """
    m = x.shape[0]
    with _obs_span("plan", workload="some_pairs", m=m):
        if schema is None:
            w = (np.full(m, 1.0) if weights is None
                 else np.asarray(weights, float))
            schema = plan_some_pairs(w, q, pairs)
        plan = _plan_for(
            schema,
            pad_reducers_to=(mesh.devices.size if mesh is not None else 1),
            pad_slots_to=pad_slots_to,
        )
    fn = _block_fn(metric, use_kernel)
    with _obs_span("execute", workload="some_pairs",
                   reducers=plan.num_reducers):
        sims = _run_and_assemble(x, plan, fn, m, mesh, executor,
                                 use_kernel=use_kernel, interpret=interpret)
    want = np.zeros((m, m), dtype=bool)
    p = np.asarray(list(pairs), dtype=np.int64).reshape(-1, 2)
    if p.size:
        want[p[:, 0], p[:, 1]] = True
        want[p[:, 1], p[:, 0]] = True
    sims = jnp.where(jnp.asarray(want), sims, 0.0)
    return sims, plan, schema


def x2y_similarity(
    x: jax.Array,                       # (mx, d) X-side feature rows
    y: jax.Array,                       # (my, d) Y-side feature rows
    *,
    q: float,
    wx=None,                            # X-side input sizes; default uniform
    wy=None,                            # Y-side input sizes; default uniform
    schema: Optional[MappingSchema] = None,
    metric: str = "dot",
    mesh=None,
    use_kernel: bool = False,
    pad_slots_to: int = 1,
    executor: str = "bucketed",
    interpret: bool = False,
):
    """Cross similarity of every X row against every Y row through an X2Y
    mapping schema (paper Section 10).

    The planner packs X into bins of size b and Y into bins of q - b; each
    reducer meets one X bin with one Y bin, so every cross pair is covered.
    Execution is rectangular end-to-end: reducers emit (Lx, Ly) cross
    blocks (never a padded square), ``executor='fused'`` runs the
    rectangular gather+Gram kernel with independent row/column gather maps,
    ``executor='sharded'`` LPT-balances the rectangular sub-plans over the
    mesh, and ``executor='streaming'`` serves the (mx, my) matrix as
    patchable state.  Returns (sims (mx, my), plan, schema)."""
    mx, my = x.shape[0], y.shape[0]
    with _obs_span("plan", workload="x2y", mx=mx, my=my):
        if schema is None:
            wx_ = np.full(mx, 1.0) if wx is None else np.asarray(wx, float)
            wy_ = np.full(my, 1.0) if wy is None else np.asarray(wy, float)
            schema = plan_x2y(wx_, wy_, q)
        plan = _x2y_plan_for(
            schema, mx,
            pad_reducers_to=(mesh.devices.size if mesh is not None else 1),
            pad_slots_to=pad_slots_to,
        )
    fn = _block_fn_x2y(metric)
    with _obs_span("execute", workload="x2y", reducers=plan.num_reducers):
        sims = get_executor(executor).run_x2y(
            (x, y), plan, fn, (mx, my), mesh=mesh, use_kernel=use_kernel,
            interpret=interpret)
    return sims, plan, schema


def _scatter_blocks(out: jax.Array, blocks: jax.Array, idx: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """max-scatter (R, L, L) reducer blocks into the running (m, m) matrix
    (initialized to -inf).  A pair may meet at several reducers; values
    agree, so `max` combine is deterministic."""
    L = idx.shape[1]
    rows = jnp.repeat(idx[:, :, None], L, axis=2)     # (R, L, L) row ids
    cols = jnp.repeat(idx[:, None, :], L, axis=1)     # (R, L, L) col ids
    valid = (mask[:, :, None] & mask[:, None, :])
    flat_vals = jnp.where(valid, blocks, -jnp.inf).reshape(-1)
    return out.at[rows.reshape(-1), cols.reshape(-1)].max(flat_vals)


def _finish_pair_matrix(out: jax.Array, m: int) -> jax.Array:
    out = jnp.where(jnp.isneginf(out), 0.0, out)
    return out * (1.0 - jnp.eye(m, dtype=out.dtype))


def assemble_pair_matrix(blocks: jax.Array, plan: ReducerPlan, m: int):
    """Scatter per-reducer (L, L) blocks into the global (m, m) matrix.

    Diagonal is zeroed (no self-pairs in A2A)."""
    out = jnp.full((m, m), -jnp.inf, dtype=blocks.dtype)
    out = _scatter_blocks(out, blocks, jnp.asarray(plan.idx),
                          jnp.asarray(plan.mask))
    return _finish_pair_matrix(out, m)


def assemble_pair_matrix_bucketed(per_bucket, m: int):
    """Scatter per-bucket (Rb, Lb, Lb) blocks into the global (m, m) matrix.

    ``per_bucket`` is ``run_reducers_bucketed(..., combine='buckets')``
    output.  Each bucket scatters at its own width — no block is ever
    padded to the dense L, so the bucketed executor's memory saving holds
    through assembly.  Padding rows (all-masked) contribute nothing."""
    if not per_bucket:
        return jnp.zeros((m, m), dtype=jnp.float32)
    dtype = per_bucket[0][1].dtype
    out = jnp.full((m, m), -jnp.inf, dtype=dtype)
    for b, blocks in per_bucket:
        out = _scatter_blocks(out, blocks, jnp.asarray(b.idx),
                              jnp.asarray(b.mask))
    return _finish_pair_matrix(out, m)
