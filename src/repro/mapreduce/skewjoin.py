"""X2Y application: skew join of X(A, B) and Y(B, C) on a heavy hitter.

All X- and Y-tuples sharing the heavy-hitter B-value must pairwise meet
(Example 3 of the paper).  The X2Y planner packs tuples into bins; each
reducer joins one X-bin against one Y-bin.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan_x2y
from repro.core.schema import MappingSchema

__all__ = ["skew_join"]


def skew_join(
    x_vals: jax.Array,            # (mx, dx) — A-side payloads for one HH key
    y_vals: jax.Array,            # (my, dy) — C-side payloads
    *,
    q: float,
    wx=None,
    wy=None,
    schema: Optional[MappingSchema] = None,
    mesh=None,
    executor: str = "dense",
):
    """Join every X row with every Y row through an X2Y mapping schema.

    Returns (pairs (mx, my, dx+dy), schema).  The dense output is assembled
    by scattering per-reducer cross products — each (x, y) pair is produced
    by >= 1 reducer (coverage guarantee), duplicates agree.

    ``executor`` is validated against the executor registry for API parity
    with the similarity apps, but the join's cross-product-concat reducer
    is not a Gram block, so every executor runs the standard path here —
    only *similarity*-shaped X2Y workloads (the some-pairs route in
    ``allpairs.some_pairs_similarity``) reach the fused/sharded engines,
    whose dispatch counters therefore track real engine dispatches only.
    """
    from .executors import get_executor
    get_executor(executor)           # registry validation (ValueError)
    mx, my = x_vals.shape[0], y_vals.shape[0]
    if schema is None:
        wx_ = np.full(mx, 1.0) if wx is None else np.asarray(wx, float)
        wy_ = np.full(my, 1.0) if wy is None else np.asarray(wy, float)
        schema = plan_x2y(wx_, wy_, q)

    # split bins back into X-part / Y-part (ids < mx are X)
    x_bins = [b for b in schema.bins if b and b[0] < mx]
    y_bins = [[i - mx for i in b] for b in schema.bins if b and b[0] >= mx]
    Lx = max(len(b) for b in x_bins)
    Ly = max(len(b) for b in y_bins)
    xb = np.zeros((len(x_bins), Lx), np.int32)
    xm = np.zeros((len(x_bins), Lx), bool)
    for i, b in enumerate(x_bins):
        xb[i, : len(b)] = b
        xm[i, : len(b)] = True
    yb = np.zeros((len(y_bins), Ly), np.int32)
    ym = np.zeros((len(y_bins), Ly), bool)
    for i, b in enumerate(y_bins):
        yb[i, : len(b)] = b
        ym[i, : len(b)] = True

    # reducer -> (x_bin, y_bin): planner emits [x_bin_id, y_bin_id_global]
    nx = len(x_bins)
    red = np.asarray(
        [[r[0], r[1] - nx] for r in schema.reducers], np.int32)  # (R, 2)

    def _join(xv, yv, xb, xm, yb, ym, red):
        # gather bins per reducer — this is the shuffle
        bx = jnp.take(xb, red[:, 0], axis=0)         # (R, Lx)
        mxk = jnp.take(xm, red[:, 0], axis=0)
        by = jnp.take(yb, red[:, 1], axis=0)         # (R, Ly)
        myk = jnp.take(ym, red[:, 1], axis=0)
        gx = jnp.take(xv, bx, axis=0)                # (R, Lx, dx)
        gy = jnp.take(yv, by, axis=0)                # (R, Ly, dy)
        # per-reducer cross product
        R = bx.shape[0]
        gxx = jnp.broadcast_to(gx[:, :, None, :], (R, Lx, Ly, gx.shape[-1]))
        gyy = jnp.broadcast_to(gy[:, None, :, :], (R, Lx, Ly, gy.shape[-1]))
        joined = jnp.concatenate([gxx, gyy], axis=-1)
        valid = mxk[:, :, None] & myk[:, None, :]
        return joined, valid, bx, by

    joined, valid, bx, by = jax.jit(_join)(
        jnp.asarray(x_vals), jnp.asarray(y_vals), jnp.asarray(xb),
        jnp.asarray(xm), jnp.asarray(yb), jnp.asarray(ym), jnp.asarray(red))

    # assemble into (mx, my, dx+dy)
    rows = jnp.broadcast_to(bx[:, :, None], valid.shape)
    cols = jnp.broadcast_to(by[:, None, :], valid.shape)
    d = joined.shape[-1]
    out = jnp.zeros((mx, my, d), joined.dtype)
    flat_r = jnp.where(valid, rows, mx).reshape(-1)   # invalid -> OOB drop
    flat_c = jnp.where(valid, cols, 0).reshape(-1)
    out = out.at[flat_r, flat_c].set(
        joined.reshape(-1, d), mode="drop")
    return out, schema
