"""X2Y application: skew join of X(A, B) and Y(B, C) on a heavy hitter.

All X- and Y-tuples sharing the heavy-hitter B-value must pairwise meet
(Example 3 of the paper).  The X2Y planner packs tuples into bins; each
reducer joins one X-bin against one Y-bin, and execution dispatches
through the executor registry like every other application.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan_x2y
from repro.core.schema import MappingSchema

__all__ = ["skew_join", "join", "join_block"]


def join_block(xblock: jax.Array, xmask: jax.Array,
               yblock: jax.Array, ymask: jax.Array) -> jax.Array:
    """Per-reducer cross-product-concat: (Lx, dx), (Lx,), (Ly, dy), (Ly,)
    -> (Lx, Ly, dx + dy) joined payloads; invalid pairs -> 0.

    This is the skew join's reducer for the rectangular executor protocol
    (``run_x2y``).  It is *not* a Gram block (no ``fused_metric`` tag), so
    the fused/sharded/streaming executors legitimately fall back to the
    rect-bucketed engine path — counted in their ``stats()`` — while
    dispatch still flows through each executor's ``run_x2y``.
    """
    Lx, Ly = xblock.shape[0], yblock.shape[0]
    gx = jnp.broadcast_to(xblock[:, None, :], (Lx, Ly, xblock.shape[-1]))
    gy = jnp.broadcast_to(yblock[None, :, :], (Lx, Ly, yblock.shape[-1]))
    joined = jnp.concatenate([gx, gy], axis=-1)
    valid = xmask[:, None] & ymask[None, :]
    return jnp.where(valid[:, :, None], joined, 0)


def skew_join(
    x_vals: jax.Array,            # (mx, dx) — A-side payloads for one HH key
    y_vals: jax.Array,            # (my, dy) — C-side payloads
    *,
    q: float,
    wx=None,
    wy=None,
    schema: Optional[MappingSchema] = None,
    mesh=None,
    executor: str = "dense",
):
    """Join every X row with every Y row through an X2Y mapping schema.

    Returns (pairs (mx, my, dx+dy), schema).  The (mx, my, dx+dy) output is
    assembled by scattering per-reducer cross blocks — each (x, y) pair is
    produced by >= 1 reducer (coverage guarantee), duplicates agree.

    ``executor`` selects a registry executor ("dense", "bucketed", "fused",
    "sharded", "streaming", or an :class:`~.executors.Executor` instance)
    and execution really dispatches through its ``run_x2y``: the schema is
    lowered to a rectangular :class:`~.engine.ReducerPlan` (independent
    X-side and Y-side gather maps per reducer) and the executor runs and
    assembles it.  The join's cross-product-concat reducer carries no
    ``fused_metric`` tag, so the Gram-only engines (fused/sharded/
    streaming) take their counted rect-bucketed fallback — outputs are
    identical across all executors.
    """
    from .allpairs import _x2y_plan_for
    from .executors import get_executor
    ex = get_executor(executor)
    mx, my = x_vals.shape[0], y_vals.shape[0]
    if schema is None:
        wx_ = np.full(mx, 1.0) if wx is None else np.asarray(wx, float)
        wy_ = np.full(my, 1.0) if wy is None else np.asarray(wy, float)
        schema = plan_x2y(wx_, wy_, q)
    plan = _x2y_plan_for(
        schema, mx,
        pad_reducers_to=(mesh.devices.size if mesh is not None else 1),
        pad_slots_to=1,
    )
    out = ex.run_x2y((jnp.asarray(x_vals), jnp.asarray(y_vals)), plan,
                     join_block, (mx, my), mesh=mesh)
    return out, schema


# registry-era name (the similarity apps say "executor", the join docs say
# "join"); both names are the same callable
join = skew_join
