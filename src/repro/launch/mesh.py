"""Production mesh definitions.

Functions, not module-level constants, so importing never touches jax device
state.  Single pod: 16x16 = 256 chips ('data', 'model').  Multi-pod: 2 pods
of 256 = 512 chips ('pod', 'data', 'model') — the 'pod' axis carries only
data parallelism (gradient all-reduce over DCI), 'model' stays intra-pod.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh as _mk

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(model: int = 1):
    """CPU/test mesh: all local devices on ('data','model')."""
    n = len(jax.devices())
    assert n % model == 0
    return _mk((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
