import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Optimized-config sweep: every cell with the beyond-paper optimizations
(grouped MoE dispatch + fused-norm VJP are code defaults; chunked SSD and
bf16 attention probabilities are flags).  Results tagged __opt."""

from repro.configs.base import SHAPES, list_archs
from repro.launch.dryrun import run_cell

OVERRIDES = {"ssd_impl": "chunked", "attn_probs_dtype": "bfloat16"}


def main():
    for arch in list_archs():
        for shape in SHAPES:
            for mp in (False, True):
                rec = run_cell(arch, shape, mp, skip_existing=True,
                               opt_overrides=OVERRIDES, tag="__opt")
                status = rec.get("status")
                line = (f"[{status:7s}] {arch:28s} {shape:12s} "
                        f"{'multipod' if mp else 'pod':8s} "
                        f"t={rec.get('compile_s', 0):6.1f}s")
                if status == "ok":
                    line += (f" frac={rec['roofline_fraction']:.3f}"
                             f" frac_res="
                             f"{rec['roofline_fraction_kernel_resident']:.3f}")
                elif status == "error":
                    line += " " + rec["error"][:100]
                print(line, flush=True)


if __name__ == "__main__":
    main()
