"""Per-(arch, mesh, flags) sharding-rule derivation.

The logical rules table is adjusted for divisibility: a logical dim only
shards over 'model' when the arch's dimension divides the axis (e.g.
Gemma-3's 8 query heads cannot shard over TP=16 — its TP parallelism comes
from d_ff/vocab/head_dim instead; Granite's single KV head is replicated).
"""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.configs_runtime import RuntimeFlags
from repro.parallel.sharding import ShardingRules

from .mesh import mesh_axis_sizes

__all__ = ["rules_for", "cache_logical_axes"]


def rules_for(cfg: ArchConfig, mesh, flags: RuntimeFlags) -> ShardingRules:
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    extra: dict = {}
    if cfg.num_heads % tp:
        extra["heads"] = (None,)
        extra["act_heads"] = (None,)
    if cfg.num_kv_heads % tp:
        extra["kv_heads"] = (None,)
    if cfg.num_experts and cfg.num_experts % tp:
        extra["experts"] = (None,)
    if cfg.d_ff and cfg.d_ff % tp:
        extra["mlp"] = (None,)
        extra["act_mlp"] = (None,)
    if cfg.ssm_state:
        H = cfg.mamba_meta()["H"]
        if H % tp:
            extra["ssm_heads"] = (None,)
    if flags.seq_shard_decode and flags.seq_shard_axes == "all":
        # long-context decode: KV sequence sharded over every mesh axis
        # (batch=1 leaves 'data' idle otherwise)
        extra["seq_shard"] = (("pod", "data", "model"),)
        extra["batch"] = (None,)
    elif flags.seq_shard_decode:
        # decode with kv_heads % tp != 0: the cache would replicate over
        # 'model' — shard its sequence dim there instead (batch stays on
        # the data axes)
        extra["seq_shard"] = ("model",)
    else:
        extra["seq_shard"] = (None,)
    return ShardingRules.create(mesh, fsdp=flags.fsdp, extra=extra)


# keyed by cache-leaf name: logical axes of the trailing dims
_CACHE_AXES = {
    "k": ("batch", "seq_shard", "kv_heads", None),
    "v": ("batch", "seq_shard", "kv_heads", None),
    "k_scale": ("batch", "seq_shard", "kv_heads"),
    "v_scale": ("batch", "seq_shard", "kv_heads"),
    "h": ("batch", "ssm_heads", None, None),
    "conv_x": ("batch", None, "act_mlp"),
    "conv_b": ("batch", None, None),
    "conv_c": ("batch", None, None),
    "len": (),
}


def cache_logical_axes(cache_shapes):
    """Mirror an (abstract) cache tree with logical-axis tuples; leading
    stacked-layer dims map to None."""
    import jax

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        tail = _CACHE_AXES.get(name, None)
        nd = len(leaf.shape)
        if tail is None:
            return (None,) * nd
        pad = nd - len(tail)
        return (None,) * pad + tuple(tail)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
