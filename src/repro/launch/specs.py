"""ShapeDtypeStruct stand-ins for every (arch x shape) cell + step builders.

``input_specs(cfg, shape_name, flags)`` returns the exact abstract inputs a
train/serve step takes — weak-type-correct, shardable, zero allocation —
which is what the dry-run lowers against.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig
from repro.models import RuntimeFlags, build_model
from repro.models.configs_runtime import RuntimeFlags
from repro.parallel.sharding import ShardingRules

__all__ = ["input_specs", "shape_applicable", "default_flags"]

SDS = jax.ShapeDtypeStruct


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason string if skipped."""
    seq, batch, kind = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k-token KV decode is "
                       "excluded per assignment (no sub-quadratic path)")
    return True, ""


def default_flags(cfg: ArchConfig, shape_name: str,
                  mesh=None) -> RuntimeFlags:
    """Baseline runtime flags per cell (documented in DESIGN.md)."""
    seq, batch, kind = SHAPES[shape_name]
    big = cfg.param_count() > 100e9
    tp = 16 if mesh is None else dict(
        zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    long_ctx = kind == "decode" and seq >= 2 ** 19
    # decode caches with kv_heads % tp != 0 would replicate over 'model';
    # shard their sequence dim there instead (§Perf iteration 7)
    kv_rep = kind == "decode" and cfg.num_kv_heads % tp != 0 \
        and cfg.family != "ssm"
    return RuntimeFlags(
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat="full" if kind == "train" else "none",
        fsdp=big,
        seq_shard_decode=long_ctx or kv_rep,
        seq_shard_axes="all" if long_ctx else "model",
        capacity_factor=1.25 if kind == "train" else 1.5,
    )


def input_specs(cfg: ArchConfig, shape_name: str,
                flags: Optional[RuntimeFlags] = None) -> dict:
    """Abstract batch for the step of this shape.

    train/prefill: token batch (prefill lowers the same teacher-forced
    forward used for scoring; its FLOPs profile equals inference prefill).
    decode: one-token step against a seq_len KV cache.
    """
    seq, batch, kind = SHAPES[shape_name]
    if flags is None:
        flags = default_flags(cfg, shape_name)
    it = jnp.int32
    if kind in ("train", "prefill"):
        s_text = seq - (cfg.num_frontend_tokens
                        if cfg.frontend == "vision" else 0)
        specs = {
            "tokens": SDS((batch, s_text), it),
            "targets": SDS((batch, s_text), it),
            "mask": SDS((batch, s_text), jnp.float32),
        }
        if cfg.frontend == "vision":
            specs["image_embeds"] = SDS(
                (batch, cfg.num_frontend_tokens, cfg.d_model), flags.cdtype)
        if cfg.frontend == "audio":
            specs["audio_embeds"] = SDS(
                (batch, cfg.encoder_seq, cfg.d_model), flags.cdtype)
        return specs
    # decode step: tokens (B,1) + pos; cache is built separately
    specs = {
        "tokens": SDS((batch, 1), it),
        "pos": SDS((), it),
    }
    if cfg.frontend == "audio":
        specs["enc_out"] = SDS(
            (batch, cfg.encoder_seq, cfg.d_model), flags.cdtype)
    return specs
