"""HLO-text analyzer: flops / HBM-traffic / collective bytes with correct
while-loop (lax.scan) multipliers.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scanned-layers model under-reports by ~num_layers x.  This walks the
optimized HLO:

  * builds a per-computation symbol table (%name -> shape) from defs;
  * dot flops: 2 * prod(result) * contracted_size (parsed from
    dot_dimension_numbers), scaled by the product of enclosing while-loop
    trip counts (trip count = max int constant in the loop condition —
    XLA canonicalizes counted loops to `iter < C`);
  * collective bytes: ring-model per kind (AG/AR/RS/A2A/permute), also
    trip-count scaled;
  * HBM traffic: every top-level op reads operands + writes result once
    (fusions count as one op — a good model of TPU fusion behavior);
    shape-only ops (bitcast/tuple/gte/parameter/constant) are free.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["analyze_hlo_text", "buffer_shapes", "has_buffer_shape",
           "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.+?\)?)\s+"
                     r"([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALLED_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_TRIPCOUNT_RE = re.compile(r'known_trip_count.{0,10}?[:=]\s*.?\{?"?n"?[:=]"?(\d+)')

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "reshape",  # layout-preserving reshapes are free on TPU
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",")] if dim_str else []


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in _dims(m.group(2)):
        n *= d
    return n


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


class _Computation:
    def __init__(self, name):
        self.name = name
        self.ops: list[_Op] = []
        self.symbols: dict[str, str] = {}   # name -> type str


def _parse_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        hdr = _COMP_HDR_RE.match(raw) if raw and raw[0] not in " }" else None
        if hdr and "{" in raw:
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m:
            name, type_str, opcode = m.groups()
            cur.ops.append(_Op(name, type_str, opcode, line))
            cur.symbols[name] = type_str
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _group_size(line: str, num_partitions: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return num_partitions


def _dot_flops(op: _Op, comp: _Computation) -> float:
    # result elements x contracted size x 2
    res = _shape_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    # lhs operand = first arg inside dot(...); depending on the XLA version
    # the text format is `dot(%name, ...)` (type looked up from the def) or
    # `dot(f32[32,16]{1,0} %name, ...)` (type inlined on the operand)
    # layout braces may carry tiling annotations, e.g. {1,0:T(8,128)}
    argm = re.search(
        re.escape(op.opcode) +
        r"\(\s*(?:([a-z][a-z0-9]*\[[0-9,]*\])(?:\{[^}]*\})?\s+)?"
        r"%?([\w.\-]+)", op.line)
    csize = 1
    if m and argm:
        lhs_type = argm.group(1)
        if lhs_type is None:
            lhs_type = comp.symbols.get(argm.group(2))
        if lhs_type:
            sm = _SHAPE_RE.search(lhs_type)
            if sm:
                dims = _dims(sm.group(2))
                for ci in _dims(m.group(1)):
                    if ci < len(dims):
                        csize *= dims[ci]
    return 2.0 * res * csize


def _conv_flops(op: _Op, comp: _Computation) -> float:
    # rough: 2 * out_elems * kernel_elems_per_output (parse window size)
    res = _shape_elems(op.type_str)
    m = re.search(r"window=\{size=([0-9x]+)", op.line)
    k = 1
    if m:
        for d in m.group(1).split("x"):
            k *= int(d)
    return 2.0 * res * k


def _collective_moved(op: _Op, line: str, num_partitions: int,
                      bf16_native: bool = True) -> float:
    n = max(_group_size(line, num_partitions), 1)
    frac = (n - 1) / n if n > 1 else 0.0
    size = _shape_bytes(op.type_str)
    if bf16_native and "promoted" in line and "f32[" in op.type_str:
        # XLA:CPU promotes bf16 all-reduces to f32; TPU keeps them bf16
        size //= 2
    kind = op.opcode.replace("-start", "")
    if kind == "all-gather":
        return size * frac
    if kind == "all-reduce":
        return 2.0 * size * frac
    if kind == "reduce-scatter":
        return size  # result is the shard; n*size enters the ring
    if kind == "all-to-all":
        return size * frac
    if kind == "collective-permute":
        return size
    return 0.0


def _trip_count(cond: _Computation) -> int:
    best = 1
    for op in cond.ops:
        for mm in _CONST_INT_RE.finditer(op.line):
            best = max(best, int(mm.group(1)))
    return best


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)
    collective_ops: int = 0
    while_trip_counts: list = dataclasses.field(default_factory=list)


def _f32_act_bytes_adjust(type_str: str) -> int:
    """Bytes of type_str counting rank>=3 f32 tensors at bf16 width.

    With compute_dtype=bf16, every rank>=3 f32 activation in the optimized
    CPU HLO stems from XLA:CPU's bf16 dot/all-reduce promotion — on TPU the
    MXU and ICI consume bf16 natively.  Genuine f32 regions (loss scalars,
    optimizer leaves, norm statistics) are rank<=2 or tiny."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        dl = _dims(dims)
        for d in dl:
            n *= d
        b = n * _DTYPE_BYTES[dt]
        if dt == "f32" and len(dl) >= 3:
            b //= 2
        total += b
    return total


def _is_resident(type_str: str, min_dim: int = 1024) -> bool:
    """True for attention-score-like tensors: trailing two dims both large
    (q_seq x kv_seq).  With the Pallas flash kernel these tiles never leave
    VMEM; `attn_resident=True` accounting excludes their HBM traffic."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return False
    dims = _dims(m.group(2))
    return len(dims) >= 2 and dims[-1] >= min_dim and dims[-2] >= min_dim


def buffer_shapes(text: str) -> dict:
    """Dim-tuple -> count over every op result buffer in the HLO text.

    Fusion interiors are included: a materialized gather shows up as its
    producing op's result shape wherever XLA placed it.  Used by the fused
    executor's acceptance check — the dense ``(R, L, d)`` gather buffer
    must appear in the dense program and be absent from the fused one.
    """
    out: dict = {}
    seen: set = set()
    for comp in _parse_computations(text).values():
        if id(comp) in seen:          # "__entry__" aliases the entry comp
            continue
        seen.add(id(comp))
        for op in comp.ops:
            # tuple-typed results (multi-output fusions) carry several
            # shapes — count every component buffer
            for _, dim_str in _SHAPE_RE.findall(op.type_str):
                dims = tuple(_dims(dim_str))
                out[dims] = out.get(dims, 0) + 1
    return out


def has_buffer_shape(text: str, dims) -> bool:
    """True if any op in the HLO produces a buffer of exactly ``dims``."""
    return tuple(int(d) for d in dims) in buffer_shapes(text)


def analyze_hlo_text(text: str, num_partitions: int = 1,
                     attn_resident: bool = False,
                     bf16_native: bool = True) -> HloStats:
    """bf16_native: XLA:CPU legalizes bf16 dots by inserting f32 converts of
    their operands; the TPU MXU consumes bf16 directly, so convert-rooted
    f32 fusions are counted at bf16 width (documented approximation)."""
    comps = _parse_computations(text)
    stats = HloStats()
    entry = comps.get("__entry__")
    if entry is None:
        return stats

    fused: set[str] = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                m = _CALLED_RE.search(op.line)
                if m:
                    for name in m.group(1).replace("%", "").split(","):
                        fused.add(name.strip())

    visited_guard: set = set()

    def walk(comp: _Computation, mult: float, as_fusion_interior: bool):
        key = (comp.name, as_fusion_interior)
        for op in comp.ops:
            line = op.line
            oc = op.opcode
            # ---- flops (counted even inside fusions)
            if oc == "dot":
                stats.flops += mult * _dot_flops(op, comp)
            elif oc == "convolution":
                stats.flops += mult * _conv_flops(op, comp)
            elif oc in ("multiply", "add", "subtract", "divide", "exponential",
                        "tanh", "rsqrt", "power", "maximum", "minimum"):
                stats.flops += mult * _shape_elems(op.type_str)
            # ---- collectives
            if oc in _COLLECTIVES:
                moved = _collective_moved(op, line, num_partitions,
                                          bf16_native=bf16_native)
                kind = oc.replace("-start", "")
                stats.collective_bytes += mult * moved
                stats.collective_by_kind[kind] = \
                    stats.collective_by_kind.get(kind, 0.0) + mult * moved
                stats.collective_ops += 1
            # ---- HBM traffic: top-level ops only
            if not as_fusion_interior and oc not in _FREE_OPS:
                sizer = _f32_act_bytes_adjust if bf16_native else _shape_bytes
                res_bytes = sizer(op.type_str)
                if attn_resident and _is_resident(op.type_str):
                    res_bytes = 0
                # XLA names fusions after their root op; slice-rooted fusions
                # touch only the slice, update-rooted ones only the update.
                is_ds = (oc in ("dynamic-slice", "gather")
                         or (oc == "fusion"
                             and ("dynamic-slice" in op.name
                                  or "gather" in op.name)))
                is_dus = (oc in ("dynamic-update-slice", "scatter")
                          or (oc == "fusion"
                              and ("dynamic-update-slice" in op.name
                                   or "scatter" in op.name)))
                if is_dus:
                    # in-place update: traffic ~ 2 x update operand
                    # (operands = carried buffer [== result size] + update)
                    sizes = []
                    argm = re.search(oc + r"\(([^)]*)\)", line)
                    if argm:
                        for nm in argm.group(1).split(","):
                            t = comp.symbols.get(nm.strip().lstrip("%"))
                            if t:
                                sizes.append(sizer(t))
                    upd = (sum(sizes) - max(sizes)) if sizes else res_bytes
                    stats.hbm_bytes += mult * 2 * max(upd, 1)
                elif is_ds:
                    # reads only the slice, writes the result
                    stats.hbm_bytes += mult * 2 * res_bytes
                else:
                    opnds = 0
                    argm = re.search(oc + r"\(([^)]*)\)", line)
                    if argm:
                        for nm in argm.group(1).split(","):
                            nm = nm.strip().lstrip("%")
                            t = comp.symbols.get(nm)
                            if t and not (attn_resident and _is_resident(t)):
                                opnds += sizer(t)
                    stats.hbm_bytes += mult * (opnds + res_bytes)
            # ---- control flow recursion
            if oc == "while":
                m = _CALLED_RE.search(line)
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                tc = 1
                tm = _TRIPCOUNT_RE.search(line)
                if tm:
                    tc = int(tm.group(1))
                elif cm and cm.group(1) in comps:
                    tc = _trip_count(comps[cm.group(1)])
                stats.while_trip_counts.append(tc)
                if bm and bm.group(1) in comps:
                    walk(comps[bm.group(1)], mult * tc, False)
            elif oc == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", line)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult, True)
            elif oc in ("call", "custom-call", "conditional", "reduce",
                        "sort", "scatter", "select-and-scatter", "map"):
                for m in re.finditer(
                        r"(?:to_apply|calls)=%?([\w.\-]+)", line):
                    if m.group(1) in comps:
                        walk(comps[m.group(1)], mult, True)

    walk(entry, 1.0, False)
    return stats
