"""One-shot observability report: render the obs layer's current state.

Pulls the four obs surfaces (DESIGN.md 1j) into a single human-readable
report — or one JSON document with ``--json``:

* metrics registry snapshot (counters / gauges / histogram summaries),
* comm-ledger reconciliation per (executor, workload) plus any anomalies,
* structured event counts and the most recent events,
* the span ring, exportable as Chrome trace JSON (``--trace out.json``,
  loadable in Perfetto / chrome://tracing).

``--demo`` first runs a small :class:`repro.serve.PairwiseService`
workload (pairs + x2y on the fused executor) so the report has something
to show — the quick-start path documented in README.md.

Usage::

    PYTHONPATH=src python -m repro.launch.obs_report --demo
    PYTHONPATH=src python -m repro.launch.obs_report --json
    PYTHONPATH=src python -m repro.launch.obs_report --demo --trace t.json
"""

from __future__ import annotations

import argparse
import json

from repro.obs import EVENTS, LEDGER, REGISTRY, TRACER


def gather(events_tail: int = 10) -> dict:
    """The full obs state as one JSON-ready document."""
    return {
        "metrics": REGISTRY.snapshot(),
        "ledger": {
            "records": LEDGER.seq,
            "summary": LEDGER.summary(),
            "anomalies": [r.summary() for r in LEDGER.records()
                          if r.anomaly],
        },
        "events": {
            "counts": EVENTS.counts(),
            "tail": EVENTS.events(last=events_tail),
        },
        "trace": {"spans": len(TRACER.spans())},
    }


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def render(doc: dict) -> str:
    """Plain-text rendering of a :func:`gather` document."""
    lines = ["== obs report =="]

    lines.append("\n-- counters --")
    for k, v in doc["metrics"]["counters"].items():
        if v:
            lines.append(f"  {k} = {v}")
    lines.append("\n-- gauges --")
    for k, v in doc["metrics"]["gauges"].items():
        lines.append(f"  {k} = {v:g}")
    lines.append("\n-- histograms --")
    for k, h in doc["metrics"]["histograms"].items():
        if h["count"]:
            lines.append(
                f"  {k}: n={h['count']} mean={h['mean']:.4g} "
                f"p50={h['p50']:.4g} p90={h['p90']:.4g} "
                f"p99={h['p99']:.4g} max={h['max']:.4g}")

    lines.append("\n-- comm ledger --")
    led = doc["ledger"]
    lines.append(f"  records: {led['records']}")
    for key, agg in led["summary"].items():
        lines.append(
            f"  {key}: n={agg['records']} anomalies={agg['anomalies']} "
            f"gathered={_fmt_bytes(agg['gathered_bytes'])} "
            f"assembled={_fmt_bytes(agg['assembled_bytes'])} "
            f"ratio=[{agg['measured_over_predicted_min']:.3f}, "
            f"{agg['measured_over_predicted_max']:.3f}]")
    for rec in led["anomalies"]:
        lines.append(f"  ANOMALY {rec['executor']}/{rec['workload']}: "
                     f"measured/predicted="
                     f"{rec['measured_over_predicted']:.3f} "
                     f"(expected ~{rec['replication']:.3f})")

    lines.append("\n-- events --")
    for kind, n in sorted(doc["events"]["counts"].items()):
        lines.append(f"  {kind}: {n}")
    for ev in doc["events"]["tail"]:
        extras = {k: v for k, v in ev.items()
                  if k not in ("seq", "ts", "kind")}
        lines.append(f"  [{ev['seq']}] {ev['kind']} {extras}")

    lines.append(f"\n-- trace --\n  spans buffered: {doc['trace']['spans']}"
                 "  (export with --trace out.json)")
    return "\n".join(lines)


def run_demo() -> None:
    """Tiny fused-executor serving workload so the report is non-empty."""
    import numpy as np

    from repro.serve import PairwiseService

    rng = np.random.RandomState(0)
    x = rng.randn(48, 16).astype(np.float32)
    # skewed sizes, clipped so any two inputs still fit one reducer (q=6)
    w = np.minimum(rng.zipf(2.0, 48), 3).astype(np.float64)
    svc = PairwiseService(q=6, executor="fused", tenant="demo")
    svc.similarity(x, weights=w)
    svc.x2y(x, x[:16])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON document instead of text")
    ap.add_argument("--demo", action="store_true",
                    help="run a small serving workload first")
    ap.add_argument("--trace", metavar="PATH",
                    help="also export the span ring as Chrome trace JSON")
    ap.add_argument("--events-tail", type=int, default=10,
                    help="number of recent events to include")
    args = ap.parse_args(argv)

    if args.demo:
        run_demo()
    doc = gather(events_tail=args.events_tail)
    if args.trace:
        TRACER.export_chrome_trace(args.trace)
        doc["trace"]["exported_to"] = args.trace
    print(json.dumps(doc, indent=2, default=str) if args.json
          else render(doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
