import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY jax import (jax pins the device count at
first init): the single CPU pretends to be 512 devices so the production
meshes materialize.  Nothing is executed — every input is a
ShapeDtypeStruct; success proves the sharding config is coherent, and
memory_analysis/cost_analysis feed EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
  python -m repro.launch.dryrun --all --skip-existing
Results: benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.launch.roofline import analyze_compiled
from repro.launch.rules import cache_logical_axes, rules_for
from repro.launch.specs import default_flags, input_specs, shape_applicable
from repro.models import build_model
from repro.parallel.sharding import logical_to_spec
from repro.train import AdamWConfig, make_state_shardings, make_train_step
from repro.train.optimizer import adamw_init
from repro.train.train_step import batch_sharding

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results",
    "dryrun")


def _sharding_tree(mesh, rules, axes_tree):
    is_leaf = lambda a: isinstance(a, tuple)
    return jax.tree.map(
        lambda a: jax.sharding.NamedSharding(mesh, logical_to_spec(rules, a)),
        axes_tree, is_leaf=is_leaf)


def lower_cell(arch: str, shape: str, multi_pod: bool, flags=None,
               opt_overrides=None):
    """Lower + compile one cell; returns (compiled, report-ready context)."""
    cfg = get_config(arch)
    seq, batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    if flags is None:
        flags = default_flags(cfg, shape, mesh)
    if opt_overrides:
        flags = dataclasses.replace(flags, **opt_overrides)
    rules = rules_for(cfg, mesh, flags)
    model = build_model(cfg, flags, rules)
    specs = input_specs(cfg, shape, flags)

    with set_mesh(mesh):
        if kind == "train":
            opt_cfg = AdamWConfig(
                moment_dtype="bfloat16" if cfg.param_count() > 100e9
                else "float32")
            step = make_train_step(model, opt_cfg)
            def abstract_state(k):
                params = model.init(k)
                return {"params": params,
                        "opt": adamw_init(params, opt_cfg),
                        "step": jnp.zeros((), jnp.int32)}

            state_shapes = jax.eval_shape(abstract_state, jax.random.key(0))
            state_sh = make_state_shardings(model, mesh, rules,
                                            zero1=flags.zero1)
            batch_sh = batch_sharding(mesh, specs)
            fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
            lowered = fn.lower(state_shapes, specs)
        elif kind == "prefill":
            def prefill(params, b):
                logits, _, _ = model.forward(params, b)
                return logits
            param_shapes = jax.eval_shape(model.init, jax.random.key(0))
            param_sh = _sharding_tree(mesh, rules,
                                      model.param_logical_axes())
            batch_sh = batch_sharding(mesh, specs)
            fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh),
                         out_shardings=None)
            lowered = fn.lower(param_shapes, specs)
        else:  # decode
            param_shapes = jax.eval_shape(model.init, jax.random.key(0))
            param_sh = _sharding_tree(mesh, rules,
                                      model.param_logical_axes())
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(batch, seq))
            cache_sh = _sharding_tree(
                mesh, rules, cache_logical_axes(cache_shapes))
            data_axes = tuple(a for a in ("pod", "data")
                              if a in mesh.axis_names)
            bspec = (jax.sharding.PartitionSpec() if flags.seq_shard_decode
                     else jax.sharding.PartitionSpec(data_axes))
            tok_sh = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(
                    mesh, bspec if getattr(s, "ndim", 0) >= 1
                    else jax.sharding.PartitionSpec()), specs)
            fn = jax.jit(model.decode_step,
                         in_shardings=(param_sh, cache_sh, tok_sh),
                         out_shardings=(None, cache_sh))
            lowered = fn.lower(param_shapes, cache_shapes, specs)
        compiled = lowered.compile()
    return compiled, dict(cfg=cfg, mesh=mesh, n_dev=n_dev, flags=flags)


def run_cell(arch: str, shape: str, multi_pod: bool,
             skip_existing: bool = True, opt_overrides=None,
             tag: str = "") -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_name}{tag}.json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    t0 = time.time()
    try:
        compiled, ctx = lower_cell(arch, shape, multi_pod,
                                   opt_overrides=opt_overrides)
        rep = analyze_compiled(
            compiled, arch=arch, shape=shape, mesh_name=mesh_name,
            num_devices=ctx["n_dev"], cfg=ctx["cfg"])
        rec = {"status": "ok", "compile_s": round(time.time() - t0, 1),
               "flags": dataclasses.asdict(ctx["flags"]),
               **rep.row()}
    except Exception as e:  # noqa: BLE001 — record the failure verbatim
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:],
               "compile_s": round(time.time() - t0, 1)}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true", default=True)
    ap.add_argument("--no-skip-existing", dest="skip_existing",
                    action="store_false")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp,
                               skip_existing=args.skip_existing)
                status = rec.get("status")
                line = (f"[{status:7s}] {arch:28s} {shape:12s} "
                        f"{'multipod' if mp else 'pod':8s} "
                        f"t={rec.get('compile_s', 0):6.1f}s")
                if status == "ok":
                    line += (f" bottleneck={rec['bottleneck']:10s} "
                             f"frac={rec['roofline_fraction']:.3f}")
                elif status == "error":
                    line += " " + rec["error"][:120]
                    failures += 1
                print(line, flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
