import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dry-run of the paper's own workload on the production mesh: the A2A
all-pairs engine, planner schema vs naive replication.

Lowers `run_reducers` (gather + per-reducer Gram matmul) for both plans on
the 16x16 mesh and reports HLO-measured roofline terms.  The headline: the
schema's communication-cost reduction shows up 1:1 as gather/collective
bytes in the compiled program.

Usage: PYTHONPATH=src python -m repro.launch.dryrun_engine [--m 1024]
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import naive_pairs, plan_a2a
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HW, combine_hlo_stats
from repro.launch.hlo_analysis import analyze_hlo_text
from repro.kernels.pairwise.fused_gather_gram import fused_traffic_model
from repro.mapreduce.allpairs import _block_fn
from repro.mapreduce.engine import build_plan
from repro.mapreduce.executors import get_executor
from repro.obs import span as _obs_span


def _traced(fn):
    """Wrap an ``analyze_*`` stage in an obs span so a dry run exports a
    per-stage Chrome trace alongside its JSON report."""
    import functools

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        with _obs_span(fn.__name__, stage="dryrun"):
            return fn(*args, **kwargs)
    return wrapper

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun")


def _stats_rec(plan, name, stats, padded_elements, extra=None):
    hw = HW()
    rec = {
        "name": name,
        "reducers": plan.num_reducers,
        "slots": int(plan.mask.sum()),
        "padded_elements": int(padded_elements),
        "schema_comm_cost_rows": float(plan.comm_cost),
        "flops_per_device": stats.flops,
        "hbm_bytes_per_device": stats.hbm_bytes,
        "collective_bytes_per_device": stats.collective_bytes,
        "t_compute": stats.flops / hw.peak_flops,
        "t_memory": stats.hbm_bytes / hw.hbm_bw,
        "t_collective": stats.collective_bytes / hw.link_bw,
    }
    if extra:
        rec.update(extra)
    return rec


@_traced
def analyze(plan, m, d, mesh, name):
    """Dense path: one program padded to the global max slot count."""
    lowered = get_executor("dense").lower(
        (m, d), plan, reducer_fn=_block_fn("dot", False), mesh=mesh,
        dtype=jnp.bfloat16)
    compiled = lowered.compile()
    stats = analyze_hlo_text(compiled.as_text(),
                             num_partitions=mesh.devices.size)
    return _stats_rec(plan, name, stats, plan.dense_padded_elements)


@_traced
def analyze_bucketed(plan, m, d, mesh, name):
    """Bucketed path: one program per capacity bucket; terms are summed
    (the bucket programs run back-to-back on the same mesh)."""
    per_bucket = get_executor("bucketed").lower(
        (m, d), plan, reducer_fn=_block_fn("dot", False), mesh=mesh,
        dtype=jnp.bfloat16)
    stats = combine_hlo_stats([
        analyze_hlo_text(lowered.compile().as_text(),
                         num_partitions=mesh.devices.size)
        for _, lowered in per_bucket
    ])
    return _stats_rec(
        plan, name, stats, plan.bucketed_padded_elements,
        extra={"bucket_widths": plan.bucket_widths(),
               "padding_savings": float(plan.padding_savings)})


@_traced
def analyze_fused(plan, m, d, mesh, name, bucketed_rec=None):
    """Fused path: ONE program for all capacity buckets, gather streamed.

    Lowers the streamed twin of the fused gather+Gram kernel (the jnp
    program with the kernel's tile dataflow — the Pallas kernel itself is
    Mosaic/TPU-only) and reports the HBM bytes it saves over the bucketed
    executor, next to the schema's communication cost and lower bound: the
    saved bytes are the materialized-gather round trip, i.e. the on-device
    copy of exactly the traffic the paper's objective minimizes."""
    lowered = get_executor("fused").lower((m, d), plan, metric="dot",
                                          mesh=mesh, dtype=jnp.bfloat16)
    stats = analyze_hlo_text(lowered.compile().as_text(),
                             num_partitions=mesh.devices.size)
    itemsize = 2                                     # bf16 table rows
    model = fused_traffic_model(plan.buckets, d, itemsize)
    extra = {
        "bucket_widths": plan.bucket_widths(),
        "padding_savings": float(plan.padding_savings),
        "fused_model": model,
        # schema-level shuffle volume for scale: shipped rows x row bytes
        "schema_comm_bytes": float(plan.comm_cost) * d * itemsize,
        "schema_lower_bound_bytes": (
            float(plan.lower_bound) * d * itemsize
            if plan.lower_bound else None),
    }
    if bucketed_rec is not None:
        saved = (bucketed_rec["hbm_bytes_per_device"]
                 - stats.hbm_bytes)
        extra["saved_hbm_bytes_per_device_vs_bucketed"] = saved
        extra["saved_hbm_vs_schema_comm"] = (
            saved * mesh.devices.size / max(extra["schema_comm_bytes"], 1))
    return _stats_rec(plan, name, stats, plan.bucketed_padded_elements,
                      extra=extra)


@_traced
def analyze_streaming(w, q, m, d, name):
    """Streaming path: lower the DELTA program of one single-input edit.

    Builds an ``IncrementalPlanner`` on the profile, applies one insert,
    and lowers what each side would actually execute: the delta's
    dirty-reducer sub-plan vs the full post-edit plan (single-host
    lowering — the delta-vs-replan comparison is per-program, not
    per-mesh).  Reports HLO bytes next to the schema-level ledger: delta
    comm bytes (dirty reducers' shipped rows), full re-plan comm bytes,
    and the instance's replication-rate lower bound — the static planner
    pays the middle number on *every* edit, the streaming planner pays the
    first."""
    from repro.stream import IncrementalPlanner

    ip = IncrementalPlanner(q, w, check=False)
    delta = ip.insert(float(np.median(w)))
    plan = ip.plan()
    ex = get_executor("streaming")
    fn = _block_fn("dot", False)

    def hbm(lowered_list):
        return combine_hlo_stats([
            analyze_hlo_text(lo.compile().as_text())
            for _, lo in lowered_list]).hbm_bytes

    delta_hbm = hbm(ex.lower((m + 1, d), plan, reducer_fn=fn, mesh=None,
                             dtype=jnp.bfloat16, delta=delta))
    full_hbm = hbm(ex.lower((m + 1, d), plan, reducer_fn=fn, mesh=None,
                            dtype=jnp.bfloat16))
    itemsize = 2                                     # bf16 table rows
    lb = float(delta.lower_bound)
    rec = {
        "name": name,
        "edit": delta.kind,
        "reducers": int(delta.num_reducers),
        "dirty_reducers": int(len(delta.dirty_rows)),
        "recompute_fraction": float(delta.recompute_fraction),
        "gap_drift": float(delta.gap_drift),
        "delta_hbm_bytes": delta_hbm,
        "full_hbm_bytes": full_hbm,
        "delta_comm_bytes": float(delta.delta_comm_rows()) * d * itemsize,
        "replan_comm_bytes": float(delta.comm_cost) * d * itemsize,
        "schema_lower_bound_bytes": lb * d * itemsize,
    }
    rec["delta_vs_replan_bytes"] = (
        rec["delta_comm_bytes"] / max(rec["replan_comm_bytes"], 1e-12))
    return rec


@_traced
def analyze_sharded(plan, m, d, mesh, name):
    """Sharded path: ONE shard_map program, reducers LPT-balanced.

    Lowers the sharded executor's program (per-shard fused tile pipeline +
    the single cross-shard assembly gather) on the production mesh and
    reports the *per-shard* HLO bytes next to the schema's per-shard share
    of the communication lower bound: with S shards, a balanced partition
    ships ~comm_cost/S rows per shard, so per-shard HLO bytes should track
    ``lower_bound * d * itemsize / S`` times the plan's optimality gap —
    the partition report quantifies how close LPT gets."""
    ex = get_executor("sharded")
    S = mesh.devices.size
    part = ex.partition(plan, S)
    lowered = ex.lower((m, d), plan, metric="dot", mesh=mesh,
                       dtype=jnp.bfloat16)
    stats = analyze_hlo_text(lowered.compile().as_text(),
                             num_partitions=S)
    itemsize = 2                                     # bf16 table rows
    lb_rows = float(plan.lower_bound) if plan.lower_bound else None
    rep = part.report()
    extra = {
        "num_shards": S,
        "balance_factor": rep["balance_factor"],
        "shipped_rows_per_shard_max": int(max(rep["shipped_rows"])),
        "shipped_rows_per_shard_mean": float(np.mean(rep["shipped_rows"])),
        "padded_elements_per_shard_max": int(
            max(rep["padded_elements_per_shard"])),
        # per-shard HLO bytes vs the schema lower bound's per-shard share
        "per_shard_hbm_bytes": stats.hbm_bytes,
        "schema_lb_bytes_per_shard": (
            lb_rows * d * itemsize / S if lb_rows else None),
        "per_shard_hbm_vs_lb": (
            stats.hbm_bytes / (lb_rows * d * itemsize / S)
            if lb_rows else None),
    }
    return _stats_rec(plan, name, stats, plan.bucketed_padded_elements,
                      extra=extra)


@_traced
def analyze_coded(plan, m, d, name, num_shards: int = 16):
    """Coded path: the replication x communication sweep.

    Lowers the coded executor's program (per-shard rect tile pipeline +
    the residual all-to-all) at several replication rates r on a 1-D
    ``num_shards``-device submesh (the coded combining stage is a 1-D
    shard-group exchange; a full 16x16 lowering adds nothing but compile
    time) and emits the replication-vs-communication Pareto frontier:
    measured per-shard assembly bytes (HLO collectives) fall with r while
    the input-shipping term ``r x comm_cost`` rises, and every point's
    total stays above the Thm-8 lower bound — replication never tunnels
    under it, it only re-shapes where the bytes are paid.
    ``choose_replication`` marks the knee."""
    from repro.compat import make_mesh
    from repro.launch.roofline import collective_bytes
    from repro.mapreduce.executors import choose_replication

    ex = get_executor("coded")
    mesh = make_mesh((num_shards,), ("shard",))
    S = num_shards
    itemsize = 2                                     # bf16 table rows
    lb_rows = float(plan.lower_bound) if plan.lower_bound else None
    lb_bytes = lb_rows * d * itemsize if lb_rows else None
    shipped_bytes = float(plan.comm_cost) * d * itemsize
    best_r, model_frontier = choose_replication(
        plan, S, m, d, itemsize=itemsize)
    frontier = []
    for rec in model_frontier:
        r = rec["replication"]
        lowered = ex.lower((m, d), plan, metric="dot", mesh=mesh,
                           dtype=jnp.bfloat16, m=m, replication=r)
        coll = collective_bytes(lowered.compile().as_text())
        point = {
            "replication": r,
            "measured_assembly_bytes_per_shard": coll["total"],
            "model_assembly_bytes_per_shard":
                rec["assembly_bytes_per_shard"],
            "local_fraction": rec["local_fraction"],
            "shipped_bytes": rec["shipped_bytes"],
            "total_comm_bytes": (rec["shipped_bytes"]
                                 + S * coll["total"]),
            "ge_lower_bound": (
                rec["shipped_bytes"] + S * coll["total"] >= lb_bytes
                if lb_bytes else None),
        }
        frontier.append(point)
    return {
        "name": name,
        "reducers": plan.num_reducers,
        "num_shards": S,
        "best_replication": best_r,
        "schema_comm_bytes": shipped_bytes,
        "schema_lower_bound_bytes": lb_bytes,
        "pareto_frontier": frontier,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=1024)
    ap.add_argument("--d", type=int, default=2048)
    ap.add_argument("--q", type=float, default=32.0)
    ap.add_argument("--zipf", action="store_true",
                    help="Zipf-skewed input sizes (bucketed-executor case)")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    n_dev = mesh.devices.size
    if args.zipf:
        rng = np.random.default_rng(0)
        w = np.clip(rng.zipf(1.6, args.m) / 16.0, 0.05,
                    args.q * 0.45)
    else:
        w = np.ones(args.m)

    schema = plan_a2a(w, args.q)
    plan_opt = build_plan(schema, pad_reducers_to=n_dev)
    plan_nv = build_plan(naive_pairs(w, args.q), pad_reducers_to=n_dev)

    bucketed_rec = analyze_bucketed(plan_opt, args.m, args.d, mesh,
                                    f"planner-bucketed[{schema.algorithm}]")
    rows = [
        analyze(plan_opt, args.m, args.d, mesh,
                f"planner[{schema.algorithm}]"),
        bucketed_rec,
        analyze_fused(plan_opt, args.m, args.d, mesh,
                      f"planner-fused[{schema.algorithm}]",
                      bucketed_rec=bucketed_rec),
        analyze_sharded(plan_opt, args.m, args.d, mesh,
                        f"planner-sharded[{schema.algorithm}]"),
        analyze(plan_nv, args.m, args.d, mesh, "naive-all-pairs"),
    ]
    base = rows[-1]
    for r in rows:
        r["shuffle_bytes_vs_naive"] = (
            r["hbm_bytes_per_device"] / max(base["hbm_bytes_per_device"], 1))
        r["comm_cost_vs_naive"] = (
            r["schema_comm_cost_rows"] / base["schema_comm_cost_rows"])
        print(f"{r['name']:40s} reducers={r['reducers']:8d} "
              f"gather_rows={r['slots']:9d} "
              f"padded={r['padded_elements']:10d} "
              f"t_m={r['t_memory']:.4f}s t_x={r['t_collective']:.4f}s "
              f"bytes_vs_naive={r['shuffle_bytes_vs_naive']:.3f} "
              f"(schema comm ratio {r['comm_cost_vs_naive']:.3f})")
        if "saved_hbm_bytes_per_device_vs_bucketed" in r:
            mdl = r["fused_model"]
            print(f"{'':40s} fused saves "
                  f"{r['saved_hbm_bytes_per_device_vs_bucketed']/1e6:.1f} "
                  f"MB/device HBM vs bucketed "
                  f"({r['saved_hbm_vs_schema_comm']:.2f}x the schema's "
                  f"comm volume of {r['schema_comm_bytes']/1e6:.1f} MB; "
                  f"kernel model: {mdl['saved_bytes']/1e6:.1f} MB global "
                  f"gather round-trip removed)")
        if "num_shards" in r:
            lb = r["schema_lb_bytes_per_shard"]
            print(f"{'':40s} sharded over {r['num_shards']} shards: "
                  f"LPT balance {r['balance_factor']:.3f}, "
                  f"per-shard HLO {r['per_shard_hbm_bytes']/1e6:.1f} MB vs "
                  f"lower-bound share "
                  f"{(lb or 0)/1e6:.1f} MB"
                  + (f" ({r['per_shard_hbm_vs_lb']:.2f}x)" if lb else ""))
    cr = analyze_coded(plan_opt, args.m, args.d,
                       f"coded-frontier[{schema.algorithm}]")
    rows.append(cr)
    print(f"{cr['name']:40s} shards={cr['num_shards']} "
          f"knee r={cr['best_replication']} "
          f"(LB {(cr['schema_lower_bound_bytes'] or 0)/1e6:.2f} MB)")
    for p in cr["pareto_frontier"]:
        print(f"{'':40s} r={p['replication']:2d} assembly "
              f"{p['measured_assembly_bytes_per_shard']/1e6:.2f} MB/shard, "
              f"shipped {p['shipped_bytes']/1e6:.2f} MB, total "
              f"{p['total_comm_bytes']/1e6:.2f} MB "
              f">=LB:{p['ge_lower_bound']}")
    sr = analyze_streaming(w, args.q, args.m, args.d,
                           "streaming-delta[insert]")
    rows.append(sr)
    print(f"{sr['name']:40s} dirty={sr['dirty_reducers']:5d}"
          f"/{sr['reducers']:8d} "
          f"(recompute {sr['recompute_fraction']:.3f}) "
          f"delta HLO {sr['delta_hbm_bytes']/1e6:.1f} MB vs full "
          f"{sr['full_hbm_bytes']/1e6:.1f} MB")
    print(f"{'':40s} delta comm {sr['delta_comm_bytes']/1e6:.2f} MB vs "
          f"re-plan {sr['replan_comm_bytes']/1e6:.2f} MB "
          f"({sr['delta_vs_replan_bytes']:.3f}x) vs lower bound "
          f"{sr['schema_lower_bound_bytes']/1e6:.2f} MB")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "engine_a2a__pod_16x16.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
