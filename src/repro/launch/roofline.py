"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Targets TPU v5e:  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute    = HLO_FLOPs_per_device / peak_FLOPS
  memory     = HLO_bytes_per_device / HBM_bw
  collective = sum over collective ops of per-device moved bytes / link_bw
               (ring model: AG (n-1)/n * out, AR 2(n-1)/n * in,
                RS (n-1)/n * in, A2A (n-1)/n * in, permute = in)

cost_analysis()/as_text() describe the SPMD-partitioned per-device module,
so all three terms are per-device seconds directly comparable against each
other; the bottleneck is the max term.  The roofline fraction we report is
compute / max(all terms) — the fraction of time the MXU would be busy under
perfect overlap.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HW", "RooflineReport", "analyze_compiled", "collective_bytes",
           "combine_hlo_stats", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 / chip
    hbm_bw: float = 819e9               # B/s
    link_bw: float = 50e9               # B/s per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9_\[\],{} ]+?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
# XLA annotates wide tuple types with /*index=N*/ comments; strip them or
# the type char-class above rejects >5-way tuple collectives (e.g. the
# coded executor's 8-way all-to-all).
_HLO_COMMENT_RE = re.compile(r"/\*.*?\*/")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)   # iota format [ngroups,size]
    if m:
        return int(m.group(2))
    return 2


def collective_bytes(hlo_text: str) -> dict:
    """Per-device moved bytes by collective kind (ring cost model)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "ops": 0}
    for line in hlo_text.splitlines():
        line = _HLO_COMMENT_RE.sub("", line)
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue
        result_bytes = _shape_bytes(m.group(1))
        n = max(_group_size(line), 1)
        kind = m.group(2)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-gather":
            moved = result_bytes * frac
        elif kind == "all-reduce":
            moved = 2.0 * result_bytes * frac
        elif kind == "reduce-scatter":
            moved = result_bytes  # result is the scattered shard; input = n*out
        elif kind == "all-to-all":
            moved = result_bytes * frac
        else:  # collective-permute
            moved = result_bytes
        out[kind] += moved
        out["ops"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("ops", "total"))
    return out


def model_flops(cfg, shape_name: str) -> float:
    """Global useful FLOPs per step: 6 N_active D (train), 2 N D (prefill),
    2 N B (decode step) + attention term."""
    from repro.configs.base import SHAPES
    seq, batch, kind = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if kind == "train":
        base = 6.0 * n_active * seq * batch
    elif kind == "prefill":
        base = 2.0 * n_active * seq * batch
    else:
        base = 2.0 * n_active * batch      # one token per request
    return base


def combine_hlo_stats(stats_list):
    """Sum per-device HLO stats over several compiled programs.

    The bucketed shuffle executor runs one XLA program per capacity bucket
    back-to-back on the same mesh, so its roofline terms are the sums of
    the per-bucket terms.  Returns a single HloStats."""
    from .hlo_analysis import HloStats

    out = HloStats()
    for s in stats_list:
        out.flops += s.flops
        out.hbm_bytes += s.hbm_bytes
        out.collective_bytes += s.collective_bytes
        out.collective_ops += s.collective_ops
        for k, v in s.collective_by_kind.items():
            out.collective_by_kind[k] = out.collective_by_kind.get(k, 0) + v
        out.while_trip_counts.extend(s.while_trip_counts)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    flops_per_device: float
    bytes_per_device: float
    collectives: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    roofline_fraction: float
    model_flops_global: float
    useful_flops_ratio: float
    memory_per_device: Optional[dict] = None
    hbm_bytes_kernel_resident: float = 0.0
    t_memory_kernel_resident: float = 0.0
    roofline_fraction_kernel_resident: float = 0.0
    bottleneck_kernel_resident: str = ""

    def row(self) -> dict:
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     num_devices: int, cfg=None, hw: HW = HW()) -> RooflineReport:
    from .hlo_analysis import analyze_hlo_text

    # XLA's cost_analysis counts while (lax.scan) bodies once; our HLO-text
    # walker applies trip-count multipliers (see hlo_analysis.py).
    text = compiled.as_text()
    stats = analyze_hlo_text(text, num_partitions=num_devices)
    stats_res = analyze_hlo_text(text, num_partitions=num_devices,
                                 attn_resident=True)
    flops = stats.flops
    bytes_acc = stats.hbm_bytes
    coll = dict(stats.collective_by_kind)
    coll["total"] = stats.collective_bytes
    coll["ops"] = stats.collective_ops
    coll["while_trip_counts"] = stats.while_trip_counts
    t_c = flops / hw.peak_flops
    t_m = bytes_acc / hw.hbm_bw
    t_x = coll["total"] / hw.link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    frac = t_c / max(max(terms.values()), 1e-30)
    mf = model_flops(cfg, shape) if cfg is not None else 0.0
    ratio = mf / max(flops * num_devices, 1e-30)
    # flash-kernel accounting: attention score tiles VMEM-resident
    t_m_res = stats_res.hbm_bytes / hw.hbm_bw
    terms_res = {"compute": t_c, "memory": t_m_res, "collective": t_x}
    frac_res = t_c / max(max(terms_res.values()), 1e-30)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        }
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, num_devices=num_devices,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        collectives=coll, t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, roofline_fraction=frac,
        model_flops_global=mf, useful_flops_ratio=ratio,
        memory_per_device=mem,
        hbm_bytes_kernel_resident=stats_res.hbm_bytes,
        t_memory_kernel_resident=t_m_res,
        roofline_fraction_kernel_resident=frac_res,
        bottleneck_kernel_resident=max(terms_res, key=terms_res.get))
