"""Structured JSONL event log for plan-lifecycle events (DESIGN.md 1j).

The streaming planners, the caches, and the executors make consequential
decisions that used to happen silently: a gap-drift re-plan fires, a soft
repack migrates bins, a background re-plan swaps in, a jit/plan/block cache
evicts an entry, a fused dispatch falls back to the bucketed path, a comm
reconciliation drifts out of tolerance.  Each of those now emits one event:
a plain dict with ``seq`` (monotonic), ``ts`` (epoch seconds), ``kind``,
and the emitter's fields — held in a bounded ring and, when a sink is
configured (``configure_sink(path)`` or ``REPRO_OBS_EVENTS=path``),
appended to a JSONL file one object per line.

Events are facts, not metrics: the registry answers "how many / how fast",
the event log answers "what happened and why" (a reconciler anomaly event
carries the offending ratios; a drift-replan event carries the trigger
gaps).  ``launch/obs_report.py`` tails this ring.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional

from . import _config

__all__ = ["EventLog", "EVENTS", "emit"]


class EventLog:
    """Bounded in-memory event ring with an optional JSONL file sink."""

    def __init__(self, capacity: int = 4096,
                 sink: Optional[str] = None):
        self._ring: deque = deque(maxlen=capacity)
        self._seq = 0
        self._lock = threading.Lock()
        self._sink_path = sink or os.environ.get("REPRO_OBS_EVENTS") or None
        self._sink_file = None

    def configure_sink(self, path: Optional[str]) -> None:
        """Append future events to ``path`` as JSONL (None disables)."""
        with self._lock:
            if self._sink_file is not None:
                self._sink_file.close()
                self._sink_file = None
            self._sink_path = path

    def emit(self, kind: str, **fields) -> Optional[dict]:
        """Record one event; returns the event dict (None when obs is
        disabled).  Non-JSON field values are stringified at sink time,
        never dropped."""
        if not _config.ENABLED:
            return None
        with self._lock:
            self._seq += 1
            ev = {"seq": self._seq, "ts": time.time(), "kind": str(kind)}
            ev.update(fields)
            self._ring.append(ev)
            if self._sink_path is not None:
                if self._sink_file is None:
                    self._sink_file = open(self._sink_path, "a")
                self._sink_file.write(
                    json.dumps(ev, default=str, sort_keys=True) + "\n")
                self._sink_file.flush()
        return ev

    def events(self, kind: Optional[str] = None, last: int = 0) -> list:
        """Snapshot of the ring (oldest first); filter by ``kind`` and/or
        keep only the ``last`` N."""
        with self._lock:
            evs = list(self._ring)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        return evs[-last:] if last else evs

    def counts(self) -> dict:
        """Event counts by kind (for report summaries)."""
        out: dict = {}
        for e in self.events():
            out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()


#: process-global event log; ``emit(...)`` below is its bound method.
EVENTS = EventLog()
emit = EVENTS.emit
