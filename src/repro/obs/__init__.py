"""Unified observability layer: metrics, spans, events, comm ledger.

Zero-dependency (stdlib-only) telemetry substrate for the repro runtime —
see DESIGN.md section 1j.  Four process-global instruments:

* :data:`REGISTRY` — labeled counters/gauges/histograms
  (:mod:`repro.obs.metrics`);
* :data:`TRACER` / :func:`span` — nested spans with Chrome-trace export
  (:mod:`repro.obs.trace`);
* :data:`EVENTS` / :func:`emit` — structured plan-lifecycle event log
  (:mod:`repro.obs.events`);
* :data:`LEDGER` — the comm reconciler: measured vs predicted vs
  lower-bound shuffle traffic (:mod:`repro.obs.ledger`).

``configure(enabled=False)`` (or ``REPRO_OBS=0`` in the environment) turns
every publish site into a single flag test; ``reset_all()`` zeroes the
whole layer between benchmark phases or test cases.
"""

from __future__ import annotations

from typing import Optional

from . import _config
from .events import EVENTS, EventLog, emit
from .ledger import LEDGER, CommLedger, CommRecord
from .metrics import (
    DEFAULT_BUCKETS,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    exponential_buckets,
)
from .trace import TRACER, Span, Tracer, span

__all__ = [
    "REGISTRY", "TRACER", "EVENTS", "LEDGER",
    "span", "emit",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Tracer", "EventLog", "CommLedger", "CommRecord",
    "DEFAULT_BUCKETS", "exponential_buckets",
    "configure", "enabled", "reset_all",
]


def configure(*, enabled: Optional[bool] = None) -> bool:
    """Flip the global observability switch; returns the current state."""
    if enabled is not None:
        _config.set_enabled(enabled)
    return _config.ENABLED


def enabled() -> bool:
    return _config.ENABLED


def reset_all() -> None:
    """Zero the registry and clear spans/events/ledger (for tests and
    benchmark phase boundaries)."""
    REGISTRY.reset()
    TRACER.clear()
    EVENTS.clear()
    LEDGER.clear()
