"""Metrics registry: counters, gauges, fixed-bucket histograms (DESIGN.md 1j).

The unified runtime-telemetry substrate for the repo: the six registry
executors, both stream planners, the plan/jit/block caches, and
``serve.PairwiseService`` all publish here instead of (or in addition to)
their legacy hand-rolled stats dicts — one queryable place for dashboards,
``launch/obs_report.py``, and the async-serving roadmap item's p50/p99/QPS
inputs.

Design constraints (the overhead budget in DESIGN.md 1j):

* **Cheap enough for per-request use.**  A counter increment is one dict
  lookup plus an integer add; a histogram observation is a ``bisect`` over
  a fixed boundary list.  Nothing allocates on the hot path once a series
  exists, and the global kill switch (``repro.obs.configure(enabled=False)``)
  turns every publish into a single attribute test.
* **Labeled series.**  A metric name plus a label mapping (executor /
  workload / tenant / cache / planner ...) identifies one series; all
  callers that share ``(name, labels)`` share the series, which is exactly
  how ``engine.fused_stats()`` aggregates every ``FusedExecutor`` instance
  into one view.
* **Snapshot / delta / reset.**  ``snapshot()`` is a plain nested dict
  (JSON-ready), ``delta(prev)`` subtracts two snapshots (counter and
  histogram counts; gauges report current), ``reset()`` zeroes in place so
  held series objects stay live.

Quantiles are estimated from fixed log-spaced buckets: p50/p90/p99 are
linearly interpolated inside the bucket containing the target rank, so the
estimate is within one bucket factor (default 1.25x) of the exact
order statistic — tests/test_obs.py pins this against numpy percentiles.

Zero dependencies beyond the stdlib (the obs layer must import in any
process, including the background re-plan daemon thread).
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_right
from typing import Optional

from . import _config

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "exponential_buckets",
]


def exponential_buckets(start: float, factor: float, count: int) -> tuple:
    """``count`` log-spaced bucket upper bounds from ``start``: the fixed
    boundary grid histograms bin into (values above the last bound land in
    the overflow bucket)."""
    assert start > 0 and factor > 1.0 and count >= 1
    return tuple(start * factor ** i for i in range(count))


# ~1 us .. ~80 s at 1.25x resolution: covers every latency this repo
# measures (per-edit p99s of ~100 ms, cold builds of a few seconds) and
# byte-ish magnitudes when a caller wants a distribution of sizes.
DEFAULT_BUCKETS = exponential_buckets(1e-6, 1.25, 82)


class Counter:
    """Monotonic counter (one labeled series)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, by: float = 1) -> None:
        if not _config.ENABLED:
            return
        self.value += by

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-value gauge (one labeled series)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        if not _config.ENABLED:
            return
        self.value = float(value)

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed-bucket histogram with p50/p90/p99 estimation.

    ``bounds`` are ascending bucket upper edges; observations above the
    last edge land in a final overflow bucket.  ``quantile(q)`` walks the
    cumulative counts to the bucket holding rank ``q * count`` and
    interpolates linearly inside it (the overflow bucket reports the max
    seen — exact, since we track it).
    """

    __slots__ = ("bounds", "counts", "count", "total", "min", "max")

    def __init__(self, bounds=DEFAULT_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        if not _config.ENABLED:
            return
        v = float(value)
        self.counts[bisect_right(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0 < q <= 1); 0.0 when empty."""
        if not self.count:
            return 0.0
        rank = q * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            prev = cum
            cum += c
            if cum >= rank:
                if i >= len(self.bounds):        # overflow bucket
                    return self.max
                lo = self.bounds[i - 1] if i else 0.0
                lo = max(lo, self.min if self.min != math.inf else lo)
                hi = min(self.bounds[i], self.max)
                if hi <= lo:
                    return hi
                frac = (rank - prev) / c
                return lo + frac * (hi - lo)
        return self.max

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def summary(self) -> dict:
        out = {"count": self.count, "total": self.total, "mean": self.mean,
               "min": self.min if self.count else 0.0,
               "max": self.max if self.count else 0.0}
        out.update(self.percentiles())
        return out


def _series_key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _render_key(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class MetricsRegistry:
    """Labeled metric series, keyed by ``(name, sorted(labels))``.

    ``counter`` / ``gauge`` / ``histogram`` create-or-return the series, so
    callers hold no registration state; creation takes a lock, subsequent
    publishes are lock-free (CPython dict reads + the GIL — the background
    re-plan thread and the serving thread may race an increment, which at
    worst drops a count, never corrupts).
    """

    def __init__(self):
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}
        self._lock = threading.Lock()

    def _get(self, store: dict, key: tuple, factory):
        s = store.get(key)
        if s is None:
            with self._lock:
                s = store.setdefault(key, factory())
        return s

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, _series_key(name, labels), Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, _series_key(name, labels), Gauge)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        return self._get(self._histograms, _series_key(name, labels),
                         lambda: Histogram(buckets or DEFAULT_BUCKETS))

    # ------------------------------------------------------------- queries
    def counter_total(self, name: str, **labels) -> float:
        """Sum of every counter series matching ``name`` and the given
        label *subset* — the aggregate view (e.g. all fused dispatches
        regardless of workload/tenant)."""
        want = set(labels.items())
        total = 0
        for (n, lbl), c in list(self._counters.items()):
            if n == name and want.issubset(lbl):
                total += c.value
        return total

    def reset_counters(self, name: str, **labels) -> None:
        """Zero every counter series matching ``name`` and the given label
        subset (the write-side companion of :meth:`counter_total`)."""
        want = set(labels.items())
        for (n, lbl), c in list(self._counters.items()):
            if n == name and want.issubset(lbl):
                c.reset()

    def snapshot(self) -> dict:
        """JSON-ready nested snapshot of every series."""
        return {
            "counters": {_render_key(k): c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {_render_key(k): g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {_render_key(k): h.summary()
                           for k, h in sorted(self._histograms.items())},
        }

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """Counter/histogram-count differences between two snapshots
        (gauges report the ``after`` value — they are not cumulative)."""
        d_ctr = {}
        for k, v in after["counters"].items():
            dv = v - before["counters"].get(k, 0)
            if dv:
                d_ctr[k] = dv
        d_hist = {}
        for k, v in after["histograms"].items():
            prev = before["histograms"].get(k, {"count": 0, "total": 0.0})
            dc = v["count"] - prev["count"]
            if dc:
                d_hist[k] = {"count": dc, "total": v["total"] - prev["total"]}
        return {"counters": d_ctr, "gauges": dict(after["gauges"]),
                "histograms": d_hist}

    def reset(self) -> None:
        """Zero every series in place (held series objects stay live)."""
        with self._lock:
            for c in self._counters.values():
                c.reset()
            for g in self._gauges.values():
                g.reset()
            for h in self._histograms.values():
                h.reset()


#: process-global registry — what the instrumented subsystems publish into.
REGISTRY = MetricsRegistry()
