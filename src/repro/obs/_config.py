"""Global observability switch (module-level so every hot-path check is a
single attribute read — see the overhead budget in DESIGN.md 1j).

``REPRO_OBS`` in the environment ("0"/"false"/"off" disables) sets the
initial state; ``repro.obs.configure(enabled=...)`` flips it at runtime —
what ``benchmarks/bench_obs.py`` uses to measure the obs-on vs obs-off
wall-clock overhead.
"""

from __future__ import annotations

import os

ENABLED: bool = os.environ.get("REPRO_OBS", "1").lower() not in (
    "0", "false", "off", "no")


def set_enabled(enabled: bool) -> bool:
    global ENABLED
    ENABLED = bool(enabled)
    return ENABLED
