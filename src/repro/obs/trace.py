"""Span tracer: nested context-manager spans + Chrome-trace export
(DESIGN.md 1j).

``span("plan")`` / ``span("execute", executor="fused")`` wrap the phases of
a request — plan -> compile -> gather/kernel -> assemble — with parent
nesting tracked per thread, so a ``PairwiseService.similarity`` call
produces a small tree: the request span at the root, the planner and
executor phases under it, jit-cache compiles under those.  Completed spans
land in a bounded ring buffer (serving loops never grow memory);
``chrome_trace()`` renders them in the Chrome trace-event format, so
``export_chrome_trace("trace.json")`` loads directly in ``chrome://tracing``
or https://ui.perfetto.dev.

``Tracer(annotate=True)`` (or ``REPRO_OBS_XPROF=1``) additionally enters a
``jax.profiler.TraceAnnotation`` for every span, so the host-side phases
line up with XLA device traces when a jax profile is being captured.  The
jax import is lazy and optional — the obs layer stays importable without
jax (zero-dependency contract).

Overhead: a span is two ``perf_counter`` calls, a dataclass, and a deque
append; disabled (``repro.obs.configure(enabled=False)``) it is a single
flag test yielding a shared no-op.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

from . import _config

__all__ = ["Span", "Tracer", "TRACER", "span"]


@dataclasses.dataclass
class Span:
    """One completed (or in-flight) span; times from ``perf_counter``."""

    name: str
    span_id: int
    parent_id: Optional[int]
    tid: int
    start: float                 # perf_counter seconds
    duration: float = 0.0        # seconds; 0 while in flight
    attrs: dict = dataclasses.field(default_factory=dict)


class Tracer:
    """Ring-buffered span collector with per-thread parent nesting."""

    def __init__(self, capacity: int = 4096, annotate: Optional[bool] = None):
        if annotate is None:
            annotate = os.environ.get("REPRO_OBS_XPROF", "") not in ("", "0")
        self.annotate = bool(annotate)
        self._spans: deque = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextmanager
    def span(self, name: str, **attrs):
        """Context manager: time a phase, nest under the thread's current
        span, record into the ring.  Yields the live :class:`Span` (attach
        late attributes via ``s.attrs[...] = ...``); yields None when
        observability is disabled."""
        if not _config.ENABLED:
            yield None
            return
        stack = self._stack()
        s = Span(name=str(name), span_id=next(self._ids),
                 parent_id=stack[-1].span_id if stack else None,
                 tid=threading.get_ident(), start=time.perf_counter(),
                 attrs=dict(attrs))
        stack.append(s)
        ann = None
        if self.annotate:
            try:
                from jax.profiler import TraceAnnotation
                ann = TraceAnnotation(s.name)
                ann.__enter__()
            except Exception:        # jax absent / profiler unavailable
                ann = None
        try:
            yield s
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            s.duration = time.perf_counter() - s.start
            stack.pop()
            self._spans.append(s)

    # ------------------------------------------------------------- queries
    def spans(self) -> list:
        """Snapshot of the completed-span ring (oldest first)."""
        return list(self._spans)

    def clear(self) -> None:
        self._spans.clear()

    def chrome_trace(self) -> dict:
        """The ring as a Chrome trace-event JSON object (``ph: "X"``
        complete events, microsecond timestamps) — loadable in
        ``chrome://tracing`` / Perfetto."""
        pid = os.getpid()
        events = []
        for s in self._spans:
            args = {k: _jsonable(v) for k, v in s.attrs.items()}
            if s.parent_id is not None:
                args["parent"] = s.parent_id
            args["span_id"] = s.span_id
            events.append({
                "name": s.name, "cat": "repro", "ph": "X",
                "ts": s.start * 1e6, "dur": s.duration * 1e6,
                "pid": pid, "tid": s.tid, "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        """Write :meth:`chrome_trace` to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


#: process-global tracer; ``span(...)`` below is its bound method.
TRACER = Tracer()
span = TRACER.span
