"""Comm-ledger reconciler: measured shuffle traffic vs the schema's
prediction and the Thm-8 lower bound (DESIGN.md 1j).

The paper's objective is communication cost — input copies shipped to
capacity-q reducers — and the planner *predicts* it exactly
(``plan.comm_cost``, weighted rows) along with the theorem lower bound
(``plan.lower_bound``, ``s^2/q`` for all-pairs).  This module closes the
loop at execution time: every executor dispatch records what actually
moved —

* ``measured_slots``: the gather slots the executed program really
  materializes (valid plan slots; replica-stacked slots for the coded
  executor, the dirty sub-plan's slots for a streaming delta);
* ``gathered_bytes``: those slots times the input row size
  (``d * itemsize`` — the byte convention ``dryrun_engine`` uses);
* ``assembled_bytes`` / ``local_bytes`` / ``residual_bytes``: cross-shard
  assembly traffic (the sharded all-gather, the coded residual
  all-to-all) and the coded replica-local vs residual split —

against the plan's booked cost.  The headline ratios:

``measured_over_predicted``
    executed input copies over planned input copies.  The schema books
    ``plan_slots`` copies at weighted cost ``predicted_rows``; the per-copy
    identity makes the ratio ``measured_slots / plan_slots`` in *any*
    weight profile.  Exactly 1.0 on the dense/bucketed/fused/sharded paths
    (they execute the schema verbatim — pinned by tests), exactly ``r`` on
    the coded executor (replication is paid in shipped copies), and the
    recompute fraction on a streaming delta relative to its delta ledger.
``measured_over_lb``
    measured weighted rows over the theorem bound — the *runtime*
    optimality gap: ``optimality_gap x measured_over_predicted``.

Drift beyond tolerance (default 5% relative to the expected replication
multiplier) means execution is not shipping what the plan booked — a plan/
executor bug, not noise — and raises a ``comm_anomaly`` event plus an
anomaly counter.  How to read one: see DESIGN.md 1j.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Optional

from . import _config
from .events import EVENTS
from .metrics import REGISTRY

__all__ = ["CommRecord", "CommLedger", "LEDGER"]


@dataclasses.dataclass
class CommRecord:
    """One execution's communication reconciliation."""

    seq: int
    executor: str
    workload: str
    predicted_rows: float          # schema ledger (weighted input copies)
    lb_rows: Optional[float]       # theorem lower bound, same units
    plan_slots: int                # gather slots the plan books
    measured_slots: int            # gather slots the program executed
    d: int                         # input row feature count
    itemsize: int                  # bytes per feature element
    replication: float = 1.0       # expected copy multiplier (coded: r)
    assembled_bytes: int = 0       # cross-shard assembly traffic (cluster)
    local_bytes: int = 0           # coded: replica-local served bytes
    residual_bytes: int = 0        # coded: cross-shard residual bytes
    anomaly: bool = False
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def row_bytes(self) -> int:
        return self.d * self.itemsize

    @property
    def gathered_bytes(self) -> int:
        return self.measured_slots * self.row_bytes

    @property
    def predicted_bytes(self) -> float:
        return self.predicted_rows * self.row_bytes

    @property
    def lb_bytes(self) -> Optional[float]:
        return None if self.lb_rows is None else self.lb_rows * self.row_bytes

    @property
    def measured_over_predicted(self) -> float:
        """Executed input copies over planned input copies (see module
        docstring: equals measured/predicted weighted rows for any weight
        profile, because both sides count the same per-copy weights)."""
        if self.plan_slots <= 0:
            return 1.0 if self.measured_slots == 0 else float("inf")
        return self.measured_slots / self.plan_slots

    @property
    def measured_rows(self) -> float:
        """Measured traffic in the schema's weighted-row units."""
        return self.predicted_rows * self.measured_over_predicted

    @property
    def measured_over_lb(self) -> Optional[float]:
        if self.lb_rows is None or self.lb_rows <= 0:
            return None
        return self.measured_rows / self.lb_rows

    def summary(self) -> dict:
        return {
            "executor": self.executor, "workload": self.workload,
            "measured_over_predicted": self.measured_over_predicted,
            "measured_over_lb": self.measured_over_lb,
            "replication": self.replication,
            "gathered_bytes": self.gathered_bytes,
            "predicted_bytes": self.predicted_bytes,
            "assembled_bytes": self.assembled_bytes,
            "local_bytes": self.local_bytes,
            "residual_bytes": self.residual_bytes,
            "anomaly": self.anomaly,
        }


class CommLedger:
    """Bounded ring of :class:`CommRecord` with anomaly detection.

    ``tolerance`` is relative: a record is anomalous when its
    ``measured_over_predicted`` deviates from the *expected* multiplier
    (``replication``; 1.0 for unreplicated executors) by more than
    ``tolerance * replication``.  Anomalies emit a ``comm_anomaly`` event
    and bump the ``ledger.anomalies`` counter; every record feeds the
    ``ledger.measured_over_predicted`` histogram per (executor, workload).
    """

    def __init__(self, capacity: int = 2048, tolerance: float = 0.05):
        self._ring: deque = deque(maxlen=capacity)
        self._seq = 0
        self._lock = threading.Lock()
        self.tolerance = float(tolerance)

    def record(self, *, executor: str, workload: str,
               predicted_rows: float, lb_rows: Optional[float],
               plan_slots: int, measured_slots: int, d: int,
               itemsize: int = 4, replication: float = 1.0,
               assembled_bytes: int = 0, local_bytes: int = 0,
               residual_bytes: int = 0,
               meta: Optional[dict] = None) -> Optional[CommRecord]:
        """Reconcile one execution; returns the record (None when obs is
        disabled)."""
        if not _config.ENABLED:
            return None
        with self._lock:
            self._seq += 1
            seq = self._seq
        rec = CommRecord(
            seq=seq, executor=str(executor), workload=str(workload),
            predicted_rows=float(predicted_rows),
            lb_rows=None if lb_rows is None else float(lb_rows),
            plan_slots=int(plan_slots), measured_slots=int(measured_slots),
            d=int(d), itemsize=int(itemsize),
            replication=float(replication),
            assembled_bytes=int(assembled_bytes),
            local_bytes=int(local_bytes),
            residual_bytes=int(residual_bytes), meta=dict(meta or {}))
        ratio = rec.measured_over_predicted
        expected = max(rec.replication, 1e-12)
        if abs(ratio - expected) > self.tolerance * expected:
            rec.anomaly = True
            REGISTRY.counter("ledger.anomalies", executor=rec.executor,
                             workload=rec.workload).inc()
            EVENTS.emit("comm_anomaly", executor=rec.executor,
                        workload=rec.workload,
                        measured_over_predicted=ratio,
                        expected=expected,
                        measured_slots=rec.measured_slots,
                        plan_slots=rec.plan_slots,
                        gathered_bytes=rec.gathered_bytes)
        REGISTRY.counter("ledger.records", executor=rec.executor,
                         workload=rec.workload).inc()
        REGISTRY.counter("ledger.gathered_bytes",
                         executor=rec.executor).inc(rec.gathered_bytes)
        REGISTRY.counter("ledger.assembled_bytes",
                         executor=rec.executor).inc(rec.assembled_bytes)
        REGISTRY.histogram("ledger.measured_over_predicted",
                           executor=rec.executor,
                           workload=rec.workload).observe(ratio)
        mol = rec.measured_over_lb
        if mol is not None:
            REGISTRY.histogram("ledger.measured_over_lb",
                               executor=rec.executor,
                               workload=rec.workload).observe(mol)
        self._ring.append(rec)
        return rec

    # ------------------------------------------------------------- queries
    @property
    def seq(self) -> int:
        """Monotonic count of records ever taken (snapshot marker: compare
        two reads to find how many records a window produced)."""
        return self._seq

    def records(self, since_seq: int = 0) -> list:
        """Records with ``seq > since_seq`` still in the ring (oldest
        first)."""
        return [r for r in list(self._ring) if r.seq > since_seq]

    def last(self) -> Optional[CommRecord]:
        return self._ring[-1] if self._ring else None

    def summary(self) -> dict:
        """Aggregate per (executor, workload): record/anomaly counts, byte
        totals, min/max measured_over_predicted."""
        out: dict = {}
        for r in list(self._ring):
            key = f"{r.executor}/{r.workload}"
            agg = out.setdefault(key, {
                "records": 0, "anomalies": 0, "gathered_bytes": 0,
                "assembled_bytes": 0, "local_bytes": 0, "residual_bytes": 0,
                "measured_over_predicted_min": float("inf"),
                "measured_over_predicted_max": 0.0})
            agg["records"] += 1
            agg["anomalies"] += int(r.anomaly)
            agg["gathered_bytes"] += r.gathered_bytes
            agg["assembled_bytes"] += r.assembled_bytes
            agg["local_bytes"] += r.local_bytes
            agg["residual_bytes"] += r.residual_bytes
            ratio = r.measured_over_predicted
            agg["measured_over_predicted_min"] = min(
                agg["measured_over_predicted_min"], ratio)
            agg["measured_over_predicted_max"] = max(
                agg["measured_over_predicted_max"], ratio)
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()


#: process-global ledger — what the executors reconcile into.
LEDGER = CommLedger()
