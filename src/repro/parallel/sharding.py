"""Logical-axis sharding (MaxText-style rules table).

Model code tags every parameter and key activation with *logical* axis names
('embed', 'heads', 'mlp', 'vocab', 'experts', 'batch', 'seq', ...).  A rules
table maps logical names to mesh axes; changing the parallelism layout is a
rules edit, not a model edit.  This is how the same model lowers on the
16x16 single-pod mesh, the 2x16x16 multi-pod mesh, and a 1-device CPU mesh.

Layouts provided:
  * TP        — heads / mlp / vocab / experts over 'model'
  * FSDP      — additionally shard the embed dim of big params over 'data'
                (+ 'pod'), all-gathered on use (GSPMD inserts the gathers
                inside the layer scan, i.e. ZeRO-3 semantics)
  * SP        — long-context: KV-cache sequence dim over 'model'
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules", "LOGICAL_RULES_BASE", "logical_to_spec",
    "shard_constraint", "named_sharding",
]

# logical name -> preferred mesh axes (first existing axis wins; tuples mean
# shard over multiple axes jointly)
LOGICAL_RULES_BASE: dict[str, tuple] = {
    # data / activation dims
    "batch": (("pod", "data"),),
    "seq": (None,),
    "seq_shard": ("model",),       # sequence-parallel KV cache (long context)
    "act_embed": (None,),
    "act_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_embed_tp": ("model",),    # d_model sharded over TP (RS+AG regions)
    # parameter dims
    "embed": (None,),              # FSDP layout overrides to ('data',)
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (None,),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),         # EP
    "conv": (None,),
    "ssm_state": (None,),
    "ssm_heads": ("model",),
    "layers": (None,),             # scan dim — never sharded
    "stage": ("stage",),           # pipeline stage dim (PP meshes only)
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple                      # tuple of (logical, axes) pairs
    mesh_axis_names: tuple

    @staticmethod
    def create(mesh: Mesh, *, fsdp: bool = False, ep: bool = True,
               seq_shard_decode: bool = False,
               extra: Optional[dict] = None) -> "ShardingRules":
        table = dict(LOGICAL_RULES_BASE)
        if fsdp:
            # ZeRO-3: embed dims of params sharded over the data axes too
            table["embed"] = (("pod", "data"),)
        if not ep:
            table["experts"] = (None,)
        if extra:
            table.update(extra)
        return ShardingRules(rules=tuple(table.items()),
                             mesh_axis_names=tuple(mesh.axis_names))

    def spec(self, *logical: Optional[str]) -> P:
        return logical_to_spec(self, logical)


def _resolve(rules: ShardingRules, name: Optional[str]):
    if name is None:
        return None
    table = dict(rules.rules)
    if name not in table:
        return None
    for cand in table[name]:
        if cand is None:
            return None
        if isinstance(cand, tuple):
            present = tuple(a for a in cand if a in rules.mesh_axis_names)
            if present:
                return present if len(present) > 1 else present[0]
            continue
        if cand in rules.mesh_axis_names:
            return cand
    return None


def logical_to_spec(rules: ShardingRules,
                    logical: Sequence[Optional[str]]) -> P:
    resolved, used = [], set()
    for name in logical:
        axis = _resolve(rules, name)
        # an axis may appear only once in a PartitionSpec
        if axis is not None:
            flat = axis if isinstance(axis, tuple) else (axis,)
            if any(a in used for a in flat):
                axis = None
            else:
                used.update(flat)
        resolved.append(axis)
    return P(*resolved)


def shard_constraint(x, rules: ShardingRules, *logical: Optional[str]):
    """with_sharding_constraint by logical names (no-op off-mesh dims)."""
    spec = logical_to_spec(rules, logical)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # not under a mesh (plain CPU tests)


def named_sharding(mesh: Mesh, rules: ShardingRules,
                   *logical: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(rules, logical))
