"""Distribution substrate: logical-axis sharding rules, mesh helpers,
collective utilities, pipeline parallelism."""

from .sharding import (
    LOGICAL_RULES_BASE,
    ShardingRules,
    logical_to_spec,
    shard_constraint,
)

__all__ = [
    "ShardingRules", "LOGICAL_RULES_BASE", "logical_to_spec",
    "shard_constraint",
]
