"""GPipe-style pipeline parallelism over a 'stage' mesh axis (shard_map).

An alternative to the TP/FSDP layout for depth-dominated models: layers are
split into S stages (params stage-sharded); microbatches stream through the
pipeline, stage boundaries move activations with collective_permute.  The
schedule runs M + S - 1 ticks (classic GPipe bubble = (S-1)/(M+S-1)).

This is deliberately self-contained — select with ``parallelism='pp'`` in a
launcher or use ``pipeline_apply`` directly; the dry-run exercises it via
tests/test_pipeline.py on a host-device mesh.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import pcast, shard_map

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    return (num_stages - 1) / (num_micro + num_stages - 1)


def pipeline_apply(
    stage_fn: Callable,          # (stage_params, x (Bm, ...)) -> (Bm, ...)
    stage_params,                # pytree, leading dim = num_stages
    x_micro: jax.Array,          # (M, Bm, ...) microbatches
    mesh: Mesh,
    stage_axis: str = "stage",
):
    """Run M microbatches through S pipeline stages; returns (M, Bm, ...).

    stage_params leading dim is sharded over `stage_axis`; every device
    executes its stage each tick (bubbles compute garbage that is never
    read — standard GPipe).
    """
    S = mesh.shape[stage_axis]
    M = x_micro.shape[0]
    T = M + S - 1

    def spmd(params_local, xs):
        # params_local: (1, ...) slice; xs: full (M, Bm, ...) (replicated)
        params_one = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        # mark carries as stage-varying up front (scan requires stable vma)
        buf = pcast(jnp.zeros_like(xs[0]), stage_axis, to="varying")
        outs = pcast(jnp.zeros_like(xs), stage_axis, to="varying")

        def tick(carry, t):
            buf, outs = carry
            # receive boundary activation from the previous stage
            recv = jax.lax.ppermute(
                buf, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            inject = jnp.where(t < M, t, 0)
            x_in = jnp.where(sid == 0, xs[inject], recv)
            y = stage_fn(params_one, x_in)
            # last stage records finished microbatch t - (S - 1)
            # (masked write — lax.cond branches disagree on shard_map
            # varying axes, a masked select does not)
            slot = t - (S - 1)
            slot_c = jnp.clip(slot, 0, M - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, slot_c, 0,
                                               keepdims=False)
            val = jnp.where((sid == S - 1) & (slot >= 0), y, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, val, slot_c, 0)
            return (y, outs), None

        (buf, outs), _ = jax.lax.scan(
            tick, (buf, outs), jnp.arange(T))
        # broadcast results from the last stage to all (psum of masked)
        outs = jnp.where(sid == S - 1, outs, 0)
        return jax.lax.psum(outs, stage_axis)

    params_spec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    fn = shard_map(
        spmd, mesh=mesh,
        in_specs=(params_spec, P()),
        out_specs=P(),
    )
    return fn(stage_params, x_micro)
