"""Runtime (non-architecture) knobs shared by train/serve/dry-run."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RuntimeFlags:
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # 'none' | 'full' | 'dots'
    use_pallas: bool = False         # TPU kernels (interpret on CPU tests)
    ssd_impl: str = "step"           # 'step' (baseline) | 'chunked'
    kv_quant: str = "none"           # 'none' | 'int8' (halves KV capacity)
    attn_probs_dtype: str = "float32"  # 'bfloat16' halves PV-matmul traffic
    kernel_resident_attn: bool = False  # roofline: scores live in VMEM
                                        # (Pallas flash kernel accounting)
    moe_mode: str = "auto"           # 'ep' | 'tp' | 'auto'
    capacity_factor: float = 1.25
    fsdp: bool = False               # ZeRO-3 param sharding over data axes
    seq_shard_decode: bool = False   # shard KV cache sequence over 'model'
    seq_shard_axes: str = "model"    # 'model' | 'all' (long-context, B=1)
    scan_layers: bool = True
    grad_compression: str = "none"   # 'none' | 'bf16' | 'int8'
    zero1: bool = True               # shard optimizer state over data axes

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)
