"""Top-level language models: decoder-only, encoder-decoder, frontend stubs.

``build_model(cfg, flags)`` returns an ``LMModel`` exposing:

  init(key)                         -> params
  param_logical_axes()              -> pytree of logical axis tuples
  loss(params, batch)               -> (scalar, metrics)     [train fwd]
  init_cache(batch, max_len)        -> decode cache
  decode_step(params, cache, batch) -> (logits, new_cache)   [serve fwd]

batch dicts:
  decoder-only: {'tokens' (B,S), 'targets' (B,S), 'mask' (B,S)}
  audio:        + {'audio_embeds' (B, S_enc, d)}   (frontend stub)
  vision:       + {'image_embeds' (B, F, d)}       (frontend stub)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ShardingRules, shard_constraint

from .blocks import (
    LayerSpec,
    StackDef,
    stack_apply,
    stack_init,
    stack_init_cache,
)
from .configs_runtime import RuntimeFlags
from .layers import embed_apply, embed_init, rms_norm, unembed_apply

__all__ = ["LMModel", "build_model"]


def _specs_to_stack(kinds: list[dict], period: int) -> StackDef:
    specs = [LayerSpec(mixer=k["mixer"], window=k["window"], ffn=k["ffn"],
                       cross=k["cross"]) for k in kinds]
    n = len(specs)
    if period <= 1:
        # uniform stack: scan every layer individually
        assert all(s == specs[0] for s in specs)
        return StackDef(pattern=(specs[0],), n_blocks=n, tail=())
    n_blocks = n // period
    tail = tuple(specs[n_blocks * period:])
    # all full blocks must share the pattern
    pattern = tuple(specs[:period])
    for b in range(1, n_blocks):
        assert tuple(specs[b * period:(b + 1) * period]) == pattern, \
            "layer kinds are not periodic"
    return StackDef(pattern=pattern, n_blocks=n_blocks, tail=tail)


@dataclasses.dataclass(frozen=True)
class LMModel:
    cfg: ArchConfig
    flags: RuntimeFlags
    rules: ShardingRules
    stack: StackDef
    enc_stack: Optional[StackDef]

    # ------------------------------------------------------------------ init
    def init(self, key):
        kd, ke, kenc = jax.random.split(key, 3)
        params: dict = {}
        axes: dict = {}
        params["embed"], axes["embed"] = embed_init(
            ke, self.cfg.padded_vocab(), self.cfg.d_model, self.flags.pdtype)
        params["stack"], axes["stack"] = stack_init(
            kd, self.stack, self.cfg, self.flags)
        params["ln_f"] = jnp.zeros((self.cfg.d_model,), jnp.float32)
        axes["ln_f"] = ("embed",)
        if self.enc_stack is not None:
            params["enc_stack"], axes["enc_stack"] = stack_init(
                kenc, self.enc_stack, self.cfg, self.flags)
            params["enc_ln_f"] = jnp.zeros((self.cfg.d_model,), jnp.float32)
            axes["enc_ln_f"] = ("embed",)
        object.__setattr__(self, "_axes_cache", axes)
        return params

    def param_logical_axes(self):
        if not hasattr(self, "_axes_cache"):
            # build axes without materializing params
            jax.eval_shape(self.init, jax.random.key(0))
        return self._axes_cache

    # ------------------------------------------------------------- encoder
    def _encode(self, params, audio_embeds):
        x = audio_embeds.astype(self.flags.cdtype)
        x = shard_constraint(x, self.rules, "batch", None, "act_embed")
        x, _, _ = stack_apply(
            params["enc_stack"], x, self.enc_stack, self.cfg, self.flags,
            self.rules)
        return rms_norm(x, params["enc_ln_f"], self.cfg.norm_eps)

    # -------------------------------------------------------------- forward
    def forward(self, params, batch, *, cache=None, positions=None):
        """Returns (logits, new_cache, aux)."""
        cfg, flags, rules = self.cfg, self.flags, self.rules
        tokens = batch["tokens"]
        x = embed_apply(params["embed"], tokens, rules)
        x = x.astype(flags.cdtype)
        if cfg.frontend == "vision" and "image_embeds" in batch:
            img = batch["image_embeds"].astype(flags.cdtype)
            x = jnp.concatenate([img, x], axis=1)
        enc_out = None
        if self.enc_stack is not None:
            # decode passes a precomputed encoder output ('enc_out') so the
            # encoder does not rerun every step
            if "enc_out" in batch:
                enc_out = batch["enc_out"]
            else:
                enc_out = self._encode(params, batch["audio_embeds"])
        if positions is None:
            positions = jnp.arange(x.shape[1])
        x, new_cache, aux = stack_apply(
            params["stack"], x, self.stack, cfg, flags, rules,
            cache=cache, positions=positions, enc_out=enc_out)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.frontend == "vision" and "image_embeds" in batch:
            x = x[:, batch["image_embeds"].shape[1]:, :]
        logits = unembed_apply(params["embed"], x, rules)
        return logits, new_cache, aux

    def loss(self, params, batch):
        logits, _, aux = self.forward(params, batch)
        targets = batch["targets"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(targets, jnp.float32)
        logits = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, targets[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll) / denom
        total = ce + 0.01 * aux
        metrics = {"ce": ce, "aux": aux,
                   "tokens": jnp.sum(mask)}
        return total, metrics

    # --------------------------------------------------------------- serve
    def init_cache(self, batch_size: int, max_len: int):
        return stack_init_cache(self.stack, self.cfg, self.flags,
                                batch_size, max_len)

    def decode_step(self, params, cache, batch):
        """One-token step.  batch: {'tokens' (B,1), 'pos' () int32} plus
        frontend embeds for enc-dec archs."""
        pos = batch["pos"]
        positions = pos[None] if jnp.ndim(pos) == 0 else pos
        logits, new_cache, _ = self.forward(
            params, batch, cache=cache, positions=positions)
        return logits, new_cache


def build_model(cfg: ArchConfig, flags: RuntimeFlags,
                rules: ShardingRules) -> LMModel:
    period = max(1, cfg.attn_period, cfg.local_global_period,
                 cfg.moe_period if cfg.num_experts else 1)
    stack = _specs_to_stack(cfg.layer_kinds(), period)
    enc_stack = None
    if cfg.encoder_layers:
        enc_spec = LayerSpec(mixer="attn", window=0, ffn="dense",
                             cross=False, causal=False)
        enc_stack = StackDef(pattern=(enc_spec,),
                             n_blocks=cfg.encoder_layers, tail=())
    return LMModel(cfg=cfg, flags=flags, rules=rules, stack=stack,
                   enc_stack=enc_stack)
