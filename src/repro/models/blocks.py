"""Layer blocks and scanned stacks.

An architecture is a repeating *pattern* of LayerSpecs (e.g. Gemma-3:
5 local-window layers + 1 global layer; Jamba: 1 attention + 7 Mamba with
MoE every other FFN).  Full pattern repetitions are stacked and lax.scan'ed
(one-superblock HLO regardless of depth — critical for 512-device compile
times); the remainder layers form an unrolled tail.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ShardingRules

from .layers import (
    AttnSpec,
    attn_apply,
    attn_init,
    attn_init_cache,
    mlp_apply,
    mlp_init,
    rms_norm,
)
from .mamba import mamba_apply, mamba_init, mamba_init_cache
from .moe import moe_apply, moe_init

__all__ = ["LayerSpec", "StackDef", "stack_init", "stack_apply",
           "stack_init_cache"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # 'attn' | 'mamba'
    window: int = 0              # sliding window (attn only; 0 = full)
    ffn: str = "dense"           # 'dense' | 'moe' | 'none'
    cross: bool = False          # cross-attention (enc-dec decoder)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class StackDef:
    pattern: tuple[LayerSpec, ...]
    n_blocks: int                # scanned repetitions of the pattern
    tail: tuple[LayerSpec, ...]  # unrolled remainder

    @property
    def num_layers(self) -> int:
        return self.n_blocks * len(self.pattern) + len(self.tail)


# --------------------------------------------------------------------- init

def _layer_init(key, spec: LayerSpec, cfg, flags):
    ks = jax.random.split(key, 6)
    dtype = flags.pdtype
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    ax: dict = {"ln1": ("embed",)}
    if spec.mixer == "attn":
        mp, max_ = attn_init(ks[0], cfg.d_model, _attn_spec(spec, cfg), dtype)
    else:
        mp, max_ = mamba_init(ks[0], cfg.d_model, cfg.ssm_state, dtype)
    p["mixer"], ax["mixer"] = mp, max_
    if spec.cross:
        cp, cax = attn_init(ks[1], cfg.d_model, _cross_spec(cfg), dtype)
        p["cross"], ax["cross"] = cp, cax
        p["ln_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
        ax["ln_cross"] = ("embed",)
    if spec.ffn != "none":
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        ax["ln2"] = ("embed",)
        if spec.ffn == "moe":
            fp, fax = moe_init(ks[2], cfg.d_model, cfg.d_ff,
                               cfg.num_experts, dtype)
        else:
            fp, fax = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype,
                               variant=cfg.mlp_variant)
        p["ffn"], ax["ffn"] = fp, fax
    return p, ax


def _attn_spec(spec: LayerSpec, cfg) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim_(), window=spec.window, causal=spec.causal,
        rope_theta=cfg.rope_theta)


def _cross_spec(cfg) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim_(), window=0, causal=False, use_rope=False)


def stack_init(key, stack: StackDef, cfg, flags):
    """Returns (params, logical_axes).  Scanned positions get a leading
    'layers' axis of size n_blocks."""
    kb, kt = jax.random.split(key)
    params: dict = {}
    axes: dict = {}
    if stack.n_blocks > 0:
        for i, spec in enumerate(stack.pattern):
            keys = jax.random.split(
                jax.random.fold_in(kb, i), stack.n_blocks)
            init_one = functools.partial(_layer_init, spec=spec, cfg=cfg,
                                         flags=flags)
            stacked_p = jax.vmap(lambda k: init_one(k)[0])(keys)
            _, ax = _layer_init(keys[0], spec, cfg, flags)
            params[f"pos{i}"] = stacked_p
            axes[f"pos{i}"] = jax.tree.map(
                lambda a: ("layers",) + tuple(a), ax,
                is_leaf=lambda a: isinstance(a, tuple))
    for j, spec in enumerate(stack.tail):
        p, ax = _layer_init(jax.random.fold_in(kt, j), spec, cfg, flags)
        params[f"tail{j}"] = p
        axes[f"tail{j}"] = ax
    return params, axes


# -------------------------------------------------------------------- apply

def _block_apply(p, x, spec: LayerSpec, cfg, flags, rules: ShardingRules,
                 cache=None, positions=None, enc_out=None):
    new_cache = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    import jax.numpy as _jnp
    if spec.mixer == "attn":
        y, mc = attn_apply(
            p["mixer"], h, _attn_spec(spec, cfg), rules,
            cache=None if cache is None else cache["mixer"],
            positions=positions, use_pallas=flags.use_pallas,
            probs_dtype=_jnp.dtype(flags.attn_probs_dtype))
    else:
        meta = cfg.mamba_meta()
        y, mc = mamba_apply(
            p["mixer"], h, meta, rules,
            cache=None if cache is None else cache["mixer"],
            use_pallas=flags.use_pallas, ssd_impl=flags.ssd_impl)
    x = x + y
    if cache is not None:
        new_cache["mixer"] = mc
    aux = jnp.zeros((), jnp.float32)
    if spec.cross:
        assert enc_out is not None
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        y, _ = attn_apply(p["cross"], h, _cross_spec(cfg), rules,
                          kv_src=enc_out)
        x = x + y
    if spec.ffn != "none":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "moe":
            y, moe_aux = moe_apply(
                p["ffn"], h, top_k=cfg.experts_per_token,
                capacity_factor=flags.capacity_factor, rules=rules)
            aux = aux + moe_aux["load_balance"]
        else:
            y = mlp_apply(p["ffn"], h, rules)
        x = x + y
    return x, new_cache if cache is not None else None, aux


def _remat(fn, flags):
    if flags.remat == "none":
        return fn
    if flags.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def stack_apply(params, x, stack: StackDef, cfg, flags,
                rules: ShardingRules, *, cache=None, positions=None,
                enc_out=None):
    """Returns (x, new_cache, aux_sum)."""
    aux_total = jnp.zeros((), jnp.float32)

    def superblock(x, block_params, block_cache):
        aux_sb = jnp.zeros((), jnp.float32)
        new_bc = {}
        for i, spec in enumerate(stack.pattern):
            x, nc, aux = _block_apply(
                block_params[f"pos{i}"], x, spec, cfg, flags, rules,
                cache=None if block_cache is None else block_cache[f"pos{i}"],
                positions=positions, enc_out=enc_out)
            if block_cache is not None:
                new_bc[f"pos{i}"] = nc
            aux_sb = aux_sb + aux
        return x, (new_bc if block_cache is not None else None), aux_sb

    new_cache: dict = {}
    if stack.n_blocks > 0:
        scanned_params = {f"pos{i}": params[f"pos{i}"]
                          for i in range(len(stack.pattern))}
        scanned_cache = (None if cache is None else
                         {f"pos{i}": cache[f"pos{i}"]
                          for i in range(len(stack.pattern))})

        if cache is None:
            def body(carry, xs):
                x, aux = carry
                x, _, aux_sb = _remat(superblock, flags)(x, xs, None)
                return (x, aux + aux_sb), None

            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), scanned_params)
        else:
            def body(carry, xs):
                x, aux = carry
                bp, bc = xs
                x, nbc, aux_sb = superblock(x, bp, bc)
                return (x, aux + aux_sb), nbc

            (x, aux_total), new_scan_cache = jax.lax.scan(
                body, (x, aux_total), (scanned_params, scanned_cache))
            new_cache.update(new_scan_cache)

    for j, spec in enumerate(stack.tail):
        x, nc, aux = _block_apply(
            params[f"tail{j}"], x, spec, cfg, flags, rules,
            cache=None if cache is None else cache[f"tail{j}"],
            positions=positions, enc_out=enc_out)
        if cache is not None:
            new_cache[f"tail{j}"] = nc
        aux_total = aux_total + aux
    return x, (new_cache if cache is not None else None), aux_total


# -------------------------------------------------------------------- cache

def _layer_init_cache(spec: LayerSpec, cfg, flags, batch, max_len):
    if spec.mixer == "attn":
        return {"mixer": attn_init_cache(
            batch, max_len, _attn_spec(spec, cfg), flags.cdtype,
            kv_quant=flags.kv_quant)}
    return {"mixer": mamba_init_cache(batch, cfg.mamba_meta(), flags.cdtype)}


def stack_init_cache(stack: StackDef, cfg, flags, batch, max_len):
    cache: dict = {}
    for i, spec in enumerate(stack.pattern):
        one = _layer_init_cache(spec, cfg, flags, batch, max_len)
        cache[f"pos{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (stack.n_blocks,) + a.shape), one)
    for j, spec in enumerate(stack.tail):
        cache[f"tail{j}"] = _layer_init_cache(spec, cfg, flags, batch,
                                              max_len)
    return cache
