"""Model zoo: composable pure-JAX transformer / MoE / SSM / hybrid blocks.

Everything is functional: ``init_*`` builds param pytrees, ``apply``-style
functions are pure.  Layers are tagged with logical sharding axes
(repro.parallel.sharding); layer stacks are scanned so 90-layer models lower
to one-layer HLO.
"""

from .lm import LMModel, build_model
from .configs_runtime import RuntimeFlags

__all__ = ["LMModel", "build_model", "RuntimeFlags"]
