"""Primitive layers: norm, RoPE, GQA attention (+KV cache), MLP, embedding.

Parameters are dicts of arrays; every init returns (params, logical_axes)
where logical_axes mirrors the param tree with tuples of logical axis names
consumed by repro.parallel.sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ShardingRules, shard_constraint

# ---------------------------------------------------------------- utilities

def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, gamma, eps):
    """Fused RMSNorm: f32 math inside, activation-dtype in/out.

    The hand-written VJP keeps the f32 gradient chain inside one fused
    expression and emits cotangents in x.dtype — without it, autodiff
    materializes ~5 full (B, S, d) f32 tensors per norm in the backward
    pass (measured: the dominant HBM-traffic term on every dense arch;
    see EXPERIMENTS.md §Perf iteration 2)."""
    out, _ = _rms_norm_fwd(x, gamma, eps)
    return out


def _rms_norm_fwd(x, gamma, eps):
    x32 = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    out = (x32 * rstd * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)
    return out, (x, rstd, gamma)


def _rms_norm_bwd(eps, res, g):
    x, rstd, gamma = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    xhat = x32 * rstd
    dxhat = g32 * (1.0 + gamma.astype(jnp.float32))
    dgamma = jnp.sum(g32 * xhat,
                     axis=tuple(range(x.ndim - 1))).astype(gamma.dtype)
    dx = rstd * (dxhat - xhat * jnp.mean(dxhat * xhat, -1, keepdims=True))
    return dx.astype(x.dtype), dgamma


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def rope(x, positions, theta: float):
    """x (..., S, H, D) rotated by position; D even."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ embed

def embed_init(key, vocab, d_model, dtype):
    p = {"table": _normal(key, (vocab, d_model), 0.02, dtype)}
    ax = {"table": ("vocab", "embed")}
    return p, ax


def embed_apply(params, tokens, rules: ShardingRules):
    out = jnp.take(params["table"], tokens, axis=0)
    return shard_constraint(out, rules, "batch", None, "act_embed")


def unembed_apply(params, x, rules: ShardingRules):
    logits = jnp.einsum("bsd,vd->bsv", x, params["table"])
    return shard_constraint(logits, rules, "batch", None, "act_vocab")


# ------------------------------------------------------------------ MLP

def mlp_init(key, d_model, d_ff, dtype, variant: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    if variant == "gelu":           # classic 2-matrix MLP (Whisper, Granite)
        p = {
            "wi": _normal(k1, (d_model, d_ff), scale_in, dtype),
            "wo": _normal(k3, (d_ff, d_model), scale_out, dtype),
        }
        ax = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
        return p, ax
    p = {
        "wi_gate": _normal(k1, (d_model, d_ff), scale_in, dtype),
        "wi_up": _normal(k2, (d_model, d_ff), scale_in, dtype),
        "wo": _normal(k3, (d_ff, d_model), scale_out, dtype),
    }
    ax = {
        "wi_gate": ("embed", "mlp"),
        "wi_up": ("embed", "mlp"),
        "wo": ("mlp", "embed"),
    }
    return p, ax


def mlp_apply(params, x, rules: ShardingRules):
    if "wi" in params:              # gelu variant
        h = jnp.einsum("bsd,df->bsf", x, params["wi"])
        h = shard_constraint(jax.nn.gelu(h), rules, "batch", None, "act_mlp")
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["wi_gate"])
        u = jnp.einsum("bsd,df->bsf", x, params["wi_up"])
        h = shard_constraint(jax.nn.silu(h) * u, rules,
                             "batch", None, "act_mlp")
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"])
    return shard_constraint(out, rules, "batch", None, "act_embed")


# ------------------------------------------------------------ GQA attention

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    window: int = 0            # 0 = full attention
    causal: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True


def attn_init(key, d_model, spec: AttnSpec, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    H, Hkv, D = spec.num_heads, spec.num_kv_heads, spec.head_dim
    s = d_model ** -0.5
    p = {
        "wq": _normal(kq, (d_model, H, D), s, dtype),
        "wk": _normal(kk, (d_model, Hkv, D), s, dtype),
        "wv": _normal(kv, (d_model, Hkv, D), s, dtype),
        "wo": _normal(ko, (H, D, d_model), (H * D) ** -0.5, dtype),
    }
    ax = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    return p, ax


def _grouped_attention(q, k, v, *, causal, window, q_pos, kv_len,
                       rules: ShardingRules, probs_dtype=jnp.float32):
    """q (B,S,H,D), k/v (B,Skv,Hkv,D) without repeating KV heads.

    q_pos: (S,) global positions of queries; keys occupy positions [0, Skv)
    masked by kv_len (scalar or (B,)).  Softmax in fp32.
    """
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scale = D ** -0.5
    scores = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale  # (B,Hkv,G,S,Skv)
    kv_pos = jnp.arange(Skv)
    mask = kv_pos[None, :] < (
        kv_len if jnp.ndim(kv_len) else jnp.full((1,), kv_len))[:, None]
    mask = mask[:, None, None, None, :]                  # (B,1,1,1,Skv)
    rel = q_pos[:, None] - kv_pos[None, :]               # (S, Skv)
    if causal:
        mask = mask & (rel >= 0)[None, None, None]
    if window and window > 0:
        mask = mask & (rel < window)[None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(probs_dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(probs_dtype))
    out = out.reshape(B, S, H, D)
    return shard_constraint(out.astype(q.dtype), rules,
                            "batch", None, "act_heads", "head_dim")


def _kv_quantize(t):
    """Symmetric per-(token, head) int8: t (B,S,H,D) -> (int8, f32 scale)."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0]


def _kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attn_apply(params, x, spec: AttnSpec, rules: ShardingRules, *,
               cache: Optional[dict] = None,
               positions: Optional[jax.Array] = None,
               use_pallas: bool = False,
               kv_src: Optional[jax.Array] = None,
               probs_dtype=jnp.float32):
    """Self-attention (or cross-attention when kv_src is the encoder output).

    cache: {'k','v': (B, Smax, Hkv, D), 'len': ()} — decode appends at 'len'.
    Returns (y, new_cache).
    """
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = shard_constraint(q, rules, "batch", None, "act_heads", "head_dim")
    src = x if kv_src is None else kv_src
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])

    if positions is None:
        positions = jnp.arange(S)
    if spec.use_rope:
        q = rope(q, positions, spec.rope_theta)
        if kv_src is None:
            k = rope(k, positions, spec.rope_theta)

    new_cache = None
    if cache is not None:
        # decode: write this step's k/v at index cache['len'].  Windowed
        # layers use a RING buffer of size `window` (allocated that way by
        # attn_init_cache): absolute position -> slot pos % window.  Keys are
        # RoPE'd with absolute positions before writing, so ring entries stay
        # valid; every live slot is inside the window by construction, which
        # replaces the causal/window mask with a plain validity mask.
        idx = cache["len"]
        cache_len = cache["k"].shape[1]
        ring = spec.window > 0 and cache_len <= spec.window
        write_idx = (idx % cache_len) if ring else idx
        quant = cache["k"].dtype == jnp.int8
        if quant:
            # int8 KV (kv_quant='int8'): 2x cache capacity; per-(token,
            # head) symmetric scales stored alongside
            kq, ks = _kv_quantize(k)
            vq, vs = _kv_quantize(v)
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kq, write_idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vq, write_idx, axis=1)
            cks = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, write_idx, axis=1)
            cvs = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, write_idx, axis=1)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                         "len": idx + S}
            k = _kv_dequantize(ck, cks, x.dtype)
            v = _kv_dequantize(cv, cvs, x.dtype)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
                cache["k"].dtype), write_idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
                cache["v"].dtype), write_idx, axis=1)
            new_cache = {"k": ck, "v": cv, "len": idx + S}
            k, v = ck, cv
        k = shard_constraint(k, rules, "batch", "seq_shard", None, None)
        v = shard_constraint(v, rules, "batch", "seq_shard", None, None)
        kv_len = jnp.minimum(idx + S, cache_len)
        out = _grouped_attention(
            q, k, v, causal=spec.causal and not ring,
            window=0 if ring else spec.window,
            q_pos=positions, kv_len=kv_len, rules=rules,
            probs_dtype=probs_dtype)
    else:
        kv_len = k.shape[1]
        if use_pallas and spec.causal and kv_override is None:
            from repro.kernels.flash.ops import mha
            out = mha(q, k, v, causal=True, window=spec.window,
                      use_kernel=True, interpret=True)
        else:
            out = _grouped_attention(
                q, k, v, causal=spec.causal, window=spec.window,
                q_pos=positions, kv_len=kv_len, rules=rules,
                probs_dtype=probs_dtype)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shard_constraint(y, rules, "batch", None, "act_embed"), new_cache


def attn_init_cache(batch, max_len, spec: AttnSpec, dtype,
                    kv_quant: str = "none"):
    Hkv, D = spec.num_kv_heads, spec.head_dim
    if spec.window > 0:
        max_len = min(max_len, spec.window)   # ring buffer for SWA layers
    if kv_quant == "int8":
        return {
            "k": jnp.zeros((batch, max_len, Hkv, D), jnp.int8),
            "v": jnp.zeros((batch, max_len, Hkv, D), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, Hkv), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, Hkv), jnp.float32),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, Hkv, D), dtype),
        "v": jnp.zeros((batch, max_len, Hkv, D), dtype),
        "len": jnp.zeros((), jnp.int32),
    }
