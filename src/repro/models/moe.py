"""Mixture-of-Experts layer with capacity-constrained sparse dispatch.

The dispatch problem is exactly the paper's setting: variable-cost inputs
(tokens) must be routed to capacity-bounded reducers (experts) while
minimizing shuffled bytes.  We use *grouped* argsort dispatch (GShard-style
groups = batch rows, so every per-group tensor keeps the leading batch dim
and shards over 'data'):

  1. top-k routing -> (token, expert) assignments;
  2. per group: stable argsort by expert id, position-within-expert
     (rank - segment start) enforces the capacity C = cf * S * k / E,
     overflow drops (standard GShard semantics);
  3. one gather builds (B, E, C, d) expert batches -> batched expert FFN on
     the MXU -> weighted scatter-add combines results.

Grouping is what keeps the compiled per-device FLOPs proportional to the
LOCAL batch (a global argsort forces GSPMD to replicate the expert compute
across the data axis — 14x compute inflation measured in the dry-run; see
EXPERIMENTS.md §Perf iteration 1).  Expert weights shard over 'model' as EP
when E divides the axis (Llama-4: 128/16), else TP over d_ff (Mixtral: 8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ShardingRules, shard_constraint

from .layers import _normal

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d_model, d_ff, num_experts, dtype):
    kg, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    p = {
        "router": _normal(kg, (d_model, num_experts), s_in, jnp.float32),
        "wi_gate": _normal(k1, (num_experts, d_model, d_ff), s_in, dtype),
        "wi_up": _normal(k2, (num_experts, d_model, d_ff), s_in, dtype),
        "wo": _normal(k3, (num_experts, d_ff, d_model), s_out, dtype),
    }
    ax = {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "mlp"),
        "wi_up": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    return p, ax


def moe_apply(params, x, *, top_k: int, capacity_factor: float,
              rules: ShardingRules):
    """x (B, S, d) -> (B, S, d); aux losses returned as dict."""
    B, S, d = x.shape
    E = params["router"].shape[1]
    C = max(1, int(capacity_factor * S * top_k / E))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                # (B, S, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # (B, S, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = {"load_balance": E * jnp.sum(me * ce)}

    A = S * top_k

    def dispatch_one(flat_e, flat_g):
        """Per group (batch row): (A,) expert ids -> slot tables (E*C,)."""
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(A) - starts[sorted_e]
        keep = pos < C
        dest = jnp.where(keep, sorted_e * C + pos, E * C)   # OOB -> drop
        src_token = (order // top_k).astype(jnp.int32)
        slot_src = jnp.full((E * C,), S, jnp.int32).at[dest].set(
            src_token, mode="drop")
        slot_gate = jnp.zeros((E * C,), jnp.float32).at[dest].set(
            flat_g[order], mode="drop")
        return slot_src, slot_gate

    slot_src, slot_gate = jax.vmap(dispatch_one)(
        gate_idx.reshape(B, A), gate_vals.reshape(B, A))    # (B, E*C)

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad, slot_src[..., None].astype(jnp.int32), axis=1)  # the shuffle
    xe = xe.reshape(B, E, C, d)
    xe = shard_constraint(xe, rules, "batch", "experts", None, "act_embed")

    h = jnp.einsum("becd,edf->becf", xe, params["wi_gate"])
    u = jnp.einsum("becd,edf->becf", xe, params["wi_up"])
    h = shard_constraint(jax.nn.silu(h) * u, rules,
                         "batch", "experts", None, "act_mlp")
    ye = jnp.einsum("becf,efd->becd", h, params["wo"])      # (B, E, C, d)
    # NOTE (§Perf iteration 5, refuted): forcing a reduce-scatter epilogue
    # here (d-sharded ye + combine while sharded + one all-gather) was
    # predicted to cut the capacity-expanded all-reduce ~2.5x, but GSPMD
    # inserts extra resharding around the sharded-d scatter-add and the
    # measured collective term ROSE 10.4s -> 14.2s.  Keeping XLA's
    # all-reduce placement.

    y_slots = ye.reshape(B, E * C, d) * slot_gate[..., None].astype(ye.dtype)

    def combine_one(ys, src):
        return jnp.zeros((S + 1, d), ys.dtype).at[src].add(ys)[:S]

    y = jax.vmap(combine_one)(y_slots, slot_src)
    y = shard_constraint(y, rules, "batch", None, "act_embed")
    return y.astype(x.dtype), aux
