"""Mamba-2 (SSD) mixer: conv frontend + selective state-space scan.

Faithful to the SSD parameterization (scalar decay per head, multi-head
state (N, P)); the chunked scan runs through the Pallas kernel on TPU and
its jnp oracle elsewhere.  Decode carries (conv window, ssm state) instead
of a KV cache — O(1) per step, which is why the hybrid/SSM archs are the
ones that run the long_500k shape.

Projections are kept separate (x, z, B, C, dt) rather than fused so each can
carry its own sharding: d_inner and the SSD head dim shard over 'model' (TP),
the small B/C/dt projections stay replicated.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ops import ssd
from repro.parallel.sharding import ShardingRules, shard_constraint

from .layers import _normal

__all__ = ["mamba_init", "mamba_apply", "mamba_init_cache"]

CONV_K = 4  # depthwise conv kernel width


def mamba_init(key, d_model, ssm_state, dtype, *, head_dim=64, expand=2):
    d_inner = expand * d_model
    H = d_inner // head_dim
    N = ssm_state
    ks = jax.random.split(key, 9)
    s = d_model ** -0.5
    p = {
        "w_x": _normal(ks[0], (d_model, d_inner), s, dtype),
        "w_z": _normal(ks[1], (d_model, d_inner), s, dtype),
        "w_b": _normal(ks[2], (d_model, N), s, dtype),
        "w_c": _normal(ks[3], (d_model, N), s, dtype),
        "w_dt": _normal(ks[4], (d_model, H), s, dtype),
        "conv_x": _normal(ks[5], (CONV_K, d_inner), 0.5, dtype),
        "conv_b": _normal(ks[6], (CONV_K, N), 0.5, dtype),
        "conv_c": _normal(ks[7], (CONV_K, N), 0.5, dtype),
        "a_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), jnp.float32),
        "w_out": _normal(ks[8], (d_inner, d_model), d_inner ** -0.5, dtype),
    }
    ax = {
        "w_x": ("embed", "act_mlp"),
        "w_z": ("embed", "act_mlp"),
        "w_b": ("embed", None),
        "w_c": ("embed", None),
        "w_dt": ("embed", "ssm_heads"),
        "conv_x": (None, "act_mlp"),
        "conv_b": (None, None),
        "conv_c": (None, None),
        "a_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "norm": ("act_mlp",),
        "w_out": ("act_mlp", "embed"),
    }
    return p, ax


def _causal_conv(x, w, state: Optional[jax.Array] = None):
    """Depthwise causal conv along seq.  x (B,S,D), w (K,D).

    state (B, K-1, D) holds the trailing inputs for decode; returns
    (y, new_state).  Long sequences use one depthwise conv op (single HBM
    round-trip — §Perf iteration 4); short/decode steps use shifted adds
    (cheaper than conv setup for S ~ 1)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, D)
    if x.shape[1] >= 32:
        y = jax.lax.conv_general_dilated(
            xp, w[:, None, :].astype(x.dtype),
            window_strides=(1,), padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=x.shape[2])
    else:
        y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
                for i in range(K))
    new_state = xp[:, -(K - 1):, :]
    return jax.nn.silu(y), new_state


def mamba_apply(params, x, meta, rules: ShardingRules, *,
                cache: Optional[dict] = None, use_pallas: bool = False,
                ssd_impl: str = "step"):
    """x (B, S, d_model) -> (B, S, d_model).  cache: {'conv_*', 'h'}."""
    B, S, _ = x.shape
    d_inner, H, N, P = meta["d_inner"], meta["H"], meta["N"], meta["P"]

    xs = jnp.einsum("bsd,de->bse", x, params["w_x"])
    xs = shard_constraint(xs, rules, "batch", None, "act_mlp")
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    b = jnp.einsum("bsd,dn->bsn", x, params["w_b"])
    c = jnp.einsum("bsd,dn->bsn", x, params["w_c"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_dt"])

    cs = cache if cache is not None else {}
    xs, ncx = _causal_conv(xs, params["conv_x"], cs.get("conv_x"))
    b, ncb = _causal_conv(b, params["conv_b"], cs.get("conv_b"))
    c, ncc = _causal_conv(c, params["conv_c"], cs.get("conv_c"))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # B,S,H
    a = -jnp.exp(params["a_log"])                      # (H,) < 0
    log_a = dt * a                                      # (B, S, H) <= 0

    xh = xs.reshape(B, S, H, P)
    xh = shard_constraint(xh, rules, "batch", None, "ssm_heads", None)
    xh_dt = xh * dt[..., None].astype(xh.dtype)        # dt-scaled input
    bh = jnp.broadcast_to(b[:, :, None, :], (B, S, H, N))
    ch = jnp.broadcast_to(c[:, :, None, :], (B, S, H, N))

    if cache is None:
        y = ssd(xh_dt, log_a, bh, ch, use_kernel=use_pallas,
                impl=ssd_impl)
        new_h = None  # training path does not export state
    else:
        # step recurrence for decode (S small)
        h = cache["h"]                                 # (B, H, N, P) fp32
        ys = []
        for t in range(S):
            at = jnp.exp(log_a[:, t])                  # (B, H)
            h = h * at[..., None, None] + jnp.einsum(
                "bhn,bhp->bhnp", bh[:, t].astype(jnp.float32),
                xh_dt[:, t].astype(jnp.float32))
            ys.append(jnp.einsum("bhn,bhnp->bhp",
                                 ch[:, t].astype(jnp.float32), h))
        y = jnp.stack(ys, axis=1).astype(x.dtype)      # (B, S, H, P)
        new_h = h

    y = y + params["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_inner)
    # gated RMS norm (fused custom-vjp norm — see layers.rms_norm)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), params["norm"], 1e-6)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    out = shard_constraint(out, rules, "batch", None, "act_embed")
    new_cache = (None if cache is None else
                 {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "h": new_h})
    return out, new_cache


def mamba_init_cache(batch, meta, dtype):
    return {
        "conv_x": jnp.zeros((batch, CONV_K - 1, meta["d_inner"]), dtype),
        "conv_b": jnp.zeros((batch, CONV_K - 1, meta["N"]), dtype),
        "conv_c": jnp.zeros((batch, CONV_K - 1, meta["N"]), dtype),
        "h": jnp.zeros(
            (batch, meta["H"], meta["N"], meta["P"]), jnp.float32),
    }
