"""Data pipeline: document stream -> packed fixed-length batches.

Variable-length documents are packed into fixed seq_len rows with the
paper's bin-packing machinery (FFD) — inputs of different sizes, bins of
capacity seq_len.  The iterator state is checkpointable (preemption-safe).
"""

from .pipeline import PackedLMDataset, packing_efficiency

__all__ = ["PackedLMDataset", "packing_efficiency"]
