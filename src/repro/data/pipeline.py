"""Packed LM dataset with FFD sequence packing (the paper applied to data).

Documents have different sizes; a training row is a reducer of capacity
seq_len.  FFD packing (repro.core.binpack) minimizes padding waste exactly
like the paper's bins minimize reducer waste; cross-document attention is
prevented with segment-aware loss masking (targets crossing a boundary are
masked).

State (epoch seed + cursor) is checkpointable; restoring reproduces the
exact stream (preemption-safe pipelines for FT).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.binpack import ffd

__all__ = ["PackedLMDataset", "packing_efficiency"]


@dataclasses.dataclass
class PackedLMDataset:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    doc_len_lognormal: tuple[float, float] = (5.5, 0.8)  # mean ~350 tokens
    docs_per_shot: int = 512
    pack: bool = True

    def __post_init__(self):
        self._emitted = 0

    # --------------------------------------------------------------- state
    def state(self) -> dict:
        """Checkpointable cursor: the stream is a pure function of
        (seed, batches emitted) — restore replays deterministically."""
        return {"seed": self.seed, "emitted": self._emitted}

    def restore(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self._emitted = int(state.get("emitted", state.get("cursor", 0)))

    # --------------------------------------------------------------- stream
    def _documents(self, shot: int) -> list[np.ndarray]:
        """Zipf-distributed tokens (learnable unigram structure: a model
        training on this stream shows a real CE drop below ln(V), unlike a
        uniform stream whose entropy is already the floor)."""
        rng = np.random.default_rng((self.seed, shot))
        mu, sigma = self.doc_len_lognormal
        lens = np.clip(rng.lognormal(mu, sigma, self.docs_per_shot).astype(
            np.int64), 8, self.seq_len)
        ranks = np.arange(1, self.vocab_size)
        p = 1.0 / (ranks + 20.0)
        p /= p.sum()
        return [(rng.choice(ranks, size=l, p=p)).astype(np.int32)
                for l in lens]

    def _pack_shot(self, docs: list[np.ndarray]):
        rows, segs = [], []
        if self.pack:
            bins = ffd([len(d) for d in docs], float(self.seq_len))
        else:
            bins = [[i] for i in range(len(docs))]
        for b in bins:
            row = np.zeros(self.seq_len, np.int32)
            seg = np.zeros(self.seq_len, np.int32)
            off = 0
            for s, i in enumerate(b):
                d = docs[i]
                row[off: off + len(d)] = d
                seg[off: off + len(d)] = s + 1
                off += len(d)
            rows.append(row)
            segs.append(seg)
        return rows, segs

    def __iter__(self) -> Iterator[dict]:
        rows_buf, segs_buf = [], []
        shot, skip = 0, self._emitted
        while True:
            while len(rows_buf) < self.batch_size:
                rows, segs = self._pack_shot(self._documents(shot))
                rows_buf.extend(rows)
                segs_buf.extend(segs)
                shot += 1
            rows = np.stack(rows_buf[: self.batch_size])
            segs = np.stack(segs_buf[: self.batch_size])
            rows_buf = rows_buf[self.batch_size:]
            segs_buf = segs_buf[self.batch_size:]
            if skip > 0:       # replaying up to the checkpointed cursor
                skip -= 1
                continue
            self._emitted += 1
            tokens = rows
            targets = np.roll(rows, -1, axis=1)
            # mask: next token must exist and stay within the same document
            same_seg = (segs == np.roll(segs, -1, axis=1)) & (segs > 0)
            same_seg[:, -1] = False
            yield {
                "tokens": tokens,
                "targets": targets,
                "mask": same_seg.astype(np.float32),
                "segments": segs,
            }


def packing_efficiency(batch) -> float:
    """Fraction of non-pad tokens in a batch (FFD vs naive comparison)."""
    return float((batch["segments"] > 0).mean())
