"""Bin-packing primitives used by every approximation algorithm in the paper.

First-Fit Decreasing (FFD) and Best-Fit Decreasing (BFD) give the classical
11/9 * OPT + O(1) guarantee the paper leans on (Theorem 10, 18, 26): every bin
except possibly one is at least half full, so ``#bins <= 2 * s / b`` for bin
size ``b`` and total weight ``s``.

``ffd``/``bfd`` are O(n log n): FFD finds the leftmost bin with enough space
by descending a max segment tree over bin spaces, BFD keeps the open-bin
spaces in a sorted list.  Both produce bit-identical bins to the textbook
O(n^2) scans (kept as ``ffd_reference``/``bfd_reference`` for tests and the
packing benchmark) — the planner's estimate phase packs once per candidate
bin size, so packing must not dominate planning time (see DESIGN.md,
"strategy registry").

``pack_prefix`` is the array-native formulation for million-input instances
(DESIGN.md "hierarchical planning"): next-fit decreasing over prefix sums.
One vectorized ``searchsorted`` finds, for every sorted position, where a
bin starting there would end; walking that jump table from position 0
yields the bin boundaries in O(#bins) steps — no per-item Python
iteration.  Adjacent bins always sum past capacity (else they would have
merged), which keeps the half-full count guarantee the paper's theorems
lean on (``#bins <= ceil(2s/b) + 1``), so the hierarchical planner's
composed gap ledger stays a provable constant.
"""

from __future__ import annotations

from bisect import bisect_left, insort
from typing import Sequence

import numpy as np

__all__ = [
    "ffd",
    "bfd",
    "pack",
    "pack_prefix",
    "prefix_bins",
    "num_bins_lower_bound",
    "ffd_reference",
    "bfd_reference",
]

_EPS = 1e-12


def _decreasing_order(weights: np.ndarray) -> np.ndarray:
    # stable sort for reproducibility
    return np.argsort(-weights, kind="stable")


def _check_fits(w: np.ndarray, bin_size: float) -> None:
    if np.any(w > bin_size + _EPS):
        bad = int(np.argmax(w))
        raise ValueError(
            f"item {bad} (w={w[bad]}) does not fit in bin of size {bin_size}")


def ffd(weights: Sequence[float], bin_size: float) -> list[list[int]]:
    """First-Fit Decreasing.  Returns bin -> list of item indices.

    Leftmost-fitting-bin queries run over a max segment tree in which every
    not-yet-opened bin reports a full ``bin_size`` of space, so "open a new
    bin" is the same query as "reuse an old one".  O(n log n) total.
    """
    w = np.asarray(weights, dtype=np.float64)
    _check_fits(w, bin_size)
    n = len(w)
    if n == 0:
        return []
    size = 1
    while size < n:
        size *= 2
    # tree[1] is the root; leaves are tree[size : size + n] (extra leaves
    # beyond n stay at -inf so they are never chosen).
    tree = np.full(2 * size, -np.inf)
    tree[size:size + n] = bin_size
    for node in range(size - 1, 0, -1):
        tree[node] = max(tree[2 * node], tree[2 * node + 1])
    bins: list[list[int]] = []
    for i in _decreasing_order(w):
        need = w[i] - _EPS
        node = 1
        while node < size:  # descend to the leftmost leaf with enough space
            node = 2 * node if tree[2 * node] >= need else 2 * node + 1
        b = node - size
        while b >= len(bins):
            bins.append([])
        bins[b].append(int(i))
        tree[node] -= w[i]
        node //= 2
        while node:
            tree[node] = max(tree[2 * node], tree[2 * node + 1])
            node //= 2
    return [b for b in bins if b]


def bfd(weights: Sequence[float], bin_size: float) -> list[list[int]]:
    """Best-Fit Decreasing: place each item into the fullest bin it fits.

    Open-bin spaces live in a sorted list of ``(space, bin_id)``; best fit is
    the first entry at least the item's size (ties resolve to the lowest bin
    id, matching the sequential scan).
    """
    w = np.asarray(weights, dtype=np.float64)
    _check_fits(w, bin_size)
    bins: list[list[int]] = []
    srt: list[tuple[float, int]] = []      # (space, bin_id), ascending
    for i in _decreasing_order(w):
        j = bisect_left(srt, (w[i] - _EPS, -1))
        if j == len(srt):
            bins.append([int(i)])
            insort(srt, (bin_size - w[i], len(bins) - 1))
        else:
            space, b = srt.pop(j)
            bins[b].append(int(i))
            insort(srt, (space - w[i], b))
    return bins


def ffd_reference(weights: Sequence[float],
                  bin_size: float) -> list[list[int]]:
    """Textbook O(n^2) FFD — oracle for testing the fast implementation."""
    w = np.asarray(weights, dtype=np.float64)
    _check_fits(w, bin_size)
    bins: list[list[int]] = []
    space: list[float] = []
    for i in _decreasing_order(w):
        placed = False
        for b in range(len(bins)):
            if w[i] <= space[b] + _EPS:
                bins[b].append(int(i))
                space[b] -= w[i]
                placed = True
                break
        if not placed:
            bins.append([int(i)])
            space.append(bin_size - w[i])
    return bins


def bfd_reference(weights: Sequence[float],
                  bin_size: float) -> list[list[int]]:
    """Textbook O(n^2) BFD — oracle for testing the fast implementation."""
    w = np.asarray(weights, dtype=np.float64)
    _check_fits(w, bin_size)
    bins: list[list[int]] = []
    space: list[float] = []
    for i in _decreasing_order(w):
        best, best_space = -1, np.inf
        for b in range(len(bins)):
            if w[i] <= space[b] + _EPS and space[b] < best_space:
                best, best_space = b, space[b]
        if best < 0:
            bins.append([int(i)])
            space.append(bin_size - w[i])
        else:
            bins[best].append(int(i))
            space[best] -= w[i]
    return bins


def pack_prefix(weights: Sequence[float], bin_size: float) -> np.ndarray:
    """Array-native sorted-prefix-sum packing: (n,) int64 bin assignment.

    Next-fit decreasing, vectorized.  With ``csum`` the inclusive prefix
    sums of the descending-sorted weights, a single ``searchsorted(csum,
    csum - w + b)`` computes for *every* sorted position the end of the bin
    that would start there; the actual bin boundaries are the orbit of
    position 0 under that jump table, O(#bins) trivially-cheap steps
    instead of FFD's inherently sequential per-item placement.  The first
    item of each bin did not fit in the previous bin, so adjacent bins sum
    past capacity and ``#bins <= ceil(2s / b) + 1`` — the same half-full
    guarantee behind Theorem 10's ``#bins <= 2s/b``.  A million weights
    pack in milliseconds where the segment-tree FFD takes seconds.

    Returns bin ids in original item order; ids are contiguous from 0 in
    descending-weight order.  Empirically FFD packs a few percent tighter;
    the hierarchical planner accounts for the difference in its
    ``gap_inner`` ledger term, which this construction provably bounds.
    """
    w = np.asarray(weights, dtype=np.float64)
    if w.size and bin_size <= 0:
        raise ValueError(f"bin_size must be positive, got {bin_size}")
    _check_fits(w, bin_size)
    n = len(w)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    order = _decreasing_order(w)
    ws = w[order]
    csum = np.cumsum(ws)
    cap = bin_size
    # float cumsum error can push a boundary item over capacity at very
    # large n; shave the measured overshoot off the working capacity and
    # re-split (overshoot is rounding noise, so this converges immediately)
    for _ in range(4):
        ends = np.searchsorted(csum, csum - ws + cap + _EPS, side="right")
        ends = np.maximum(ends, np.arange(1, n + 1))  # always make progress
        bounds = [0]
        pos = 0
        while pos < n:  # orbit walk: one step per *bin*, not per item
            pos = int(ends[pos])
            bounds.append(pos)
        counts = np.diff(np.asarray(bounds, dtype=np.int64))
        bin_of_sorted = np.repeat(
            np.arange(len(counts), dtype=np.int64), counts)
        over = float(np.bincount(bin_of_sorted, weights=ws).max()) - bin_size
        if over <= _EPS:
            break
        cap -= over
    else:  # pragma: no cover - float noise is orders below bin_size
        raise AssertionError("prefix pack failed to fit bins")
    bin_of = np.empty(n, dtype=np.int64)
    bin_of[order] = bin_of_sorted
    return bin_of


def prefix_bins(weights: Sequence[float], bin_size: float) -> list[list[int]]:
    """``pack_prefix`` in the bin -> item-ids format of ``ffd``/``bfd``."""
    w = np.asarray(weights, dtype=np.float64)
    bin_of = pack_prefix(w, bin_size)
    if bin_of.size == 0:
        return []
    order = _decreasing_order(w)
    sorted_bins = bin_of[order]
    cuts = np.flatnonzero(np.diff(sorted_bins)) + 1
    return [g.tolist() for g in np.split(order, cuts)]


def pack(weights: Sequence[float], bin_size: float,
         method: str = "ffd") -> list[list[int]]:
    if method == "ffd":
        return ffd(weights, bin_size)
    if method == "bfd":
        return bfd(weights, bin_size)
    if method == "prefix":
        return prefix_bins(weights, bin_size)
    if method == "best":
        a, b = ffd(weights, bin_size), bfd(weights, bin_size)
        return a if len(a) <= len(b) else b
    raise ValueError(method)


def num_bins_lower_bound(weights: Sequence[float], bin_size: float) -> int:
    s = float(np.sum(np.asarray(weights, dtype=np.float64)))
    return int(np.ceil(s / bin_size - 1e-12))
