"""Bin-packing primitives used by every approximation algorithm in the paper.

First-Fit Decreasing (FFD) and Best-Fit Decreasing (BFD) give the classical
11/9 * OPT + O(1) guarantee the paper leans on (Theorem 10, 18, 26): every bin
except possibly one is at least half full, so ``#bins <= 2 * s / b`` for bin
size ``b`` and total weight ``s``.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

__all__ = ["ffd", "bfd", "pack", "num_bins_lower_bound"]


def _decreasing_order(weights: np.ndarray) -> np.ndarray:
    # stable sort for reproducibility
    return np.argsort(-weights, kind="stable")


def ffd(weights: Sequence[float], bin_size: float) -> list[list[int]]:
    """First-Fit Decreasing.  Returns bin -> list of item indices."""
    w = np.asarray(weights, dtype=np.float64)
    if np.any(w > bin_size + 1e-12):
        bad = int(np.argmax(w))
        raise ValueError(
            f"item {bad} (w={w[bad]}) does not fit in bin of size {bin_size}")
    bins: list[list[int]] = []
    space: list[float] = []
    for i in _decreasing_order(w):
        placed = False
        for b in range(len(bins)):
            if w[i] <= space[b] + 1e-12:
                bins[b].append(int(i))
                space[b] -= w[i]
                placed = True
                break
        if not placed:
            bins.append([int(i)])
            space.append(bin_size - w[i])
    return bins


def bfd(weights: Sequence[float], bin_size: float) -> list[list[int]]:
    """Best-Fit Decreasing: place each item into the fullest bin it fits."""
    w = np.asarray(weights, dtype=np.float64)
    if np.any(w > bin_size + 1e-12):
        bad = int(np.argmax(w))
        raise ValueError(
            f"item {bad} (w={w[bad]}) does not fit in bin of size {bin_size}")
    bins: list[list[int]] = []
    space: list[float] = []
    for i in _decreasing_order(w):
        best, best_space = -1, np.inf
        for b in range(len(bins)):
            if w[i] <= space[b] + 1e-12 and space[b] < best_space:
                best, best_space = b, space[b]
        if best < 0:
            bins.append([int(i)])
            space.append(bin_size - w[i])
        else:
            bins[best].append(int(i))
            space[best] -= w[i]
    return bins


def pack(weights: Sequence[float], bin_size: float,
         method: str = "ffd") -> list[list[int]]:
    if method == "ffd":
        return ffd(weights, bin_size)
    if method == "bfd":
        return bfd(weights, bin_size)
    if method == "best":
        a, b = ffd(weights, bin_size), bfd(weights, bin_size)
        return a if len(a) <= len(b) else b
    raise ValueError(method)


def num_bins_lower_bound(weights: Sequence[float], bin_size: float) -> int:
    s = float(np.sum(np.asarray(weights, dtype=np.float64)))
    return int(np.ceil(s / bin_size - 1e-12))
