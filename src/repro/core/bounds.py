"""Lower bounds from the paper (Theorems 8, 9, 11, 25) and Table-1 upper
bounds, used by tests and the benchmark harness to validate the reproduction
against the paper's own claims.

``some_pairs_comm_lower_bound`` extends the replication-rate argument of
Afrati et al., "Upper and Lower Bounds on the Cost of a Map-Reduce
Computation", to an explicit required-pair set (Ullman & Ullman's some-pairs
problem).  The planner attaches the matching bound to every schema it
returns (``MappingSchema.lower_bound``) so plans self-report their
optimality gap.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "a2a_comm_lower_bound",
    "a2a_reducers_lower_bound",
    "a2a_binpack_comm_lower_bound",
    "a2a_unit_comm_lower_bound",
    "a2a_unit_reducers_lower_bound",
    "x2y_comm_lower_bound",
    "x2y_reducers_lower_bound",
    "some_pairs_comm_lower_bound",
    "a2a_k2_comm_upper_bound",
    "a2a_algk_comm_upper_bound",
    "x2y_comm_upper_bound",
    "big_input_comm_upper_bound",
]


def a2a_comm_lower_bound(weights, q: float) -> float:
    """Theorem 8: comm >= s^2 / q (valid when s >= q)."""
    s = float(np.sum(weights))
    return s * s / q if s >= q else s


def a2a_reducers_lower_bound(weights, q: float) -> float:
    """Theorem 8: reducers >= s^2 / q^2."""
    s = float(np.sum(weights))
    return max(1.0, s * s / (q * q))


def a2a_binpack_comm_lower_bound(weights, q: float, k: int) -> float:
    """Theorem 9: comm >= s * floor((sk/q - 1)/(k - 1)) for the bin-packing
    strategy with bins of size q/k."""
    s = float(np.sum(weights))
    x = s * k / q
    return s * np.floor((x - 1) / (k - 1)) if k > 1 else s


def a2a_unit_comm_lower_bound(m: int, q: int) -> int:
    """Theorem 11: m * floor((m-1)/(q-1)) for unit-size inputs."""
    return m * ((m - 1) // (q - 1)) if q > 1 else m

def a2a_unit_reducers_lower_bound(m: int, q: int) -> int:
    return (m // q) * ((m - 1) // (q - 1)) if q > 1 else 1


def x2y_comm_lower_bound(wx, wy, q: float) -> float:
    """Theorem 25: comm >= 2 * sum_x * sum_y / q."""
    sx, sy = float(np.sum(wx)), float(np.sum(wy))
    return 2.0 * sx * sy / q


def x2y_reducers_lower_bound(wx, wy, q: float) -> float:
    sx, sy = float(np.sum(wx)), float(np.sum(wy))
    return max(1.0, 2.0 * sx * sy / (q * q))


def some_pairs_comm_lower_bound(weights, q: float, pairs) -> float:
    """Replication-rate lower bound for an explicit required-pair set.

    Two arguments, take the max:

      * every input incident to >= 1 required pair ships at least once, so
        comm >= sum of incident weights;
      * a reducer holding inputs S with load L = sum_{i in S} w_i <= q
        covers pair products sum_{{i,j} in S} 2 w_i w_j <= L^2 <= q L.
        Summing over reducers, q * comm >= sum_{(i,j) in P} 2 w_i w_j,
        i.e. comm >= 2 * sum_P w_i w_j / q.  With P = all pairs this
        recovers Theorem 8 up to the diagonal term; with P = X x Y it is
        exactly Theorem 25.
    """
    w = np.asarray(weights, dtype=np.float64)
    p = np.asarray(list(pairs), dtype=np.int64).reshape(-1, 2)
    if p.size == 0:
        return 0.0
    incident = np.zeros(len(w), dtype=bool)
    incident[p.ravel()] = True
    lb_ship = float(np.sum(w[incident]))
    lb_pairs = 2.0 * float(np.sum(w[p[:, 0]] * w[p[:, 1]])) / q
    return max(lb_ship, lb_pairs)


# ------------------------------------------------------------------ upper
def a2a_k2_comm_upper_bound(weights, q: float) -> float:
    """Theorem 10 (k=2 bin packing): comm <= 4 s^2 / q."""
    s = float(np.sum(weights))
    return 4.0 * s * s / q


def a2a_algk_comm_upper_bound(weights, q: float, k: int) -> float:
    """Theorem 18 (Algorithms 1 and 2): comm <=
    (q / 2k) * ceil(sk/(q(k-1))) * (ceil(sk/(q(k-1))) - 1)."""
    s = float(np.sum(weights))
    g = np.ceil(s * k / (q * (k - 1)))
    return (q / (2.0 * k)) * g * (g - 1) if k > 1 else s


def x2y_comm_upper_bound(wx, wy, b: float) -> float:
    """Theorem 26: comm <= 4 sum_x sum_y / b for bin size b, q = 2b."""
    sx, sy = float(np.sum(wx)), float(np.sum(wy))
    return 4.0 * sx * sy / b


def big_input_comm_upper_bound(weights, q: float) -> float:
    """Theorem 24: comm <= (m-1) q + 4 s^2 / q when one input > q/2."""
    m = len(weights)
    s = float(np.sum(weights))
    return (m - 1) * q + 4.0 * s * s / q
