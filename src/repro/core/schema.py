"""Mapping-schema representation for the A2A / X2Y / some-pairs problems.

A *mapping schema* (Afrati, Dolev, Korach, Sharma, Ullman 2015) assigns a set
of inputs — each with a size ``w_i`` — to reducers of identical capacity ``q``
such that

  * the sum of input sizes at any reducer is at most ``q``;
  * every *required pair* of inputs meets at >= 1 reducer.

For the A2A problem the required pairs are all ``(i, j), i != j``.  For the
X2Y problem they are all ``(x, y), x in X, y in Y``.  For the some-pairs
problem (Ullman & Ullman, "Some Pairs Problems") they are an explicit subset.

The schema produced by the planners in this package is a two-level object:

  bins      — optional grouping step (bin packing).  ``bins[b]`` is the list
              of original input ids packed into bin ``b``.  When the planner
              works directly on inputs, bins are singletons.
  reducers  — ``reducers[r]`` is the list of *bin* ids assigned to reducer r.

``expand()`` flattens a schema to reducer -> original-input-ids, which is what
the JAX execution engine consumes and what ``validate()`` checks.

Every schema produced by the planners carries the paper's replication-rate
communication lower bound for its instance (``lower_bound``), so a plan
self-reports its optimality gap: ``optimality_gap()`` is measured
communication over the lower bound (1.0 = provably optimal).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = [
    "MappingSchema",
    "InfeasibleError",
    "communication_cost",
    "replication_vector",
]


class InfeasibleError(ValueError):
    """No mapping schema exists for the given instance (e.g. two inputs with
    ``w_i + w_j > q`` in A2A, or an input larger than ``q``)."""


@dataclass
class MappingSchema:
    """A concrete assignment of inputs to capacity-``q`` reducers."""

    weights: np.ndarray                  # (m,) float64 — original input sizes
    q: float                             # reducer capacity
    bins: list[list[int]]                # bin id -> original input ids
    reducers: list[list[int]]            # reducer id -> bin ids
    algorithm: str = "unknown"           # provenance tag for reporting
    meta: dict = field(default_factory=dict)
    lower_bound: Optional[float] = None  # paper's comm lower bound (Thm 8/25)

    # ---------------------------------------------------------------- helpers
    @property
    def m(self) -> int:
        return int(len(self.weights))

    @property
    def num_reducers(self) -> int:
        return len(self.reducers)

    def bin_weight(self, b: int) -> float:
        return float(sum(self.weights[i] for i in self.bins[b]))

    def expand(self) -> list[list[int]]:
        """reducer id -> sorted list of original input ids (deduplicated)."""
        out = []
        for red in self.reducers:
            ids: set[int] = set()
            for b in red:
                ids.update(self.bins[b])
            out.append(sorted(ids))
        return out

    # ------------------------------------------------------------------ costs
    def reducer_load(self, r: int) -> float:
        """Sum of original input sizes at reducer ``r`` (deduplicated)."""
        ids: set[int] = set()
        for b in self.reducers[r]:
            ids.update(self.bins[b])
        return float(sum(self.weights[i] for i in ids))

    def _bin_weights(self) -> np.ndarray:
        w = np.asarray(self.weights, dtype=np.float64)
        return np.array([float(np.sum(w[np.asarray(b, dtype=np.int64)]))
                         if len(b) else 0.0 for b in self.bins])

    def communication_cost(self) -> float:
        """Total bytes shipped map->reduce: sum of loads over reducers.

        Disjoint-bin schemas (the common case) are summed with one vectorized
        pass over the flattened reducer lists; overlapping-bin schemas
        (hybrid / big-input paths) deduplicate input ids per reducer.
        """
        if not self.reducers:
            return 0.0
        if not self.meta.get("bins_overlap", False):
            bw = self._bin_weights()
            flat = np.fromiter(
                itertools.chain.from_iterable(self.reducers),
                dtype=np.int64,
                count=sum(len(r) for r in self.reducers))
            return float(np.sum(bw[flat]))
        return float(sum(self.reducer_load(r)
                         for r in range(self.num_reducers)))

    def optimality_gap(self) -> Optional[float]:
        """communication_cost / lower_bound (>= 1.0); None when no bound
        was attached or the bound is degenerate."""
        if self.lower_bound is None or self.lower_bound <= 0.0:
            return None
        return self.communication_cost() / self.lower_bound

    def replication(self) -> np.ndarray:
        """(m,) number of reducers each original input is sent to."""
        rep = np.zeros(self.m, dtype=np.int64)
        for red in self.expand():
            for i in red:
                rep[i] += 1
        return rep

    def max_load(self) -> float:
        if not self.reducers:
            return 0.0
        return max(self.reducer_load(r) for r in range(self.num_reducers))

    # -------------------------------------------------------------- validation
    def validate(
        self,
        pairs: str = "a2a",
        x_ids: Optional[Sequence[int]] = None,
        y_ids: Optional[Sequence[int]] = None,
        required_pairs: Optional[Sequence[tuple[int, int]]] = None,
        strict_capacity: bool = True,
    ) -> None:
        """Raise AssertionError if the schema is not a valid mapping schema.

        pairs='a2a'   — every unordered pair of distinct inputs must meet.
        pairs='x2y'   — every (x, y) with x in x_ids, y in y_ids must meet.
        pairs='some'  — every pair in ``required_pairs`` must meet
                        (Ullman & Ullman's some-pairs problem).
        """
        m = self.m
        expanded = self.expand()
        # capacity
        if strict_capacity:
            for r in range(self.num_reducers):
                load = self.reducer_load(r)
                assert load <= self.q + 1e-9, (
                    f"reducer {r} overflows: load={load} > q={self.q} "
                    f"(algorithm={self.algorithm})"
                )
        # every input placed in >= 1 bin; duplicates only when the algorithm
        # declares overlapping packings (hybrid Alg 5, big-input path).  The
        # some-pairs planner may legitimately leave pair-free inputs unplaced
        # (meta['partial_cover']=True).
        seen = sorted(itertools.chain.from_iterable(self.bins))
        if not self.meta.get("bins_overlap", False):
            assert seen == sorted(set(seen)), "an input appears in two bins"
        if not self.meta.get("partial_cover", False):
            assert set(seen) == set(range(m)), (
                f"bins cover {len(set(seen))} of {m} inputs"
            )
        # pair coverage via boolean matrix (m is moderate in tests)
        met = np.zeros((m, m), dtype=bool)
        for ids in expanded:
            idx = np.asarray(ids, dtype=np.int64)
            met[np.ix_(idx, idx)] = True
        if pairs == "a2a":
            want = ~np.eye(m, dtype=bool)
            missing = np.argwhere(want & ~met)
            assert missing.size == 0, (
                f"{len(missing)} uncovered pairs, e.g. {missing[:5].tolist()} "
                f"(algorithm={self.algorithm}, m={m}, q={self.q})"
            )
        elif pairs == "x2y":
            assert x_ids is not None and y_ids is not None
            xs = np.asarray(list(x_ids), dtype=np.int64)
            ys = np.asarray(list(y_ids), dtype=np.int64)
            sub = met[np.ix_(xs, ys)]
            missing = np.argwhere(~sub)
            assert missing.size == 0, (
                f"{len(missing)} uncovered X2Y pairs "
                f"(algorithm={self.algorithm})"
            )
        elif pairs == "some":
            assert required_pairs is not None, \
                "pairs='some' needs required_pairs"
            bad = [(int(i), int(j)) for i, j in required_pairs
                   if not met[int(i), int(j)]]
            assert not bad, (
                f"{len(bad)} uncovered required pairs, e.g. {bad[:5]} "
                f"(algorithm={self.algorithm})"
            )
        else:  # pragma: no cover
            raise ValueError(pairs)

    # ------------------------------------------------------------ composition
    @staticmethod
    def concat(a: "MappingSchema", b: "MappingSchema") -> "MappingSchema":
        """Union of two schemas over the *same* input universe."""
        assert a.m == b.m and a.q == b.q
        nb = len(a.bins)
        bins = a.bins + b.bins
        reducers = a.reducers + [[x + nb for x in red] for red in b.reducers]
        return MappingSchema(
            weights=a.weights, q=a.q, bins=bins, reducers=reducers,
            algorithm=f"{a.algorithm}+{b.algorithm}",
            lower_bound=a.lower_bound,
        )


def communication_cost(schema: MappingSchema) -> float:
    return schema.communication_cost()


def replication_vector(schema: MappingSchema) -> np.ndarray:
    return schema.replication()
