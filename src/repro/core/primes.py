"""Small prime utilities for the AU-method family of constructions."""

from __future__ import annotations

__all__ = ["is_prime", "prev_prime", "next_prime"]


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n < 4:
        return True
    if n % 2 == 0:
        return False
    f = 3
    while f * f <= n:
        if n % f == 0:
            return False
        f += 2
    return True


def prev_prime(n: int) -> int:
    """Largest prime <= n (raises for n < 2)."""
    if n < 2:
        raise ValueError("no prime <= 1")
    while not is_prime(n):
        n -= 1
    return n


def next_prime(n: int) -> int:
    """Smallest prime >= n."""
    n = max(n, 2)
    while not is_prime(n):
        n += 1
    return n
