"""Core: mapping schemas for different-sized inputs in MapReduce.

Reproduces Afrati, Dolev, Korach, Sharma, Ullman — "Assignment Problems of
Different-Sized Inputs in MapReduce" (2015): A2A and X2Y mapping-schema
planners with capacity-q reducers, bin-packing approximations, the optimal
unit-size constructions (q=2, q=3, AU method + extensions), the hybrid and
big-input paths, plus the paper's lower/upper bounds for validation.

Public planner API
------------------
``plan_a2a(weights, q, method='auto')``
    All-pairs mapping schema.  ``method='auto'`` runs the strategy-registry
    portfolio: every applicable strategy is costed with an exact closed-form
    estimate and only the argmin winner is materialized.  Results are
    memoized in ``PLAN_CACHE`` by the (sorted-weights, q, method) profile.
``plan_x2y(wx, wy, q)``
    Bipartite (X-to-Y) mapping schema, Section 10.
``plan_some_pairs(weights, q, pairs)``
    Cover an explicit required-pair subset (Ullman & Ullman, "Some Pairs
    Problems"): dense instances fall back to the A2A portfolio, sparse ones
    pay only for the bin pairs that contain required pairs.
``plan_unit(n, k)``
    Unit-size scheduler: n identical items, integer capacity k.
``plan_a2a_materialized(weights, q)``
    The seed build-every-candidate portfolio, kept as the benchmark
    baseline and correctness oracle for the estimate-based planner.
``estimate_a2a(weights, q)``
    (strategy label, exact communication cost) without building a schema.
``naive_pairs(weights, q)``
    One reducer per pair — the worst-case baseline.

Every returned :class:`MappingSchema` carries ``lower_bound`` (the paper's
replication-rate communication lower bound for its instance) and reports
``optimality_gap()`` = measured cost / lower bound.

Extension points: ``strategies.register_unit_strategy`` and
``strategies.register_a2a_strategy`` add constructions that all planners
pick up automatically; ``PLAN_CACHE`` (a :class:`strategies.PlanCache`)
can be cleared or resized.

Supporting modules: ``unit_schemas`` (Sections 5-7 constructions),
``binpack`` (O(n log n) FFD/BFD), ``bounds`` (Theorems 8/9/11/25 + Table 1),
``exact`` (brute-force optima for tiny instances), ``primes``.
"""

from .binpack import bfd, ffd, pack, pack_prefix, prefix_bins
from .bounds import (
    a2a_algk_comm_upper_bound,
    a2a_binpack_comm_lower_bound,
    a2a_comm_lower_bound,
    a2a_k2_comm_upper_bound,
    a2a_reducers_lower_bound,
    a2a_unit_comm_lower_bound,
    a2a_unit_reducers_lower_bound,
    big_input_comm_upper_bound,
    some_pairs_comm_lower_bound,
    x2y_comm_lower_bound,
    x2y_comm_upper_bound,
    x2y_reducers_lower_bound,
)
from .hierarchy import (
    choose_grouping_factor,
    plan_a2a_hierarchical,
    sampled_pair_coverage,
)
from .planner import (
    PlanPartition,
    bucket_summary,
    compute_buckets,
    compute_rect_buckets,
    estimate_a2a,
    estimate_x2y,
    naive_pairs,
    partition_plan,
    plan_a2a,
    plan_a2a_materialized,
    plan_some_pairs,
    plan_unit,
    plan_x2y,
    reducer_work,
)
from .primes import is_prime, next_prime, prev_prime
from .schema import InfeasibleError, MappingSchema
from .strategies import (
    A2A_REGISTRY,
    PLAN_CACHE,
    PlanCache,
    UNIT_REGISTRY,
    register_a2a_strategy,
    register_unit_strategy,
)
from . import unit_schemas

__all__ = [
    "MappingSchema", "InfeasibleError",
    "plan_a2a", "plan_a2a_materialized", "plan_x2y", "plan_unit",
    "plan_some_pairs", "estimate_a2a", "estimate_x2y", "naive_pairs",
    "compute_buckets", "compute_rect_buckets", "bucket_summary",
    "PlanPartition", "partition_plan", "reducer_work",
    "PLAN_CACHE", "PlanCache",
    "UNIT_REGISTRY", "A2A_REGISTRY",
    "register_unit_strategy", "register_a2a_strategy",
    "ffd", "bfd", "pack", "pack_prefix", "prefix_bins",
    "plan_a2a_hierarchical", "choose_grouping_factor",
    "sampled_pair_coverage",
    "is_prime", "prev_prime", "next_prime",
    "unit_schemas",
    "a2a_comm_lower_bound", "a2a_reducers_lower_bound",
    "a2a_binpack_comm_lower_bound", "a2a_unit_comm_lower_bound",
    "a2a_unit_reducers_lower_bound", "a2a_k2_comm_upper_bound",
    "a2a_algk_comm_upper_bound", "big_input_comm_upper_bound",
    "x2y_comm_lower_bound", "x2y_comm_upper_bound",
    "x2y_reducers_lower_bound", "some_pairs_comm_lower_bound",
]
