"""Core: mapping schemas for different-sized inputs in MapReduce.

Reproduces Afrati, Dolev, Korach, Sharma, Ullman — "Assignment Problems of
Different-Sized Inputs in MapReduce" (2015): A2A and X2Y mapping-schema
planners with capacity-q reducers, bin-packing approximations, the optimal
unit-size constructions (q=2, q=3, AU method + extensions), the hybrid and
big-input paths, plus the paper's lower/upper bounds for validation.
"""

from .binpack import bfd, ffd, pack
from .bounds import (
    a2a_algk_comm_upper_bound,
    a2a_binpack_comm_lower_bound,
    a2a_comm_lower_bound,
    a2a_k2_comm_upper_bound,
    a2a_reducers_lower_bound,
    a2a_unit_comm_lower_bound,
    a2a_unit_reducers_lower_bound,
    big_input_comm_upper_bound,
    x2y_comm_lower_bound,
    x2y_comm_upper_bound,
    x2y_reducers_lower_bound,
)
from .planner import naive_pairs, plan_a2a, plan_unit, plan_x2y
from .primes import is_prime, next_prime, prev_prime
from .schema import InfeasibleError, MappingSchema
from . import unit_schemas

__all__ = [
    "MappingSchema", "InfeasibleError",
    "plan_a2a", "plan_x2y", "plan_unit", "naive_pairs",
    "ffd", "bfd", "pack",
    "is_prime", "prev_prime", "next_prime",
    "unit_schemas",
    "a2a_comm_lower_bound", "a2a_reducers_lower_bound",
    "a2a_binpack_comm_lower_bound", "a2a_unit_comm_lower_bound",
    "a2a_unit_reducers_lower_bound", "a2a_k2_comm_upper_bound",
    "a2a_algk_comm_upper_bound", "big_input_comm_upper_bound",
    "x2y_comm_lower_bound", "x2y_comm_upper_bound",
    "x2y_reducers_lower_bound",
]
