"""Strategy registry: estimate-all, build-one planning (DESIGN.md section 3).

The paper (Sections 4-10) picks one construction per case a priori.  The seed
planner generalized that to a portfolio — materialize *every* applicable
candidate schema and keep the argmin by measured communication cost — which
is strictly better but O(sum of schema sizes) per plan: at m = 10^4 inputs a
single k=2 candidate already has millions of reducers, so the portfolio
spends minutes building schemas it will throw away.

This module replaces materialization with *registered strategies*.  Each
strategy knows three things:

  applicable(...)  — can this construction serve the instance at all?
  estimate(...)    — the **exact** communication cost its ``build`` would
                     incur, in closed form over the bin-weight vector
                     (vectorized NumPy; no reducers are created);
  build(...)       — materialize the schema (invoked only for the winner).

The estimates are exact, not heuristic: every unit construction in the paper
replicates each item a number of times that depends only on (n, k) and the
item's position in the layout, so cost = sum_i w_i * rep_i collapses to a few
NumPy reductions (e.g. Algorithm 2 replicates every item exactly u_p - 1
times, the AU square exactly k + 1 times, Algorithm 4 exactly
(k+1)^(l-1) times).  ``method='auto'`` therefore returns the *same* schema
the materialize-everything portfolio would have chosen (or a cheaper one:
unit-strategy selection is weighted here, while the seed selected by
unweighted copy counts), at the cost of building exactly one schema.

Registries are extension points: ``register_unit_strategy`` /
``register_a2a_strategy`` add new constructions that ``plan_a2a``,
``plan_unit`` and ``plan_some_pairs`` pick up automatically.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.obs import EVENTS as _OBS_EVENTS
from repro.obs import REGISTRY as _OBS_REGISTRY

from . import unit_schemas as us
from .binpack import pack
from .primes import is_prime, prev_prime
from .schema import MappingSchema

__all__ = [
    "UnitStrategy",
    "A2AStrategy",
    "UNIT_REGISTRY",
    "A2A_REGISTRY",
    "register_unit_strategy",
    "register_a2a_strategy",
    "best_unit",
    "unit_estimates",
    "A2AProfile",
    "PlanCache",
    "PLAN_CACHE",
]


# ===========================================================================
# plan cache
# ===========================================================================
class PlanCache:
    """LRU cache keyed by the (sorted-weights, q, method) profile.

    Plans depend only on the weight *multiset*: the planner computes the
    schema in canonical (descending-weight) order and the cache stores that
    canonical schema, so permutations of the same weights hit the same entry
    and are remapped to the caller's input order in O(m).
    """

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self._store: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    @staticmethod
    def key(sorted_w: np.ndarray, q: float, method: str) -> tuple:
        return (sorted_w.tobytes(), float(q), method)

    def get(self, key: tuple):
        if key in self._store:
            self.hits += 1
            _OBS_REGISTRY.counter("cache.hits", cache="plan").inc()
            self._store.move_to_end(key)
            return self._store[key]
        self.misses += 1
        _OBS_REGISTRY.counter("cache.misses", cache="plan").inc()
        return None

    def put(self, key: tuple, value) -> None:
        self._store[key] = value
        self._store.move_to_end(key)
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)
            self.evictions += 1
            _OBS_REGISTRY.counter("cache.evictions", cache="plan").inc()
            _OBS_EVENTS.emit("cache_eviction", cache="plan")

    def invalidate(self, key: tuple) -> bool:
        """Drop one entry (the streaming gap-drift re-plan path: a serving
        stream that re-plans has permanently moved off its previous weight
        profile, so that profile's entry is dead weight in the LRU and would
        otherwise push live request-serving profiles out).  Returns whether
        the key was present.  Not counted as an eviction — ``evictions``
        tracks capacity pressure only."""
        if self._store.pop(key, None) is None:
            return False
        self.invalidations += 1
        _OBS_REGISTRY.counter("cache.invalidations", cache="plan").inc()
        return True

    def stats(self) -> dict:
        """Counter snapshot: hits / misses / capacity evictions / explicit
        invalidations, plus current size and cap."""
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "size": len(self._store), "maxsize": self.maxsize}

    def clear(self) -> None:
        self._store.clear()
        self.hits = self.misses = 0
        self.evictions = self.invalidations = 0

    def __len__(self) -> int:
        return len(self._store)


PLAN_CACHE = PlanCache()


# ===========================================================================
# unit-size strategies (items are bins; integer capacity k items per reducer)
# ===========================================================================
@dataclass(frozen=True)
class UnitStrategy:
    """A unit-size construction: n abstract items, capacity k per reducer.

    ``estimate(bw, k)`` must equal the weighted communication cost of the
    schema ``build(len(bw), k)`` produces, for every applicable (n, k) —
    this invariant is what lets the planner skip materialization, and it is
    enforced by tests/test_planner_registry.py.
    """

    name: str
    applicable: Callable[[int, int], bool]          # (n, k) -> bool
    estimate: Callable[[np.ndarray, int], float]    # (bin_weights, k) -> cost
    build: Callable[[int, int], list[list[int]]]    # (n, k) -> reducers


def _filter(reducers: list[list[int]], n: int) -> list[list[int]]:
    out = [[i for i in red if i < n] for red in reducers]
    return [r for r in out if len(r) >= 1]


# ------------------------------------------------------------- closed forms
def _even_layout(n: int, k: int) -> int:
    """Padded group count u_p of Algorithm 2; every item replicates u_p - 1
    times (each group meets every other group exactly once, empty padding
    groups included — a group paired with an empty one still ships)."""
    g = k // 2
    u = math.ceil(n / g)
    return u + (u % 2)


def _even_cost(bw: np.ndarray, k: int) -> float:
    n = len(bw)
    if n == 0:
        return 0.0
    if n <= k:
        return float(bw.sum())
    return float(bw.sum()) * (_even_layout(n, k) - 1)


def _odd_layout(n: int, k: int) -> tuple[int, int]:
    """(u_p, n_a) of Algorithm 1: set A = first n_a items in groups of
    (k-1)/2, set B = the rest, one B item broadcast per team."""
    g = (k - 1) // 2
    u = max(2, math.ceil((n + 1) / (g + 1)))
    while u * g + (u + (u % 2)) - 1 < n:
        u += 1
    u_p = u + (u % 2)
    return u_p, min(n, u * g)


def _odd_cost(bw: np.ndarray, k: int) -> float:
    n = len(bw)
    if n == 0:
        return 0.0
    if n <= k:
        return float(bw.sum())
    u_p, n_a = _odd_layout(n, k)
    # A items: once per team = u_p - 1; B item t: every pair of team t =
    # u_p / 2; plus the recursion that covers B x B.
    cost = float(bw[:n_a].sum()) * (u_p - 1)
    b = bw[n_a:]
    cost += float(b.sum()) * (u_p // 2)
    return cost + _odd_cost(b, k)


def _au_square_cost(bw: np.ndarray, k: int) -> float:
    # one appearance per team, k + 1 teams
    return float(bw.sum()) * (k + 1)


def _au_projective_cost(bw: np.ndarray, k: int) -> float:
    # p = k - 1: base items once per team (p + 1 = k); extension item t is in
    # the p reducers of team t plus the all-new reducer, also k total.
    return float(bw.sum()) * k


def _alg3_prime(n: int, k: int) -> Optional[int]:
    """The prime p <= k that us.alg3 selects for (n, k), or None."""
    cand = k
    while cand >= 2:
        cand = prev_prime(cand)
        l = k - cand
        if n <= cand * cand + l * (cand + 1):
            return cand
        cand -= 1
    return None


def _alg3_cost(bw: np.ndarray, k: int) -> float:
    n = len(bw)
    if n == 0:
        return 0.0
    p = _alg3_prime(n, k)
    assert p is not None, "estimate called on inapplicable alg3"
    n_a = min(n, p * p)
    cost = float(bw[:n_a].sum()) * (p + 1)      # AU square appearances
    b = bw[n_a:]
    cost += float(b.sum()) * p                  # broadcast to one team (p red)
    if len(b) > 1:                              # B x B recursion
        cost += _odd_cost(b, k) if k % 2 else _even_cost(b, k)
    return cost


def _alg4_level(n: int, k: int) -> int:
    return round(math.log(n, k)) if n > 1 else 0


def _alg4_cost(bw: np.ndarray, k: int) -> float:
    # every item replicates exactly (k+1)^(l-1) times in the assignment tree
    l = _alg4_level(len(bw), k)
    return float(bw.sum()) * (k + 1) ** (l - 1)


def _alg4_applicable(n: int, k: int) -> bool:
    if not is_prime(k):
        return False
    l = _alg4_level(n, k)
    return l >= 2 and k ** l == n and (k * (k + 1)) ** (l - 1) <= 200_000


def _single_build(n: int, k: int) -> list[list[int]]:
    return [list(range(n))]


UNIT_REGISTRY: list[UnitStrategy] = []


def register_unit_strategy(strategy: UnitStrategy) -> UnitStrategy:
    UNIT_REGISTRY.append(strategy)
    PLAN_CACHE.clear()      # cached plans predate the new strategy
    return strategy


# Registration order is the tie-break order (argmin is stable), mirroring the
# candidate order of the seed planner.
register_unit_strategy(UnitStrategy(
    "single",
    applicable=lambda n, k: n <= k,
    estimate=lambda bw, k: float(bw.sum()),
    build=_single_build,
))
register_unit_strategy(UnitStrategy(
    "alg_even",
    applicable=lambda n, k: k % 2 == 0,
    estimate=_even_cost,
    build=lambda n, k: us.alg_even(n, k),
))
register_unit_strategy(UnitStrategy(
    "alg_odd",
    applicable=lambda n, k: k % 2 == 1 and k >= 3,
    estimate=_odd_cost,
    build=lambda n, k: us.alg_odd(n, k),
))
register_unit_strategy(UnitStrategy(
    "au_square",
    applicable=lambda n, k: is_prime(k) and n <= k * k,
    estimate=_au_square_cost,
    build=lambda n, k: _filter(us.au_square(k, with_teams=True)[0], n),
))
register_unit_strategy(UnitStrategy(
    "au_projective",
    applicable=lambda n, k: is_prime(k - 1) and n <= (k - 1) ** 2 + k,
    estimate=_au_projective_cost,
    build=lambda n, k: _filter(us.au_projective(k - 1), n),
))
register_unit_strategy(UnitStrategy(
    "alg3",
    applicable=lambda n, k: _alg3_prime(n, k) is not None,
    estimate=_alg3_cost,
    build=lambda n, k: us.alg3(n, k),
))
register_unit_strategy(UnitStrategy(
    "alg4",
    applicable=_alg4_applicable,
    estimate=_alg4_cost,
    build=lambda n, k: us.alg4(n, k),
))


def unit_estimates(bw: np.ndarray, k: int,
                   method: str = "auto") -> list[tuple[UnitStrategy, float]]:
    """(strategy, exact cost) for every applicable unit strategy.

    The 'single' strategy short-circuits: when everything fits in one
    reducer nothing can beat shipping each item once.
    """
    bw = np.asarray(bw, dtype=np.float64)
    n = len(bw)
    assert k >= 2
    if n <= k:
        single = UNIT_REGISTRY[0]
        return [(single, single.estimate(bw, k))]
    out = []
    for strat in UNIT_REGISTRY:
        if strat.name == "single":
            continue
        if method not in ("auto", strat.name):
            continue
        if strat.applicable(n, k):
            out.append((strat, strat.estimate(bw, k)))
    if not out:
        # always-applicable parity fallback (mirrors the seed planner)
        name = "alg_even" if k % 2 == 0 else "alg_odd"
        strat = next(s for s in UNIT_REGISTRY if s.name == name)
        out.append((strat, strat.estimate(bw, k)))
    return out


def argmin_estimate(cands):
    """First candidate within float tolerance of the minimum estimate.

    Closed-form estimates of equal-cost schemas can differ in the last few
    ulps (different summation orders), so a plain ``min`` would break ties
    by noise; registration/k order is the intended tie-break.
    """
    best = min(c[1] for c in cands)
    tol = 1e-9 * max(1.0, abs(best))
    return next(c for c in cands if c[1] <= best + tol)


def best_unit(bw: np.ndarray, k: int,
              method: str = "auto") -> tuple[UnitStrategy, float]:
    """Argmin by estimated (= exact) weighted cost; stable on ties."""
    return argmin_estimate(unit_estimates(bw, k, method))


# ===========================================================================
# A2A strategies over different-sized inputs
# ===========================================================================
class A2AProfile:
    """Instance profile: weights + capacity, with memoized per-bin-size
    packings so estimate and build share one pack per candidate."""

    def __init__(self, weights: np.ndarray, q: float):
        self.w = np.asarray(weights, dtype=np.float64)
        self.q = float(q)
        self.m = len(self.w)
        self.s = float(np.sum(self.w))
        self.wmax = float(np.max(self.w)) if self.m else 0.0
        self._packs: dict[int, tuple[list[list[int]], np.ndarray]] = {}
        self._hybrid: Optional[tuple] = None

    @property
    def kmax(self) -> int:
        return max(2, min(int(self.q / max(self.wmax, 1e-12)), 64))

    def pack_k(self, k: int) -> tuple[list[list[int]], np.ndarray]:
        """FFD/BFD-best bins of size q/k and their weight vector."""
        if k not in self._packs:
            bins = pack(self.w, self.q / k, method="best")
            bw = np.array([float(np.sum(self.w[np.asarray(b)]))
                           for b in bins])
            self._packs[k] = (bins, bw)
        return self._packs[k]

    def hybrid_packs(self):
        """(big_bins, big_bw, med_bins, med_bw, small_bins, small_bw) of
        Algorithm 5: (q/3, q/2] inputs into q/2 bins; <= q/3 inputs into
        both q/2 and q/3 bins."""
        if self._hybrid is None:
            w, q = self.w, self.q
            a_ids = np.flatnonzero((w > q / 3 + 1e-12) & (w <= q / 2 + 1e-12))
            b_ids = np.flatnonzero(w <= q / 3 + 1e-12)

            def sub(ids, size):
                bins = [[int(ids[i]) for i in bn]
                        for bn in pack(w[ids], size, "best")]
                bw = np.array([float(np.sum(w[np.asarray(b)])) for b in bins])
                return bins, bw

            big = sub(a_ids, q / 2) if len(a_ids) else ([], np.empty(0))
            med = sub(b_ids, q / 2) if len(b_ids) else ([], np.empty(0))
            sml = sub(b_ids, q / 3) if len(b_ids) else ([], np.empty(0))
            self._hybrid = (a_ids, b_ids, *big, *med, *sml)
        return self._hybrid


class A2AStrategy:
    """Base: an entry in the A2A portfolio."""

    name: str = "abstract"

    def applicable(self, prof: A2AProfile) -> bool:  # pragma: no cover
        raise NotImplementedError

    def estimate(self, prof: A2AProfile) -> float:   # pragma: no cover
        raise NotImplementedError

    def build(self, prof: A2AProfile) -> MappingSchema:  # pragma: no cover
        raise NotImplementedError


class BinpackStrategy(A2AStrategy):
    """Sections 4.1 / 6 / 7: bins of size q/k, then the best unit scheduler
    (weighted argmin over the unit registry)."""

    def __init__(self, k: int, unit_method: str = "auto"):
        self.k = k
        self.unit_method = unit_method
        self.name = f"binpack-k{k}"

    def applicable(self, prof: A2AProfile) -> bool:
        return prof.wmax <= prof.q / self.k + 1e-12

    def estimate(self, prof: A2AProfile) -> float:
        _, bw = prof.pack_k(self.k)
        _, cost = best_unit(bw, self.k, self.unit_method)
        return cost

    def build(self, prof: A2AProfile) -> MappingSchema:
        bins, bw = prof.pack_k(self.k)
        strat, cost = best_unit(bw, self.k, self.unit_method)
        reducers = strat.build(len(bins), self.k)
        return MappingSchema(
            weights=prof.w, q=prof.q, bins=bins, reducers=reducers,
            algorithm=f"binpack-k{self.k}+{strat.name}",
            meta={"k": self.k, "bin_size": prof.q / self.k,
                  "num_bins": len(bins), "estimated_cost": cost},
        )


class HybridStrategy(A2AStrategy):
    """Algorithm 5 (Section 8): mixed big (q/3, q/2] and small (<= q/3)
    inputs; small inputs are packed twice (overlapping bins)."""

    name = "hybrid-alg5"

    def applicable(self, prof: A2AProfile) -> bool:
        w, q = prof.w, prof.q
        if prof.wmax > q / 2 + 1e-12:
            return False
        n_big = int(np.sum(w > q / 3 + 1e-12))
        return 0 < n_big < prof.m

    def estimate(self, prof: A2AProfile) -> float:
        (_, _, big_bins, big_bw, med_bins, med_bw,
         small_bins, small_bw) = prof.hybrid_packs()
        nb, nm = len(big_bins), len(med_bins)
        # step 2: big-bin pairs (lone big bin gets a solo reducer);
        # step 3: big x medium; step 4: unit scheduler on small bins.
        cost = float(big_bw.sum()) * (nb - 1 + nm)
        if nb == 1:
            cost += float(big_bw[0])
        cost += float(med_bw.sum()) * nb
        _, small_cost = best_unit(small_bw, 3)
        return cost + small_cost

    def build(self, prof: A2AProfile) -> MappingSchema:
        (_, _, big_bins, big_bw, med_bins, med_bw,
         small_bins, small_bw) = prof.hybrid_packs()
        bins = big_bins + med_bins + small_bins
        nb, nm = len(big_bins), len(med_bins)
        reducers: list[list[int]] = []
        for i in range(nb):
            for j in range(i + 1, nb):
                reducers.append([i, j])
        if nb == 1:
            reducers.append([0])
        for i in range(nb):
            for j in range(nm):
                reducers.append([i, nb + j])
        strat, _ = best_unit(small_bw, 3)
        off = nb + nm
        for red in strat.build(len(small_bins), 3):
            reducers.append([off + i for i in red])
        return MappingSchema(
            weights=prof.w, q=prof.q, bins=bins, reducers=reducers,
            algorithm="hybrid-alg5",
            meta={"bins_overlap": True, "big_bins": nb, "med_bins": nm,
                  "small_bins": len(small_bins)},
        )


A2A_REGISTRY: list[Callable[[A2AProfile], list[A2AStrategy]]] = []


def register_a2a_strategy(
        factory: Callable[[A2AProfile], list[A2AStrategy]]):
    """Register a factory: profile -> strategy instances to consider."""
    A2A_REGISTRY.append(factory)
    PLAN_CACHE.clear()      # cached plans predate the new strategy
    return factory


register_a2a_strategy(
    lambda prof: [BinpackStrategy(k) for k in range(2, prof.kmax + 1)])
register_a2a_strategy(lambda prof: [HybridStrategy()])


def a2a_portfolio(prof: A2AProfile) -> list[tuple[A2AStrategy, float]]:
    """(strategy, exact estimated cost) for every applicable strategy."""
    out = []
    for factory in A2A_REGISTRY:
        for strat in factory(prof):
            if strat.applicable(prof):
                out.append((strat, strat.estimate(prof)))
    return out
