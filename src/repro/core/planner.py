"""Planner: different-sized inputs -> mapping schema (paper Sections 4-10).

``plan_a2a`` is the main entry point.  It reproduces the paper's case
analysis:

  * one input with  q/2 < w < q            -> big-input path (Section 9)
  * all inputs <= q/k for some k >= 2      -> bin packing to bins of q/k,
    then a unit-size scheduler on the bins (Sections 4-7)
  * mixed profile around q/3 .. q/2        -> hybrid Algorithm 5 (Section 8)

Going beyond the paper, ``method='auto'`` runs a *portfolio*: it evaluates
every applicable strategy (all feasible k, every unit scheduler, the hybrid)
and returns the schema with the smallest actual communication cost.  The
paper picks one strategy per case a priori; measuring and taking the argmin
is strictly better and is one of our beyond-paper optimizations (it never
does worse than the paper's choice, which is always in the portfolio).

``plan_x2y`` implements Section 10 with a swept bin-size split.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from . import unit_schemas as us
from .binpack import pack
from .primes import is_prime, prev_prime
from .schema import InfeasibleError, MappingSchema

__all__ = ["plan_a2a", "plan_x2y", "plan_unit", "naive_pairs"]


# ---------------------------------------------------------------------------
# unit-size dispatcher (items are bins; capacity k items per reducer)
# ---------------------------------------------------------------------------
def plan_unit(n: int, k: int, method: str = "auto") -> tuple[list[list[int]], str]:
    """Best unit-size schema for n items, integer capacity k >= 2.

    Returns (reducers over range(n), algorithm-name).
    """
    assert k >= 2
    if n <= k:
        return [list(range(n))], "single"
    candidates: list[tuple[list[list[int]], str]] = []

    def consider(name: str, reds: Optional[list[list[int]]]):
        if reds is not None:
            candidates.append((reds, name))

    if method in ("auto", "alg_even") and k % 2 == 0:
        consider("alg_even", us.alg_even(n, k))
    if method in ("auto", "alg_odd") and k % 2 == 1 and k >= 3:
        consider("alg_odd", us.alg_odd(n, k))
    if method in ("auto", "au") and is_prime(k) and n <= k * k:
        reds, _ = us.au_square(k, with_teams=True)
        consider("au_square", _filter(reds, n))
    if method in ("auto", "au_projective") and is_prime(k - 1) \
            and n <= (k - 1) ** 2 + k:
        consider("au_projective", _filter(us.au_projective(k - 1), n))
    if method in ("auto", "alg3"):
        consider("alg3", us.alg3(n, k))
    if method in ("auto", "alg4") and is_prime(k):
        l = round(math.log(n, k)) if n > 1 else 0
        # only when exact power and the tree stays small
        if l >= 2 and k ** l == n and (k * (k + 1)) ** (l - 1) <= 200_000:
            consider("alg4", us.alg4(n, k))
    if not candidates:
        # always-applicable fallback
        if k % 2 == 0:
            consider("alg_even", us.alg_even(n, k))
        else:
            consider("alg_odd", us.alg_odd(n, k))
    # pick minimum total copies (= comm in the unit world)
    best = min(candidates, key=lambda c: sum(len(r) for r in c[0]))
    return best


def _filter(reducers: list[list[int]], n: int) -> list[list[int]]:
    out = [[i for i in red if i < n] for red in reducers]
    return [r for r in out if len(r) >= 1]


# ---------------------------------------------------------------------------
# A2A for different-sized inputs
# ---------------------------------------------------------------------------
def plan_a2a(weights: Sequence[float], q: float,
             method: str = "auto") -> MappingSchema:
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    if m == 0:
        return MappingSchema(w, q, [], [], algorithm="empty")
    if np.any(w > q + 1e-12):
        raise InfeasibleError("an input exceeds the reducer capacity")
    big = np.flatnonzero(w > q / 2 + 1e-12)
    if len(big) >= 2:
        raise InfeasibleError(
            "two inputs larger than q/2 cannot share a reducer")
    if float(np.sum(w)) <= q + 1e-12:
        # everything fits in one reducer
        return MappingSchema(
            w, q, [[i] for i in range(m)], [list(range(m))],
            algorithm="single")

    if len(big) == 1:
        return _plan_big_input(w, q, int(big[0]), method)

    if method == "auto":
        cands = [s for s in _candidate_schemas(w, q) if s is not None]
        assert cands, "portfolio produced no schema"
        return min(cands, key=lambda s: s.communication_cost())
    if method.startswith("binpack"):
        # e.g. 'binpack-k2', 'binpack-k3'
        k = int(method.split("k")[-1]) if "k" in method else 2
        s = _binpack_schema(w, q, k)
        if s is None:
            raise InfeasibleError(f"inputs too large for bins of q/{k}")
        return s
    if method == "hybrid":
        s = _hybrid_schema(w, q)
        if s is None:
            raise InfeasibleError("hybrid (Alg 5) inapplicable")
        return s
    raise ValueError(f"unknown method {method!r}")


def _candidate_schemas(w: np.ndarray, q: float):
    wmax = float(np.max(w))
    kmax = max(2, min(int(q / max(wmax, 1e-12)), 64))
    for k in range(2, kmax + 1):
        yield _binpack_schema(w, q, k)
    yield _hybrid_schema(w, q)


def _binpack_schema(w: np.ndarray, q: float, k: int) -> Optional[MappingSchema]:
    """Sections 4.1 / 6 / 7: bins of size q/k, then unit scheduler."""
    b = q / k
    if float(np.max(w)) > b + 1e-12:
        return None
    bins = pack(w, b, method="best")
    reducers, name = plan_unit(len(bins), k)
    return MappingSchema(
        weights=w, q=q, bins=bins, reducers=reducers,
        algorithm=f"binpack-k{k}+{name}",
        meta={"k": k, "bin_size": b, "num_bins": len(bins)},
    )


def _hybrid_schema(w: np.ndarray, q: float) -> Optional[MappingSchema]:
    """Algorithm 5 (Section 8): mixed big (q/3, q/2] and small (<= q/3).

    Small inputs get packed twice (medium q/2 bins and small q/3 bins), so
    bins overlap — meta['bins_overlap']=True.
    """
    a_ids = np.flatnonzero((w > q / 3 + 1e-12) & (w <= q / 2 + 1e-12))
    b_ids = np.flatnonzero(w <= q / 3 + 1e-12)
    if len(a_ids) + len(b_ids) != len(w):
        return None  # some input > q/2 — handled by big-input path
    if len(a_ids) == 0 or len(b_ids) == 0:
        return None  # degenerate: plain bin packing covers it
    big_bins = [[int(a_ids[i]) for i in bn]
                for bn in pack(w[a_ids], q / 2, "best")]
    med_bins = [[int(b_ids[i]) for i in bn]
                for bn in pack(w[b_ids], q / 2, "best")]
    small_bins = [[int(b_ids[i]) for i in bn]
                  for bn in pack(w[b_ids], q / 3, "best")]
    bins = big_bins + med_bins + small_bins
    nb, nm = len(big_bins), len(med_bins)
    reducers: list[list[int]] = []
    # step 2: all pairs of big bins
    for i in range(nb):
        for j in range(i + 1, nb):
            reducers.append([i, j])
    if nb == 1:
        # single big bin still pairs internally via itself? pairs inside one
        # bin never co-reduce otherwise; give it one reducer alone
        reducers.append([0])
    # step 3: big x medium
    for i in range(nb):
        for j in range(nm):
            reducers.append([i, nb + j])
    # step 4: all pairs of small bins, capacity 3 in the unit world
    sub, _ = plan_unit(len(small_bins), 3)
    off = nb + nm
    for red in sub:
        reducers.append([off + i for i in red])
    return MappingSchema(
        weights=w, q=q, bins=bins, reducers=reducers,
        algorithm="hybrid-alg5",
        meta={"bins_overlap": True, "big_bins": nb, "med_bins": nm,
              "small_bins": len(small_bins)},
    )


def _plan_big_input(w: np.ndarray, q: float, big: int,
                    method: str) -> MappingSchema:
    """Section 9: one input of size in (q/2, q)."""
    wb = float(w[big])
    rest = [i for i in range(len(w)) if i != big]
    rest_w = w[rest]
    if len(rest) and float(np.max(rest_w)) > q - wb + 1e-12:
        raise InfeasibleError(
            "an input cannot share a reducer with the big input")
    # (a) pair the big input with everything: bins of size q - w_big
    small_bins = [[rest[i] for i in bn]
                  for bn in pack(rest_w, q - wb, "best")]
    bins: list[list[int]] = [[big]] + small_bins
    reducers: list[list[int]] = [[0, 1 + b] for b in range(len(small_bins))]
    schema_a = MappingSchema(
        weights=w, q=q, bins=bins, reducers=reducers,
        algorithm="big-input-pairing", meta={"bins_overlap": True})
    # (b) all pairs among the small inputs: recurse on the sub-universe
    sub = plan_a2a(rest_w, q, method="auto" if method == "auto" else method)
    sub_bins = [[rest[i] for i in bn] for bn in sub.bins]
    schema_b = MappingSchema(
        weights=w, q=q, bins=sub_bins, reducers=sub.reducers,
        algorithm=f"rest:{sub.algorithm}", meta={"bins_overlap": True})
    out = MappingSchema.concat(schema_a, schema_b)
    out.algorithm = f"big-input+{sub.algorithm}"
    out.meta["bins_overlap"] = True
    return out


# ---------------------------------------------------------------------------
# X2Y (Section 10)
# ---------------------------------------------------------------------------
def plan_x2y(wx: Sequence[float], wy: Sequence[float], q: float,
             num_splits: int = 8) -> MappingSchema:
    """Bipartite schema: X ids are 0..m-1, Y ids are m..m+n-1.

    Paper: pack X into bins of size b, Y into bins of q - b, cross product.
    We sweep b over a small grid (the paper fixes b = max_x resp. q/2) and
    keep the cheapest — the paper's choices are grid points.
    """
    wx = np.asarray(wx, dtype=np.float64)
    wy = np.asarray(wy, dtype=np.float64)
    m, n = len(wx), len(wy)
    if m == 0 or n == 0:
        return MappingSchema(np.concatenate([wx, wy]), q, [], [],
                             algorithm="empty")
    max_x, max_y = float(np.max(wx)), float(np.max(wy))
    if max_x + max_y > q + 1e-12:
        raise InfeasibleError("largest X and Y inputs cannot co-reduce")
    w_all = np.concatenate([wx, wy])
    lo, hi = max_x, q - max_y
    grid = sorted({lo, hi, q / 2, *np.linspace(lo, hi, num_splits).tolist()})
    best: Optional[MappingSchema] = None
    for b in grid:
        if b < max_x - 1e-12 or q - b < max_y - 1e-12:
            continue
        xbins = pack(wx, b, "best")
        ybins = [[m + i for i in bn] for bn in pack(wy, q - b, "best")]
        bins = [list(bn) for bn in xbins] + ybins
        nx = len(xbins)
        reducers = [[i, nx + j] for i in range(nx) for j in range(len(ybins))]
        s = MappingSchema(
            weights=w_all, q=q, bins=bins, reducers=reducers,
            algorithm=f"x2y-binpack(b={b:.3g})",
            meta={"b": b, "x_bins": nx, "y_bins": len(ybins)})
        if best is None or s.communication_cost() < best.communication_cost():
            best = s
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# naive baseline: one reducer per pair (worst-case comm, used in benchmarks)
# ---------------------------------------------------------------------------
def naive_pairs(weights: Sequence[float], q: float) -> MappingSchema:
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    reducers = []
    for i in range(m):
        for j in range(i + 1, m):
            if w[i] + w[j] > q + 1e-12:
                raise InfeasibleError(f"pair ({i},{j}) exceeds q")
            reducers.append([i, j])
    return MappingSchema(w, q, [[i] for i in range(m)], reducers,
                         algorithm="naive-pairs")
