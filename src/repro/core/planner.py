"""Planner: different-sized inputs -> mapping schema (paper Sections 4-10).

``plan_a2a`` is the main entry point.  It reproduces the paper's case
analysis:

  * one input with  q/2 < w < q            -> big-input path (Section 9)
  * all inputs <= q/k for some k >= 2      -> bin packing to bins of q/k,
    then a unit-size scheduler on the bins (Sections 4-7)
  * mixed profile around q/3 .. q/2        -> hybrid Algorithm 5 (Section 8)

Going beyond the paper, ``method='auto'`` runs a *portfolio* over the
strategy registry (``repro.core.strategies``): every applicable strategy —
all feasible k, every unit scheduler, the hybrid — is *estimated* with its
exact closed-form cost, and only the argmin winner is built.  The paper
picks one strategy per case a priori; taking the argmin is strictly better
(the paper's choice is always in the portfolio), and estimate-all/build-one
makes it O(packing) instead of O(sum of candidate schema sizes) — see
``benchmarks/bench_planner.py`` for the speedup curve and
``plan_a2a_materialized`` for the measure-everything baseline it replaced.

``plan_x2y`` implements Section 10 with a swept bin-size split.
``plan_some_pairs`` covers an explicit required-pair subset (Ullman &
Ullman, "Some Pairs Problems"), reusing the same registry for its dense
fallback and exploiting sparsity otherwise.

Every schema returned by this module carries the matching replication-rate
communication lower bound (``schema.lower_bound``, from ``repro.core.bounds``)
so plans self-report their optimality gap.

Results are memoized in ``strategies.PLAN_CACHE`` keyed by the
(sorted-weights, q, method) profile; permutations of the same weight
multiset share one cache entry.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional, Sequence

import numpy as np

from .binpack import pack
from .bounds import (
    a2a_comm_lower_bound,
    some_pairs_comm_lower_bound,
    x2y_comm_lower_bound,
)
from .schema import InfeasibleError, MappingSchema
from .strategies import (
    A2AProfile,
    BinpackStrategy,
    HybridStrategy,
    PLAN_CACHE,
    PlanCache,
    a2a_portfolio,
    argmin_estimate,
    best_unit,
)

__all__ = [
    "plan_a2a",
    "plan_a2a_materialized",
    "plan_x2y",
    "plan_unit",
    "plan_some_pairs",
    "estimate_a2a",
    "estimate_x2y",
    "naive_pairs",
    "compute_buckets",
    "compute_rect_buckets",
    "bucket_summary",
    "PlanPartition",
    "partition_plan",
    "reducer_work",
]


# ---------------------------------------------------------------------------
# unit-size dispatcher (items are bins; capacity k items per reducer)
# ---------------------------------------------------------------------------
def plan_unit(n: int, k: int, method: str = "auto") -> tuple[list[list[int]], str]:
    """Best unit-size schema for n items, integer capacity k >= 2.

    Returns (reducers over range(n), algorithm-name).  Selection is the
    registry argmin over exact per-strategy costs — no candidate is built.
    """
    assert k >= 2
    if n <= 0:
        return [], "empty"
    if method == "au":          # historical alias
        method = "au_square"
    strat, _ = best_unit(np.ones(n), k, method)
    return strat.build(n, k), strat.name


# ---------------------------------------------------------------------------
# A2A for different-sized inputs
# ---------------------------------------------------------------------------
def _check_a2a_feasible(w: np.ndarray, q: float) -> np.ndarray:
    if np.any(w > q + 1e-12):
        raise InfeasibleError("an input exceeds the reducer capacity")
    big = np.flatnonzero(w > q / 2 + 1e-12)
    if len(big) >= 2:
        raise InfeasibleError(
            "two inputs larger than q/2 cannot share a reducer")
    return big


def plan_a2a(weights: Sequence[float], q: float, method: str = "auto",
             use_cache: bool = True) -> MappingSchema:
    """All-pairs mapping schema for different-sized inputs.

    Treat the returned schema as immutable: cache hits share their reducer
    lists with the ``PLAN_CACHE`` entry (copying them would defeat the O(m)
    hit path), so mutating ``schema.reducers``/``schema.bins`` in place
    would poison every future plan for the same weight profile.  Pass
    ``use_cache=False`` to get a schema with no shared state.
    """
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    if m == 0:
        return MappingSchema(w, q, [], [], algorithm="empty", lower_bound=0.0)
    _check_a2a_feasible(w, q)

    # canonicalize to descending weights: plans depend only on the weight
    # multiset, so permutations share one cache entry and one computation.
    order = np.argsort(-w, kind="stable")
    ws = w[order]
    key = PlanCache.key(ws, q, method)
    schema_s = PLAN_CACHE.get(key) if use_cache else None
    if schema_s is None:
        schema_s = _plan_a2a_sorted(ws, q, method, use_cache)
        if use_cache:
            PLAN_CACHE.put(key, schema_s)
    return _remap_schema(schema_s, order, w)


def _remap_schema(schema: MappingSchema, order: np.ndarray,
                  w: np.ndarray) -> MappingSchema:
    """Translate a canonical-order schema back to the caller's input ids.
    Reducer lists are shared with the cached schema — treat plans as
    immutable."""
    bins = [[int(order[i]) for i in b] for b in schema.bins]
    return MappingSchema(
        weights=w, q=schema.q, bins=bins, reducers=schema.reducers,
        algorithm=schema.algorithm, meta=dict(schema.meta),
        lower_bound=schema.lower_bound,
    )


def _plan_a2a_sorted(w: np.ndarray, q: float, method: str,
                     use_cache: bool) -> MappingSchema:
    """Plan for descending-sorted weights (canonical cache order)."""
    m = len(w)
    lb = a2a_comm_lower_bound(w, q)
    big = np.flatnonzero(w > q / 2 + 1e-12)
    if float(np.sum(w)) <= q + 1e-12:
        # everything fits in one reducer
        return MappingSchema(
            w, q, [[i] for i in range(m)], [list(range(m))],
            algorithm="single", lower_bound=lb)
    if len(big) == 1:
        out = _plan_big_input(w, q, int(big[0]), method, use_cache)
        out.lower_bound = lb
        return out

    prof = A2AProfile(w, q)
    if method == "auto":
        portfolio = a2a_portfolio(prof)
        assert portfolio, "portfolio produced no strategy"
        strat, est = argmin_estimate(portfolio)
        schema = strat.build(prof)
        schema.lower_bound = lb
        schema.meta["estimated_cost"] = est
        schema.meta["portfolio"] = {s.name: c for s, c in portfolio}
        return schema
    if method.startswith("binpack"):
        # e.g. 'binpack-k2', 'binpack-k3'
        k = int(method.split("k")[-1]) if "k" in method else 2
        strat = BinpackStrategy(k)
        if not strat.applicable(prof):
            raise InfeasibleError(f"inputs too large for bins of q/{k}")
        schema = strat.build(prof)
        schema.lower_bound = lb
        return schema
    if method == "hybrid":
        strat = HybridStrategy()
        if not strat.applicable(prof):
            raise InfeasibleError("hybrid (Alg 5) inapplicable")
        schema = strat.build(prof)
        schema.lower_bound = lb
        return schema
    raise ValueError(f"unknown method {method!r}")


def plan_a2a_materialized(weights: Sequence[float], q: float) -> MappingSchema:
    """The seed portfolio: materialize every applicable candidate schema and
    return the argmin by *measured* communication cost.

    Kept as the baseline for ``benchmarks/bench_planner.py`` and as the
    oracle the estimate-based ``plan_a2a(method='auto')`` is validated
    against: both must return schemas of identical cost.
    """
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    if m == 0:
        return MappingSchema(w, q, [], [], algorithm="empty", lower_bound=0.0)
    big = _check_a2a_feasible(w, q)
    lb = a2a_comm_lower_bound(w, q)
    if float(np.sum(w)) <= q + 1e-12:
        return MappingSchema(
            w, q, [[i] for i in range(m)], [list(range(m))],
            algorithm="single", lower_bound=lb)
    if len(big) == 1:
        out = _plan_big_input(w, q, int(big[0]), "auto", use_cache=False)
        out.lower_bound = lb
        return out
    prof = A2AProfile(w, q)
    cands = [strat.build(prof) for strat, _ in a2a_portfolio(prof)]
    assert cands, "portfolio produced no schema"
    out = min(cands, key=lambda s: s.communication_cost())
    out.lower_bound = lb
    return out


def estimate_a2a(weights: Sequence[float], q: float) -> tuple[str, float]:
    """(winning strategy label, exact cost) without building any schema.

    This is the planning fast path: it mirrors ``plan_a2a``'s dispatch
    (single reducer / big input / registry portfolio) but never materializes
    reducers, so it is safe to call on instances whose plan would have
    millions of them.
    """
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    if m == 0:
        return "empty", 0.0
    big = _check_a2a_feasible(w, q)
    s = float(np.sum(w))
    if s <= q + 1e-12:
        return "single", s
    if len(big) == 1:
        b = int(big[0])
        wb = float(w[b])
        rest_w = np.delete(w, b)
        if len(rest_w) and float(np.max(rest_w)) > q - wb + 1e-12:
            raise InfeasibleError(
                "an input cannot share a reducer with the big input")
        n_small = len(pack(rest_w, q - wb, "best"))
        s_rest = float(np.sum(rest_w))
        sub_name, sub_cost = estimate_a2a(rest_w, q)
        return (f"big-input+{sub_name}",
                wb * n_small + s_rest + sub_cost)
    prof = A2AProfile(w, q)
    portfolio = a2a_portfolio(prof)
    assert portfolio, "portfolio produced no strategy"
    strat, est = argmin_estimate(portfolio)
    return strat.name, est


def _plan_big_input(w: np.ndarray, q: float, big: int, method: str,
                    use_cache: bool = True) -> MappingSchema:
    """Section 9: one input of size in (q/2, q)."""
    wb = float(w[big])
    rest = [i for i in range(len(w)) if i != big]
    rest_w = w[rest]
    if len(rest) and float(np.max(rest_w)) > q - wb + 1e-12:
        raise InfeasibleError(
            "an input cannot share a reducer with the big input")
    # (a) pair the big input with everything: bins of size q - w_big
    small_bins = [[rest[i] for i in bn]
                  for bn in pack(rest_w, q - wb, "best")]
    bins: list[list[int]] = [[big]] + small_bins
    reducers: list[list[int]] = [[0, 1 + b] for b in range(len(small_bins))]
    schema_a = MappingSchema(
        weights=w, q=q, bins=bins, reducers=reducers,
        algorithm="big-input-pairing", meta={"bins_overlap": True})
    # (b) all pairs among the small inputs: recurse on the sub-universe
    sub = plan_a2a(rest_w, q, method="auto" if method == "auto" else method,
                   use_cache=use_cache)
    sub_bins = [[rest[i] for i in bn] for bn in sub.bins]
    schema_b = MappingSchema(
        weights=w, q=q, bins=sub_bins, reducers=sub.reducers,
        algorithm=f"rest:{sub.algorithm}", meta={"bins_overlap": True})
    out = MappingSchema.concat(schema_a, schema_b)
    out.algorithm = f"big-input+{sub.algorithm}"
    out.meta["bins_overlap"] = True
    return out


# ---------------------------------------------------------------------------
# some-pairs (Ullman & Ullman): cover an explicit subset of the pairs
# ---------------------------------------------------------------------------
def _normalize_pairs(m: int, pairs) -> np.ndarray:
    p = np.asarray(list(pairs), dtype=np.int64).reshape(-1, 2)
    if p.size == 0:
        return p
    if np.any(p < 0) or np.any(p >= m):
        raise ValueError("pair references an input id out of range")
    p = p[p[:, 0] != p[:, 1]]
    p = np.sort(p, axis=1)                       # unordered pairs
    return np.unique(p, axis=0)


def _sparse_layout(w: np.ndarray, q: float, p: np.ndarray):
    """Bins of q/2 over pair-incident inputs; a reducer per *needed* bin
    pair.  Returns (bins, cross, lone, cost): cross = distinct inter-bin
    pairs, lone = bins whose internal pairs are covered by no cross reducer.
    """
    incident = np.unique(p.ravel())
    sub_bins = pack(w[incident], q / 2.0, "best")
    bins = [[int(incident[i]) for i in bn] for bn in sub_bins]
    bin_of = np.full(len(w), -1, dtype=np.int64)
    for b, members in enumerate(bins):
        bin_of[members] = b
    pb = np.sort(np.stack([bin_of[p[:, 0]], bin_of[p[:, 1]]], axis=1), axis=1)
    nb = len(bins)
    bw = np.array([float(np.sum(w[np.asarray(b)])) for b in bins])
    codes = np.unique(pb[:, 0] * nb + pb[:, 1])
    b1, b2 = codes // nb, codes % nb
    inter = b1 != b2
    cross = np.stack([b1[inter], b2[inter]], axis=1)
    internal = b1[~inter]                        # bins with an internal pair
    covered = np.zeros(nb, dtype=bool)
    covered[cross.ravel()] = True
    lone = internal[~covered[internal]]
    cost = float(np.sum(bw[cross.ravel()])) + float(np.sum(bw[lone]))
    return bins, cross, lone, cost


def plan_some_pairs(weights: Sequence[float], q: float, pairs,
                    method: str = "auto") -> MappingSchema:
    """Mapping schema covering an explicit set of required pairs.

    The some-pairs problem (Ullman & Ullman) sits between A2A (all pairs
    required) and nothing: when the pair set is dense the A2A portfolio is
    the right tool, and when it is sparse a schema should only pay for the
    pairs that exist.  Three registered strategies, argmin by exact
    estimate, only the winner is built:

      'a2a'     — the full A2A registry portfolio (covers every pair);
      'sparse'  — bins of q/2 over pair-incident inputs, one reducer per
                  *needed* bin pair (inputs with no required pair are never
                  shipped: meta['partial_cover']=True);
      'pairs'   — one reducer per required pair (optimal for very sparse P).

    ``pairs`` is an iterable of (i, j) index pairs; order and duplicates are
    ignored.  The returned schema carries the replication-rate lower bound
    for the pair set (``some_pairs_comm_lower_bound``).
    """
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    p = _normalize_pairs(m, pairs)
    if m == 0 or len(p) == 0:
        return MappingSchema(w, q, [], [], algorithm="some-pairs-empty",
                             meta={"partial_cover": True}, lower_bound=0.0)
    pair_w = w[p[:, 0]] + w[p[:, 1]]
    if float(np.max(pair_w)) > q + 1e-12:
        i, j = p[int(np.argmax(pair_w))]
        raise InfeasibleError(f"required pair ({i},{j}) exceeds q")
    lb = some_pairs_comm_lower_bound(w, q, p)

    candidates: list[tuple[str, float]] = []
    sparse = None
    incident = np.unique(p.ravel())
    if method in ("auto", "sparse") and \
            float(np.max(w[incident])) <= q / 2.0 + 1e-12:
        sparse = _sparse_layout(w, q, p)
        candidates.append(("sparse", sparse[3]))
    if method in ("auto", "pairs"):
        candidates.append(("pairs", float(np.sum(pair_w))))
    if method in ("auto", "a2a"):
        try:
            _, a2a_cost = estimate_a2a(w, q)
            candidates.append(("a2a", a2a_cost))
        except InfeasibleError:
            pass
    if not candidates:
        raise InfeasibleError(f"no some-pairs strategy for method={method!r}")
    winner, est = min(candidates, key=lambda c: c[1])

    if winner == "a2a":
        schema = plan_a2a(w, q)
        schema.algorithm = f"some-pairs:a2a:{schema.algorithm}"
    elif winner == "sparse":
        bins, cross, lone, _ = sparse
        reducers = [[int(a), int(b)] for a, b in cross]
        reducers += [[int(b)] for b in lone]
        schema = MappingSchema(
            weights=w, q=q, bins=bins, reducers=reducers,
            algorithm="some-pairs:sparse-bins",
            meta={"partial_cover": True, "num_bins": len(bins)})
    else:  # 'pairs'
        incident_list = [int(i) for i in incident]
        bin_of = {i: b for b, i in enumerate(incident_list)}
        schema = MappingSchema(
            weights=w, q=q,
            bins=[[i] for i in incident_list],
            reducers=[[bin_of[int(i)], bin_of[int(j)]] for i, j in p],
            algorithm="some-pairs:pair-per-reducer",
            meta={"partial_cover": True})
    schema.lower_bound = lb
    schema.meta["required_pairs"] = int(len(p))
    schema.meta["estimated_cost"] = est
    schema.meta["portfolio"] = dict(candidates)
    return schema


# ---------------------------------------------------------------------------
# X2Y (Section 10)
# ---------------------------------------------------------------------------
def _x2y_grid(wx: np.ndarray, wy: np.ndarray, q: float,
              num_splits: int) -> list[float]:
    """Shared bin-size grid of the X2Y estimator and builder (identical by
    construction so ``estimate_x2y``'s winner is the schema ``plan_x2y``
    materializes)."""
    lo, hi = float(np.max(wx)), q - float(np.max(wy))
    return sorted({lo, hi, q / 2, *np.linspace(lo, hi, num_splits).tolist()})


def estimate_x2y(wx: Sequence[float], wy: Sequence[float], q: float,
                 num_splits: int = 8) -> tuple[float, float]:
    """Closed-form X2Y cost estimate: ``(best_b, best_cost)``.

    After packing X into ``nx`` bins of size ``b`` and Y into ``ny`` bins of
    ``q - b``, every X bin meets every Y bin, so the built schema ships
    exactly ``ny * sum(wx) + nx * sum(wy)`` — the estimate is *exact* (the
    same estimate-all/build-one contract as ``estimate_a2a``; enforced by
    ``tests/test_planner_registry.py``).  Packing is O(m log m) per grid
    point; the ``nx * ny`` reducer list is never materialized here.
    """
    wx = np.asarray(wx, dtype=np.float64)
    wy = np.asarray(wy, dtype=np.float64)
    if len(wx) == 0 or len(wy) == 0:
        return 0.0, 0.0
    max_x, max_y = float(np.max(wx)), float(np.max(wy))
    if max_x + max_y > q + 1e-12:
        raise InfeasibleError("largest X and Y inputs cannot co-reduce")
    sx, sy = float(wx.sum()), float(wy.sum())
    best_b, best_est = None, math.inf
    for b in _x2y_grid(wx, wy, q, num_splits):
        if b < max_x - 1e-12 or q - b < max_y - 1e-12:
            continue
        nx = len(pack(wx, b, "best"))
        ny = len(pack(wy, q - b, "best"))
        est = ny * sx + nx * sy
        if est < best_est:
            best_b, best_est = b, est
    assert best_b is not None
    return best_b, best_est


def plan_x2y(wx: Sequence[float], wy: Sequence[float], q: float,
             num_splits: int = 8) -> MappingSchema:
    """Bipartite schema: X ids are 0..m-1, Y ids are m..m+n-1.

    Paper: pack X into bins of size b, Y into bins of q - b, cross product.
    We sweep b over a small grid (the paper fixes b = max_x resp. q/2) and
    keep the cheapest — the paper's choices are grid points.  The sweep
    runs on ``estimate_x2y``'s closed-form costs; only the winning split is
    materialized, and ``meta['estimated_cost']`` records the estimate (==
    the built schema's measured cost).
    """
    wx = np.asarray(wx, dtype=np.float64)
    wy = np.asarray(wy, dtype=np.float64)
    m, n = len(wx), len(wy)
    if m == 0 or n == 0:
        return MappingSchema(np.concatenate([wx, wy]), q, [], [],
                             algorithm="empty", lower_bound=0.0)
    b, est = estimate_x2y(wx, wy, q, num_splits)
    w_all = np.concatenate([wx, wy])
    lb = x2y_comm_lower_bound(wx, wy, q)
    xbins = pack(wx, b, "best")
    ybins = [[m + i for i in bn] for bn in pack(wy, q - b, "best")]
    bins = [list(bn) for bn in xbins] + ybins
    nx = len(xbins)
    reducers = [[i, nx + j] for i in range(nx) for j in range(len(ybins))]
    return MappingSchema(
        weights=w_all, q=q, bins=bins, reducers=reducers,
        algorithm=f"x2y-binpack(b={b:.3g})",
        meta={"b": b, "x_bins": nx, "y_bins": len(ybins),
              "estimated_cost": est},
        lower_bound=lb)


# ---------------------------------------------------------------------------
# capacity buckets: group reducers by padded slot count (skew-aware shuffle)
# ---------------------------------------------------------------------------
def compute_buckets(slot_counts: Sequence[int], *, pad_slots_to: int = 1,
                    max_buckets: int = 8) -> list[tuple[int, np.ndarray]]:
    """Group reducers into a small number of capacity buckets.

    ``slot_counts[r]`` is the number of input slots at reducer ``r``.  A
    dense execution plan pads every reducer to ``max(slot_counts)`` — on a
    skewed schema (one heavy reducer, many light ones) that wastes
    memory and compute quadratically in the reducer function.  Instead,
    reducers are grouped by *bucket width*: the smallest
    ``pad_slots_to * 2^j`` (clamped to the dense width) that holds their
    slot count.  Each bucket is then executed as its own vmapped batch
    padded only to its own width.

    If more than ``max_buckets`` distinct widths appear, the narrowest
    buckets are merged upward (a reducer never lands in a bucket narrower
    than its slot count), keeping per-execution dispatch overhead bounded.

    Returns ``[(width, reducer_ids), ...]`` with widths ascending and
    ``reducer_ids`` the sorted original reducer indices of the bucket.
    Empty input -> empty list.
    """
    counts = np.asarray(list(slot_counts), dtype=np.int64)
    if counts.size == 0:
        return []
    assert pad_slots_to >= 1 and max_buckets >= 1
    dense_w = -(-max(int(counts.max()), 1) // pad_slots_to) * pad_slots_to
    # width(n) = pad_slots_to * 2^ceil(log2(n / pad_slots_to)), <= dense_w
    tiles = np.maximum(-(-counts // pad_slots_to), 1)
    widths = pad_slots_to * (
        2 ** np.ceil(np.log2(tiles)).astype(np.int64))
    widths = np.minimum(widths, dense_w)
    uniq = np.unique(widths)
    while len(uniq) > max_buckets:
        # merge the narrowest bucket into the next width up
        widths[widths == uniq[0]] = uniq[1]
        uniq = uniq[1:]
    return [(int(w), np.flatnonzero(widths == w)) for w in uniq]


def compute_rect_buckets(x_counts: Sequence[int], y_counts: Sequence[int],
                         *, pad_slots_to: int = 1,
                         max_buckets: int = 8
                         ) -> list[tuple[int, int, np.ndarray]]:
    """Rectangular capacity buckets: group reducers by (x-width, y-width).

    The rectangular analogue of :func:`compute_buckets` for X2Y plans:
    reducer ``r`` holds ``x_counts[r]`` X-side and ``y_counts[r]`` Y-side
    slots; each side is padded to the smallest ``pad_slots_to * 2^j``
    (clamped to its dense width) and reducers sharing a ``(wx, wy)`` pair
    execute as one vmapped batch.  When more than ``max_buckets`` distinct
    pairs appear, the two smallest-area pairs are merged into their
    component-wise max (a reducer never lands in a bucket narrower than its
    slot counts on either side).

    Returns ``[(wx, wy, reducer_ids), ...]`` ordered by ascending area with
    ``reducer_ids`` sorted original indices.  Empty input -> empty list.
    """
    xc = np.asarray(list(x_counts), dtype=np.int64)
    yc = np.asarray(list(y_counts), dtype=np.int64)
    assert xc.shape == yc.shape, (xc.shape, yc.shape)
    if xc.size == 0:
        return []
    assert pad_slots_to >= 1 and max_buckets >= 1

    def _side_widths(counts: np.ndarray) -> np.ndarray:
        dense = -(-max(int(counts.max()), 1) // pad_slots_to) * pad_slots_to
        tiles = np.maximum(-(-counts // pad_slots_to), 1)
        w = pad_slots_to * (2 ** np.ceil(np.log2(tiles)).astype(np.int64))
        return np.minimum(w, dense)

    wx = _side_widths(xc)
    wy = _side_widths(yc)
    pairs = {(int(a), int(b)) for a, b in zip(wx, wy)}
    while len(pairs) > max_buckets:
        by_area = sorted(pairs, key=lambda p: (p[0] * p[1], p))
        a, b = by_area[0], by_area[1]
        merged = (max(a[0], b[0]), max(a[1], b[1]))
        sel = ((wx == a[0]) & (wy == a[1])) | ((wx == b[0]) & (wy == b[1]))
        wx[sel], wy[sel] = merged
        pairs = (pairs - {a, b}) | {merged}
    out = [(px, py, np.flatnonzero((wx == px) & (wy == py)))
           for px, py in sorted(pairs, key=lambda p: (p[0] * p[1], p))]
    return out


def bucket_summary(schema: MappingSchema, *, pad_slots_to: int = 1,
                   max_buckets: int = 8) -> dict:
    """plan -> buckets telemetry: how much padding bucketing saves.

    Returns a dict with the dense padded-slot count (every reducer padded
    to the global max), the bucketed count (each reducer padded to its
    bucket width), the savings ratio, and a per-bucket breakdown — the
    numbers the serving dashboards and ``benchmarks/bench_engine.py``
    report.  Pure schema arithmetic; nothing is executed.
    """
    expanded = schema.expand()
    counts = [len(ids) for ids in expanded]
    buckets = compute_buckets(counts, pad_slots_to=pad_slots_to,
                              max_buckets=max_buckets)
    dense_w = -(-max(counts, default=1) // pad_slots_to) * pad_slots_to
    dense_slots = dense_w * len(expanded)
    rows = [{"width": w, "reducers": int(len(ids)),
             "padded_slots": int(w * len(ids)),
             "valid_slots": int(sum(counts[i] for i in ids))}
            for w, ids in buckets]
    bucketed_slots = sum(r["padded_slots"] for r in rows)
    return {
        "algorithm": schema.algorithm,
        "num_reducers": len(expanded),
        "dense_width": int(dense_w),
        "dense_padded_slots": int(dense_slots),
        "bucketed_padded_slots": int(bucketed_slots),
        "padding_savings": float(dense_slots / max(bucketed_slots, 1)),
        "buckets": rows,
    }


# ---------------------------------------------------------------------------
# shard partitioning: LPT balancing of reducers across a device mesh
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlanPartition:
    """LPT partition of a ReducerPlan's reducers over ``num_shards`` shards.

    shards        — per-shard *compact* sub-plans (same type as the input
                    plan; each holds only its own reducers' idx/mask rows
                    and re-grouped capacity buckets whose ``rows`` are
                    local to the sub-plan).
    shard_rows    — per-shard arrays of *global* plan-row ids (ascending);
                    the union over shards is exactly the real reducers,
                    each appearing once.
    widths        — (R0,) per-reducer execution width (bucket width, or the
                    dense L without buckets) — the padded gather cost.
    loads         — (S,) per-shard work in gather+FLOP units
                    (``sum(width + flop_weight * width^2)`` over the
                    shard's reducers).
    shipped_rows  — (S,) valid slots per shard: the shard's share of the
                    schema's shipped input copies (the paper's comm cost in
                    rows); sums to the plan's total valid slots.
    comm_cost     — (S,) the plan's weighted communication cost prorated by
                    shipped rows; sums to ``plan.comm_cost``.
    balance_factor — max(loads) / mean(loads) (1.0 = perfectly balanced;
                    inflated when num_shards > num_reducers since empty
                    shards drag the mean down).
    replication   — r: every reducer's sub-plan is *materialized* on r
                    shards (primary + r-1 LPT-chosen replicas).  The
                    primary assignment — and with it coverage, capacity,
                    ``shipped_rows`` and ``comm_cost`` — is byte-identical
                    to the r=1 partition; replication only adds holders.
    replica_rows  — per-shard sorted arrays of ALL global rows the shard
                    holds (primary ∪ replicas); every row appears on
                    exactly r shards, and shard s's array is a superset of
                    ``shard_rows[s]``.
    replica_loads — (S,) per-shard work including replicas (what the
                    coded executor's redundant compute actually costs).
    replica_slots — (S,) valid slots held per shard including replicas;
                    sums to exactly ``replication * sum(shipped_rows)``
                    (the replication ledger).
    """

    num_shards: int
    shards: tuple
    shard_rows: tuple
    widths: np.ndarray
    loads: np.ndarray
    shipped_rows: np.ndarray
    comm_cost: np.ndarray
    balance_factor: float
    flop_weight: float
    ywidths: Optional[np.ndarray] = None   # (R0,) Y-side widths (rect plans)
    replication: int = 1
    replica_rows: Optional[tuple] = None
    replica_loads: Optional[np.ndarray] = None
    replica_slots: Optional[np.ndarray] = None

    def report(self) -> dict:
        """Telemetry dict (benchmarks, dryrun, serving dashboards)."""
        rrows = (self.replica_rows if self.replica_rows is not None
                 else self.shard_rows)
        rloads = (self.replica_loads if self.replica_loads is not None
                  else self.loads)
        rslots = (self.replica_slots if self.replica_slots is not None
                  else self.shipped_rows)
        rmean = float(rloads.sum()) / max(self.num_shards, 1)
        return {
            "num_shards": self.num_shards,
            "reducers_per_shard": [int(len(r)) for r in self.shard_rows],
            "loads": [float(x) for x in self.loads],
            "shipped_rows": [int(x) for x in self.shipped_rows],
            "comm_cost": [float(x) for x in self.comm_cost],
            "balance_factor": float(self.balance_factor),
            "max_load": float(self.loads.max(initial=0.0)),
            "padded_elements_per_shard": [
                int(np.sum(self.widths[rows])) for rows in self.shard_rows],
            "replication": int(self.replication),
            "replica_reducers_per_shard": [int(len(r)) for r in rrows],
            "replica_slots": [int(x) for x in rslots],
            "replica_balance_factor": (
                float(rloads.max(initial=0.0)) / rmean if rmean > 0
                else 1.0),
        }


def reducer_work(plan, flop_weight: float = 1.0) -> np.ndarray:
    """(R0,) per-reducer work estimate: gather slots + Gram FLOPs, both at
    the reducer's *execution* width (its capacity-bucket width — what the
    bucketed/fused pipelines actually pad to), so the balance the LPT
    achieves is the balance the hardware sees.  Rectangular (X2Y) plans
    count both sides' gather slots and the cross block's ``wx * wy``
    FLOPs."""
    widths = _execution_widths(plan)
    w = widths.astype(np.float64)
    yw = _execution_ywidths(plan)
    if yw is not None:
        y = yw.astype(np.float64)
        return w + y + flop_weight * w * y
    return w + flop_weight * w * w


def _execution_widths(plan) -> np.ndarray:
    """Per-real-reducer execution width: bucket width where the plan has
    capacity buckets, the dense L otherwise.  (The X side of a rectangular
    plan.)"""
    R0 = int(plan.num_reducers)
    widths = np.full(R0, int(plan.L) if R0 else 0, dtype=np.int64)
    for b in getattr(plan, "buckets", ()) or ():
        rows = np.asarray(b.rows)
        real = rows[(rows >= 0) & (rows < R0)].astype(np.int64)
        widths[real] = int(b.width)
    return widths


def _execution_ywidths(plan) -> Optional[np.ndarray]:
    """Per-real-reducer Y-side execution width of a rectangular plan
    (bucket ``ywidth``, dense ``Ly`` fallback) — ``None`` for square
    plans."""
    if getattr(plan, "yidx", None) is None:
        return None
    R0 = int(plan.num_reducers)
    widths = np.full(R0, int(plan.yidx.shape[1]) if R0 else 0,
                     dtype=np.int64)
    for b in getattr(plan, "buckets", ()) or ():
        if getattr(b, "yidx", None) is None:
            continue
        rows = np.asarray(b.rows)
        real = rows[(rows >= 0) & (rows < R0)].astype(np.int64)
        widths[real] = int(b.ywidth)
    return widths


def partition_plan(plan, num_shards: int, *,
                   flop_weight: float = 1.0,
                   replication: int = 1) -> PlanPartition:
    """LPT/greedy balance of a ReducerPlan's reducers into per-shard
    compact sub-plans.

    Longest-processing-time-first: reducers sorted by descending work
    (``reducer_work``: per-reducer gather + FLOP cost at its bucket width)
    are assigned to the least-loaded shard.  Greedy guarantees
    ``max_load <= mean + (1 - 1/S) * max_work``, so the balance factor is
    bounded by ``1 + S * max_work / total_work`` — tight (→ 1.0) whenever
    reducers are plentiful relative to shards, which is exactly the regime
    the mesh runs in.

    Every *real* reducer (row < ``plan.num_reducers``) lands in exactly one
    shard with its idx/mask rows copied verbatim — coverage and reducer
    capacity are preserved by construction, and the per-shard
    ``shipped_rows``/``comm_cost`` shares sum to the plan's totals (the
    schema's communication cost is a cluster quantity; sharding only
    re-buckets it).  Works on any plan-shaped object exposing ``idx`` /
    ``mask`` / ``num_reducers`` / ``buckets``; sub-plans are built with
    ``type(plan)`` so this module stays free of engine imports.

    ``replication=r > 1`` additionally materializes every reducer on r-1
    *replica* shards (coded execution, after Afrati et al.'s
    replication-rate framing, arXiv:1206.4377): round by round, each
    reducer — heaviest first — is placed on the least replica-loaded
    shard not already holding it, so holder sets are nested across r
    (the r-replica holders contain the (r-1)-replica holders).  The
    primary assignment and every coverage/capacity/comm ledger above are
    *unchanged*; replication is accounted separately in ``replica_rows``
    / ``replica_loads`` / ``replica_slots`` and in ``report()``.
    """
    assert num_shards >= 1, num_shards
    replication = int(replication)
    assert 1 <= replication <= num_shards, (replication, num_shards)
    R0 = int(plan.num_reducers)
    widths = _execution_widths(plan)
    ywidths = _execution_ywidths(plan)
    work = reducer_work(plan, flop_weight)
    mask = np.asarray(plan.mask)
    slots = (mask[:R0].sum(axis=1).astype(np.int64) if R0
             else np.zeros(0, np.int64))
    if getattr(plan, "ymask", None) is not None and R0:
        slots = slots + np.asarray(plan.ymask)[:R0].sum(axis=1).astype(
            np.int64)
    total_slots = int(slots.sum())

    # LPT: stable sort by descending work, min-heap of (load, shard)
    order = np.argsort(-work, kind="stable")
    loads = np.zeros(num_shards, dtype=np.float64)
    assign: list[list[int]] = [[] for _ in range(num_shards)]
    heap = [(0.0, s) for s in range(num_shards)]
    heapq.heapify(heap)
    for r in order:
        load, s = heapq.heappop(heap)
        assign[s].append(int(r))
        load += float(work[r])
        loads[s] = load
        heapq.heappush(heap, (load, s))

    shard_rows = tuple(np.asarray(sorted(a), dtype=np.int64) for a in assign)
    shipped = np.array([int(slots[rows].sum()) for rows in shard_rows],
                       dtype=np.int64)
    comm = (shipped / max(total_slots, 1)) * float(plan.comm_cost)
    shards = tuple(_sub_plan(plan, rows, widths) for rows in shard_rows)
    total = float(work.sum())
    bf = (float(loads.max()) / (total / num_shards)) if total > 0 else 1.0

    # replica placement: nested LPT rounds over the replica-load tally
    held = np.zeros((num_shards, R0), dtype=bool)
    for s, rows in enumerate(shard_rows):
        held[s, rows] = True
    rloads = loads.copy()
    for _ in range(replication - 1):
        for r in order:
            cand = np.flatnonzero(~held[:, r])
            s = int(cand[np.argmin(rloads[cand])])
            held[s, r] = True
            rloads[s] += float(work[r])
    replica_rows = tuple(np.flatnonzero(held[s]).astype(np.int64)
                         for s in range(num_shards))
    replica_slots = np.array([int(slots[rows].sum())
                              for rows in replica_rows], dtype=np.int64)
    return PlanPartition(
        num_shards=num_shards, shards=shards, shard_rows=shard_rows,
        widths=widths, loads=loads, shipped_rows=shipped, comm_cost=comm,
        balance_factor=bf, flop_weight=flop_weight, ywidths=ywidths,
        replication=replication, replica_rows=replica_rows,
        replica_loads=rloads, replica_slots=replica_slots)


def _sub_plan(plan, rows: np.ndarray, widths: np.ndarray):
    """Compact sub-plan holding only ``rows`` (global plan-row ids).

    idx/mask rows are copied verbatim; capacity buckets are re-grouped from
    the parent's buckets with ``rows`` re-indexed to sub-plan-local ids, so
    the sub-plan is a self-consistent plan of the same type.  Rectangular
    plans carry their Y-side rows (``yidx`` / ``ymask`` / bucket
    ``ywidth``) through the same row selection."""
    idx = np.asarray(plan.idx)
    mask = np.asarray(plan.mask)
    rect = getattr(plan, "yidx", None) is not None
    n = len(rows)
    sub_idx = idx[rows] if n else np.zeros((0, idx.shape[1]), idx.dtype)
    sub_mask = mask[rows] if n else np.zeros((0, mask.shape[1]), mask.dtype)
    local = {int(g): i for i, g in enumerate(rows)}
    buckets = []
    for b in getattr(plan, "buckets", ()) or ():
        b_rows = np.asarray(b.rows)
        pos = np.flatnonzero(np.isin(b_rows, rows))      # bucket-local slots
        if not len(pos):
            continue
        sel = b_rows[pos].astype(np.int64)               # global row ids
        extra = {}
        if getattr(b, "yidx", None) is not None:
            extra = dict(ywidth=int(b.ywidth),
                         yidx=np.asarray(b.yidx)[pos],
                         ymask=np.asarray(b.ymask)[pos])
        buckets.append(type(b)(
            width=int(b.width),
            rows=np.asarray([local[int(g)] for g in sel], dtype=np.int64),
            idx=np.asarray(b.idx)[pos],
            mask=np.asarray(b.mask)[pos],
            **extra,
        ))
    max_inputs = int(sub_mask.sum(axis=1).max(initial=0))
    shipped = int(sub_mask.sum())
    total_slots = int(mask[:plan.num_reducers].sum())
    extra = {}
    if rect:
        ymask = np.asarray(plan.ymask)
        yidx = np.asarray(plan.yidx)
        sub_yidx = yidx[rows] if n else np.zeros((0, yidx.shape[1]),
                                                 yidx.dtype)
        sub_ymask = ymask[rows] if n else np.zeros((0, ymask.shape[1]),
                                                   ymask.dtype)
        shipped += int(sub_ymask.sum())
        total_slots += int(ymask[:plan.num_reducers].sum())
        extra = dict(yidx=sub_yidx, ymask=sub_ymask,
                     max_y_inputs=int(sub_ymask.sum(axis=1).max(initial=0)),
                     num_x=getattr(plan, "num_x", 0),
                     num_y=getattr(plan, "num_y", 0))
    share = shipped / max(total_slots, 1)
    return type(plan)(
        idx=sub_idx, mask=sub_mask, num_reducers=n,
        comm_cost=float(plan.comm_cost) * share,
        max_inputs=max_inputs, algorithm=plan.algorithm,
        lower_bound=None, buckets=tuple(buckets), **extra)


# ---------------------------------------------------------------------------
# naive baseline: one reducer per pair (worst-case comm, used in benchmarks)
# ---------------------------------------------------------------------------
def naive_pairs(weights: Sequence[float], q: float) -> MappingSchema:
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    reducers = []
    for i in range(m):
        for j in range(i + 1, m):
            if w[i] + w[j] > q + 1e-12:
                raise InfeasibleError(f"pair ({i},{j}) exceeds q")
            reducers.append([i, j])
    return MappingSchema(w, q, [[i] for i in range(m)], reducers,
                         algorithm="naive-pairs",
                         lower_bound=a2a_comm_lower_bound(w, q))
