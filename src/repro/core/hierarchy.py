"""Hierarchical (two-level) A2A planner for million-input instances.

The paper's bin-packing approximation (Theorem 9 / the Theorem 10
construction) packs the m inputs into bins of size ``q/2`` and pairs bins in
reducers.  At m = 10^6 the flat planner is sound but slow: packing, schema
construction and the portfolio all walk per-input Python structures.  The
hierarchical planner composes the packing *twice*:

  1. inner pack (``binpack.pack_prefix``, array-native): inputs -> super-
     inputs of size <= ``b = q / (2c)`` for a grouping factor ``c >= 1``;
  2. outer plan (``planner.plan_a2a``, the full strategy registry): the G
     super-input *weights* form a G-item A2A instance over the same
     capacity ``q``; G ~ thousands, so every existing strategy, estimate
     and cache applies unchanged.

Because the inner bins are disjoint, flattening the composition preserves
communication cost exactly and Theorem 8's lower bound ``s^2/q`` depends
only on the total weight ``s`` — which grouping preserves.  The optimality
gap therefore *composes multiplicatively*, and the planner surfaces the
ledger on the schema like every other plan in this repo:

  ``gap_inner``  = G / ceil(s / b)   — inner packing's bin-count gap
                   (<= 2 + o(1) by the prefix pack's half-full guarantee);
  ``gap_outer``  = outer cost / outer lower bound — the registry plan's
                   measured gap over the super weights;
  ``gap_total``  = gap_outer * gap_inner — a provable constant upper bound
                   on the composed schema's gap (the measured composed gap
                   equals ``gap_outer`` exactly; see DESIGN.md section 1h).

Composed plans are memoized in ``PLAN_CACHE`` under a method tag embedding
``c`` (``hier-c{c}|{method}``) so hierarchical entries never collide with
flat plans or with each other across grouping factors.  Unlike the flat
planner the key uses the literal weight order — remapping a million-entry
schema on every hit would cost more than planning.

``sampled_pair_coverage`` replaces ``MappingSchema.validate``'s dense
O(m^2) met-matrix at large m: it checks random required pairs against a
CSR bin -> reducers map, so conformance at m = 10^6 is O(samples).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from .binpack import pack_prefix
from .bounds import a2a_comm_lower_bound
from .planner import plan_a2a
from .schema import InfeasibleError, MappingSchema
from .strategies import PLAN_CACHE, PlanCache

__all__ = [
    "plan_a2a_hierarchical",
    "choose_grouping_factor",
    "sampled_pair_coverage",
]

_EPS = 1e-12


def choose_grouping_factor(weights: Sequence[float], q: float,
                           target_super: int = 4096) -> int:
    """Grouping factor c aiming for ~``target_super`` super-inputs.

    ``b = q/(2c)`` and the prefix pack yields G ~ s/b super-inputs, so
    ``c ~ q * target_super / (2s)``, clamped to ``[1, q / (2 * wmax)]`` so
    every input fits in a super-input bin.  Returns 0 when no grouping is
    possible (an input exceeds q/2 — the big-input path owns that case).
    """
    w = np.asarray(weights, dtype=np.float64)
    if w.size == 0:
        return 0
    wmax = float(np.max(w))
    s = float(np.sum(w))
    if wmax > q / 2 + _EPS or s <= 0:
        return 0
    cmax = int(q / (2.0 * wmax) + _EPS) if wmax > 0 else 2 ** 20
    if cmax < 1:
        return 0
    c = int(round(q * target_super / (2.0 * s))) if s > 0 else 1
    return max(1, min(c, cmax))


def plan_a2a_hierarchical(weights: Sequence[float], q: float, *,
                          c: Optional[int] = None, method: str = "auto",
                          use_cache: bool = True,
                          target_super: int = 4096) -> MappingSchema:
    """Two-level A2A plan: inner prefix pack to bins of ``q/(2c)``, outer
    registry plan over the super-input weights, flattened composition.

    ``c=None`` picks the grouping factor automatically (and falls back to
    the flat planner when grouping cannot help: a big input, or m already
    at most ``target_super``).  The returned schema's ``meta`` carries the
    composed ledger: ``c``, ``b``, ``num_super``, ``gap_inner``,
    ``gap_outer`` and ``gap_total = gap_outer * gap_inner``.

    Treat the result as immutable — cache hits share structure, exactly
    like ``plan_a2a``.
    """
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    if np.any(w > q + _EPS):
        raise InfeasibleError("an input exceeds the reducer capacity")
    if c is None:
        if m <= target_super:
            return plan_a2a(w, q, method, use_cache=use_cache)
        c = choose_grouping_factor(w, q, target_super)
        if c == 0:  # big input: grouping cannot host it, flat path owns it
            return plan_a2a(w, q, method, use_cache=use_cache)
    elif c < 1:
        raise ValueError(f"grouping factor must be >= 1, got {c}")
    b = q / (2.0 * c)
    if m and float(np.max(w)) > b + _EPS:
        raise InfeasibleError(
            f"an input exceeds the super-input size q/(2c) = {b}")

    hkey = PlanCache.key(w, q, f"hier-c{c}|{method}")
    if use_cache:
        cached = PLAN_CACHE.get(hkey)
        if cached is not None:
            return cached

    # inner: array-native pack into super-inputs of size <= b
    bin_of = pack_prefix(w, b)
    super_w = np.bincount(bin_of, weights=w)
    num_super = len(super_w)
    s = float(np.sum(w))
    inner_lb = max(1, int(math.ceil(s / b - _EPS))) if s > 0 else max(
        1, num_super)
    gap_inner = num_super / inner_lb

    # outer: the existing registry portfolio over the super weights
    outer = plan_a2a(super_w, q, method, use_cache=use_cache)
    gap_outer = outer.optimality_gap()
    if gap_outer is None:  # degenerate bound (s < q): cost == lower bound
        gap_outer = 1.0

    # compose: outer bins expand to original inputs; reducers carry over.
    # Inner CSR (inputs grouped by super id) built with one argsort; each
    # outer bin concatenates its supers' input slices — per-bin work only,
    # and overlapping outer bins (the hybrid path) stay overlapping.
    order = np.argsort(bin_of, kind="stable")
    indptr = np.zeros(num_super + 1, dtype=np.int64)
    np.cumsum(np.bincount(bin_of, minlength=num_super), out=indptr[1:])
    bins = []
    for outer_bin in outer.bins:
        parts = [order[indptr[sid]:indptr[sid + 1]] for sid in outer_bin]
        bins.append(np.concatenate(parts) if parts
                    else np.zeros(0, dtype=np.int64))

    meta = dict(outer.meta)
    meta.update(
        hierarchy={
            "c": int(c), "b": b, "num_super": int(num_super),
            "inner_bins_lb": int(inner_lb),
            "gap_inner": float(gap_inner),
            "gap_outer": float(gap_outer),
            "gap_total": float(gap_outer * gap_inner),
        },
        outer_algorithm=outer.algorithm,
    )
    schema = MappingSchema(
        weights=w, q=q, bins=bins, reducers=outer.reducers,
        algorithm=f"hier-c{c}+{outer.algorithm}", meta=meta,
        lower_bound=a2a_comm_lower_bound(w, q))
    if use_cache:
        PLAN_CACHE.put(hkey, schema)
    return schema


# ---------------------------------------------------------------------------
# sampled conformance: random required pairs, no dense met matrix
# ---------------------------------------------------------------------------
def _bin_of_inputs(schema: MappingSchema) -> np.ndarray:
    counts = np.asarray([len(b) for b in schema.bins], dtype=np.int64)
    flat = (np.concatenate([np.asarray(b, dtype=np.int64)
                            for b in schema.bins])
            if len(schema.bins) else np.zeros(0, dtype=np.int64))
    out = np.full(schema.m, -1, dtype=np.int64)
    out[flat] = np.repeat(np.arange(len(counts), dtype=np.int64), counts)
    return out


def _bin_reducers_csr(schema: MappingSchema):
    """CSR bin -> sorted reducer ids over the schema's reducer lists."""
    nb = len(schema.bins)
    pairs_b = (np.concatenate([np.asarray(r, dtype=np.int64)
                               for r in schema.reducers])
               if schema.reducers else np.zeros(0, dtype=np.int64))
    pairs_r = np.repeat(
        np.arange(len(schema.reducers), dtype=np.int64),
        np.asarray([len(r) for r in schema.reducers], dtype=np.int64))
    order = np.lexsort((pairs_r, pairs_b))
    indptr = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(np.bincount(pairs_b, minlength=nb), out=indptr[1:])
    return indptr, pairs_r[order]


def sampled_pair_coverage(schema: MappingSchema, num_samples: int = 2048,
                          seed: int = 0) -> float:
    """Fraction of sampled required pairs (i != j) that meet at a reducer.

    O(num_samples) once the CSR bin -> reducers map is built (O(m + A) for
    A total reducer assignments) — usable at m = 10^6 where ``validate()``'s
    dense met matrix would need 10^12 cells.  Requires disjoint bins (every
    planner schema except the overlapping hybrid/big-input paths, which are
    small enough for ``validate()``).
    """
    if schema.meta.get("bins_overlap", False):
        raise ValueError("sampled coverage requires disjoint bins")
    m = schema.m
    if m < 2:
        return 1.0
    bin_of = _bin_of_inputs(schema)
    indptr, red = _bin_reducers_csr(schema)
    rng = np.random.default_rng(seed)
    ii = rng.integers(0, m, size=num_samples)
    jj = rng.integers(0, m - 1, size=num_samples)
    jj = np.where(jj >= ii, jj + 1, jj)  # j != i, uniform over the rest
    hit = 0
    for i, j in zip(ii, jj):
        bi, bj = bin_of[i], bin_of[j]
        if bi < 0 or bj < 0:
            continue
        if bi == bj:
            hit += indptr[bi + 1] > indptr[bi]  # any reducer hosting the bin
            continue
        ri = red[indptr[bi]:indptr[bi + 1]]
        rj = red[indptr[bj]:indptr[bj + 1]]
        hit += np.intersect1d(ri, rj, assume_unique=False).size > 0
    return hit / num_samples
