"""Exact optimal mapping schemas for tiny instances (exhaustive search).

Used by tests/benchmarks to measure the planner's true approximation factor
on instances where the optimum is computable (m <= ~7).  Searches over the
number of reducers z = 1, 2, ...; for each z, assigns inputs to subsets via
depth-first search with capacity pruning, minimizing communication cost.
"""

from __future__ import annotations

import itertools
from typing import Optional

import numpy as np

from .schema import MappingSchema

__all__ = ["optimal_a2a_bruteforce"]


def optimal_a2a_bruteforce(weights, q: float,
                           max_reducers: int = 8) -> Optional[MappingSchema]:
    """Minimum-communication A2A schema by exhaustive subset search.

    Enumerates candidate reducers (subsets fitting in q), then searches for
    the cheapest cover of all pairs.  Exponential — tiny m only.
    """
    w = np.asarray(weights, dtype=np.float64)
    m = len(w)
    assert m <= 8, "brute force is exponential; use the planner"
    pairs = [(i, j) for i in range(m) for j in range(i + 1, m)]

    # candidate reducers: maximal feasible subsets (non-maximal subsets are
    # never better: adding an input to a feasible reducer only covers more
    # pairs at equal reducer count; cost ties are broken by the search)
    feasible = []
    for r in range(2, m + 1):
        for sub in itertools.combinations(range(m), r):
            if sum(w[i] for i in sub) <= q + 1e-12:
                feasible.append(frozenset(sub))
    maximal = [s for s in feasible
               if not any(s < t for t in feasible)]
    if not maximal:
        return None
    cost = {s: float(sum(w[i] for i in s)) for s in maximal}
    cover = {s: {p for p in pairs if p[0] in s and p[1] in s}
             for s in maximal}
    need = set(pairs)

    best: list[Optional[tuple]] = [None]

    def dfs(remaining, chosen, total):
        if best[0] is not None and total >= best[0][0] - 1e-12:
            return
        if not remaining:
            best[0] = (total, list(chosen))
            return
        # branch on an uncovered pair; try all reducers covering it
        p = min(remaining,
                key=lambda pp: sum(1 for s in maximal if pp in cover[s]))
        for s in maximal:
            if p in cover[s]:
                dfs(remaining - cover[s], chosen + [s], total + cost[s])

    dfs(need, [], 0.0)
    if best[0] is None:
        return None
    _, chosen = best[0]
    return MappingSchema(
        weights=w, q=q,
        bins=[[i] for i in range(m)],
        reducers=[sorted(s) for s in chosen],
        algorithm="bruteforce-optimal")
