"""Unit-size mapping-schema constructions (paper Sections 5-7).

Everything in this module works in the *unit-size world*: ``n`` abstract items
(in practice: bins produced by bin packing, treated as unit-size inputs) and an
integer reducer capacity ``k`` (number of items per reducer).  All functions
return ``list[list[int]]`` — reducer -> item ids in ``range(n)`` — plus, where
meaningful, the *team* structure the paper exploits (a team is a set of
reducers in which every item appears exactly once).

Implemented constructions:

  round_robin_teams   1-factorization of K_n (circle method) — the q=2
                      optimal schema of Section 5.1.  The paper's recursive
                      power-of-two construction yields the same object; the
                      circle method generalizes to every even n, which is the
                      "known techniques" generalization the paper alludes to.
  alg_even            Algorithm 2 (even k): group items into groups of k/2 and
                      take all pairs of groups via the team structure.
  alg_odd             Algorithm 1 (odd k >= 3): groups of (k-1)/2, pairs of
                      groups per reducer, one set-B item broadcast per team,
                      recursion on B.  k=3 reproduces Section 5.2 exactly.
  au_square           The AU method (Section 5.3): k prime, n = k^2,
                      k(k+1) reducers — meets the lower bound (optimal).
  au_projective       Extension: capacity k, k-1 prime, n = (k-1)^2 + k —
                      the projective-plane schema, optimal.
  alg3                First extension of the AU method (Section 7.1).
  alg4                Second extension (Section 7.2): n = k^l via the
                      bottom-up / assignment trees.
"""

from __future__ import annotations

import math
from typing import Optional

from .primes import is_prime, prev_prime

__all__ = [
    "round_robin_teams",
    "alg_even",
    "alg_odd",
    "au_square",
    "au_projective",
    "alg3",
    "alg4",
    "unit_lower_bound_reducers",
    "unit_lower_bound_comm",
]


# --------------------------------------------------------------------------
# lower bounds (Theorem 11, unit-size world)
# --------------------------------------------------------------------------
def unit_lower_bound_comm(n: int, k: int) -> int:
    """m * floor((m-1)/(q-1)) for unit inputs."""
    if n <= 1 or k <= 1:
        return n
    return n * ((n - 1) // (k - 1))


def unit_lower_bound_reducers(n: int, k: int) -> int:
    if n <= 1 or k <= 1:
        return 1
    return (n // k) * ((n - 1) // (k - 1))


# --------------------------------------------------------------------------
# q = 2: 1-factorization of K_n  (Section 5.1)
# --------------------------------------------------------------------------
def round_robin_teams(n: int) -> list[list[tuple[int, int]]]:
    """For even n: n-1 teams of n/2 disjoint pairs; every pair of items in
    range(n) appears in exactly one team (circle method)."""
    assert n % 2 == 0 and n >= 2, n
    others = list(range(1, n))
    teams = []
    for t in range(n - 1):
        rot = others[t:] + others[:t]
        pairs = [(0, rot[0])]
        for i in range(1, n // 2):
            pairs.append((rot[i], rot[n - 1 - i]))
        teams.append(pairs)
    return teams


# --------------------------------------------------------------------------
# Algorithm 2 — even capacity k  (Section 6)
# --------------------------------------------------------------------------
def alg_even(n: int, k: int, with_teams: bool = False):
    """All-pairs of n unit items with even reducer capacity k.

    Groups of k/2 items; every pair of groups shares one reducer.  Returns
    reducers (and optionally the team structure: team -> reducer ids)."""
    assert k >= 2 and k % 2 == 0
    if n <= 0:
        return ([], []) if with_teams else []
    if n <= k:
        reducers = [list(range(n))]
        return (reducers, [[0]]) if with_teams else reducers
    g = k // 2
    u = math.ceil(n / g)
    u_p = u + (u % 2)  # pad to even with empty groups
    groups = [list(range(i * g, min((i + 1) * g, n))) for i in range(u)]
    groups += [[] for _ in range(u_p - u)]
    reducers: list[list[int]] = []
    teams: list[list[int]] = []
    for pairs in round_robin_teams(u_p):
        team_rids = []
        for a, b in pairs:
            items = groups[a] + groups[b]
            if items:
                team_rids.append(len(reducers))
                reducers.append(items)
        teams.append(team_rids)
    return (reducers, teams) if with_teams else reducers


# --------------------------------------------------------------------------
# Algorithm 1 — odd capacity k >= 3  (Sections 5.2 and 6)
# --------------------------------------------------------------------------
def alg_odd(n: int, k: int) -> list[list[int]]:
    """All-pairs of n unit items with odd reducer capacity k >= 3.

    Pairs of (k-1)/2-item groups fill k-1 slots; the spare slot broadcasts one
    set-B item across a team; recurse on B.  k=3 is Section 5.2."""
    assert k >= 3 and k % 2 == 1
    if n <= 0:
        return []
    if n <= k:
        return [list(range(n))]
    g = (k - 1) // 2
    # smallest u with  u*g (set A) + (u_padded - 1) (set B) >= n
    u = max(2, math.ceil((n + 1) / (g + 1)))
    while u * g + (u + (u % 2)) - 1 < n:
        u += 1
    u_p = u + (u % 2)
    n_a = min(n, u * g)
    b_items = list(range(n_a, n))           # |B| <= u_p - 1
    assert len(b_items) <= u_p - 1
    groups = [list(range(i * g, min((i + 1) * g, n_a))) for i in range(u)]
    groups += [[] for _ in range(u_p - u)]
    reducers: list[list[int]] = []
    for t, pairs in enumerate(round_robin_teams(u_p)):
        extra = [b_items[t]] if t < len(b_items) else []
        for a, b in pairs:
            items = groups[a] + groups[b] + extra
            if items:
                reducers.append(items)
    # recurse for B x B pairs
    sub = alg_odd(len(b_items), k)
    for red in sub:
        reducers.append([b_items[i] for i in red])
    return reducers


# --------------------------------------------------------------------------
# AU method — k prime, n = k^2  (Section 5.3)
# --------------------------------------------------------------------------
def au_square(p: int, with_teams: bool = False):
    """Optimal schema for p^2 unit items, capacity p, p prime.

    Item (i, j) -> id i*p + j.  Team t in [0, p): reducer r holds cells with
    (i + t*j) mod p == r.  Team p: reducer r holds column r.  p+1 teams of p
    reducers; every team contains every item exactly once."""
    assert is_prime(p), p
    reducers: list[list[int]] = []
    teams: list[list[int]] = []
    for t in range(p):
        team_rids = []
        buckets: list[list[int]] = [[] for _ in range(p)]
        for i in range(p):
            for j in range(p):
                buckets[(i + t * j) % p].append(i * p + j)
        for r in range(p):
            team_rids.append(len(reducers))
            reducers.append(buckets[r])
        teams.append(team_rids)
    # column team
    team_rids = []
    for r in range(p):
        team_rids.append(len(reducers))
        reducers.append([i * p + r for i in range(p)])
    teams.append(team_rids)
    return (reducers, teams) if with_teams else reducers


def au_projective(p: int) -> list[list[int]]:
    """Optimal schema for n = p^2 + p + 1 items, capacity p + 1, p prime.

    AU square on the first p^2 items; new item p^2 + t joins every reducer of
    team t; one extra reducer holds all p + 1 new items."""
    assert is_prime(p), p
    base, teams = au_square(p, with_teams=True)
    reducers = [list(r) for r in base]
    new_ids = [p * p + t for t in range(p + 1)]
    for t, rids in enumerate(teams):
        for rid in rids:
            reducers[rid].append(new_ids[t])
    reducers.append(list(new_ids))
    return reducers


# --------------------------------------------------------------------------
# Algorithm 3 — first extension of the AU method  (Section 7.1)
# --------------------------------------------------------------------------
def alg3(n: int, k: int, p: Optional[int] = None) -> Optional[list[list[int]]]:
    """Capacity k, n items with p^2 < n <= p^2 + (k-p)(p+1), p prime <= k.

    AU square on A = first p^2 items (uses p of the k capacity); the k-p spare
    slots per reducer broadcast a group of B items per team; recursion on B.
    Returns None when no prime p <= k accommodates n."""
    if p is None:
        cand = k
        while cand >= 2:
            cand = prev_prime(cand)
            l = k - cand
            if n <= cand * cand + l * (cand + 1):
                p = cand
                break
            cand -= 1
        if p is None:
            return None
    l = k - p
    if n > p * p + l * (p + 1):
        return None
    # Pad A to p^2 with dummy ids >= n; caller-independent: we filter here.
    base, teams = au_square(p, with_teams=True)
    def real(ids):
        return [i for i in ids if i < n]
    reducers = [real(r) for r in base]
    b_items = list(range(p * p, n))  # x <= l*(p+1)
    # groups of <= l items, one group per team
    groups = [b_items[i * l:(i + 1) * l] for i in range(math.ceil(len(b_items) / max(l, 1)))] if l > 0 else []
    assert len(groups) <= p + 1
    for t, grp in enumerate(groups):
        for rid in teams[t]:
            reducers[rid].extend(grp)
    reducers = [r for r in reducers if r]
    # B x B pairs
    if len(b_items) > 1:
        sub = alg_odd(len(b_items), k) if k % 2 else alg_even(len(b_items), k)
        for red in sub:
            reducers.append([b_items[i] for i in red])
    return reducers


# --------------------------------------------------------------------------
# Algorithm 4 — second extension: n = k^l  (Section 7.2)
# --------------------------------------------------------------------------
def alg4(n: int, k: int) -> Optional[list[list[int]]]:
    """Capacity k prime, n = k^l (l >= 2): bottom-up tree + assignment tree.

    A *matrix* is a list of k^2 block ids, each block spanning ``size``
    consecutive items.  Applying the AU pattern to a matrix yields k(k+1)
    bins of k blocks; when size == 1 bins are reducers, otherwise each bin
    becomes a child matrix whose cells are the blocks' k children."""
    if not is_prime(k):
        return None
    l = round(math.log(n, k)) if n > 1 else 1
    if k ** l != n or l < 2:
        return None
    au = au_square(k)  # pattern over k^2 cell positions

    reducers: list[list[int]] = []

    def expand(matrix: list[int], size: int) -> None:
        # matrix: k^2 block ids at granularity `size`
        for bin_pos in au:
            blocks = [matrix[c] for c in bin_pos]
            if size == 1:
                reducers.append(blocks)
            else:
                child = []
                for b in blocks:
                    child.extend(b * k + j for j in range(k))
                expand(child, size // k)

    root_size = k ** (l - 2)
    expand(list(range(k * k)), root_size)
    return reducers
