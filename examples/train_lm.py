"""End-to-end driver: train a ~100M-param LM with the full stack —
FFD-packed data pipeline, logical-axis sharding, AdamW, checkpointing,
crash-safe resume.

Defaults are sized for this CPU container (--smoke trains a 3M model in
seconds).  The full ~110M config is `--preset 100m --steps 300`; on real
hardware the same script scales out by swapping make_local_mesh for
make_production_mesh.

Run:  PYTHONPATH=src python examples/train_lm.py --smoke
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ArchConfig
from repro.data import PackedLMDataset
from repro.launch.mesh import make_local_mesh
from repro.launch.rules import rules_for
from repro.models import RuntimeFlags, build_model
from repro.train import AdamWConfig, CheckpointManager, make_train_step
from repro.train.optimizer import adamw_init

PRESETS = {
    "smoke": ArchConfig(
        name="train-smoke", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=512,
        vocab_size=2048),
    "100m": ArchConfig(
        name="train-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()
    if args.smoke:
        args.preset = "smoke"

    cfg = PRESETS[args.preset]
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params)")

    mesh = make_local_mesh()
    flags = RuntimeFlags(param_dtype="float32", compute_dtype="float32",
                         remat="none")
    rules = rules_for(cfg, mesh, flags)
    model = build_model(cfg, flags, rules)

    opt_cfg = AdamWConfig(peak_lr=3e-4, warmup_steps=20,
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    ds = PackedLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch_size=args.batch, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    # crash-safe resume
    state, manifest = mgr.restore()
    if state is None:
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adamw_init(params, opt_cfg),
                 "step": jnp.zeros((), jnp.int32)}
        start = 0
    else:
        start = manifest["step"]
        ds.restore(manifest["extra"]["data"])
        print(f"resumed from step {start}")

    it = iter(ds)
    t_last, losses = time.perf_counter(), []
    with set_mesh(mesh):
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()
                     if k != "segments"}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % 5 == 0:
                dt = (time.perf_counter() - t_last) / 5
                t_last = time.perf_counter()
                print(f"step {step + 1:4d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"{dt * 1e3:.0f} ms/step")
            if (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state, extra={"data": ds.state()})
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
