"""Live-table similarity serving on the streaming executor.

A ``PairwiseService(executor="streaming")`` adopts a table once
(``load_table``) and then absorbs edits — ``add_input`` /
``remove_input`` / ``update_weight`` — without re-planning or
re-shuffling the world: the ``repro.stream`` subsystem repairs the
maintained mapping schema locally, recomputes only the reducers the edit
dirtied, and patches the cached (m, m) matrix.  This example drives an
edit stream and prints the per-edit telemetry the dashboards chart:

  * the recompute fraction (dirty reducers / total — the paper's
    communication per unit of useful work, made visible per edit);
  * the delta's shipped rows vs what a full re-shuffle would ship;
  * the optimality-gap drift that eventually triggers an amortized full
    re-plan through ``PLAN_CACHE``.

Run:  PYTHONPATH=src python examples/serve_stream.py
"""

import numpy as np

from repro.serve import PairwiseService

M, D, Q = 128, 32, 1.0


def main():
    rng = np.random.default_rng(0)
    svc = PairwiseService(q=Q, metric="dot", executor="streaming")

    x = rng.normal(size=(M, D)).astype(np.float32)
    w = np.clip(rng.zipf(1.6, M) / 32.0, 0.01, 0.30)
    sims, info = svc.load_table(x, w)
    print(f"cold build: [{info['algorithm']}] reducers={info['reducers']} "
          f"gap={info['optimality_gap']:.2f}x "
          f"wall={info['wall_s'] * 1e3:.0f}ms\n")

    print(f"{'edit':12s} {'id':>4s} {'dirty':>11s} {'frac':>6s} "
          f"{'delta/replan':>12s} {'drift':>6s} {'replan':>6s} {'wall':>9s}")
    for step in range(12):
        op = rng.choice(["add", "remove", "reweight"], p=[0.5, 0.3, 0.2])
        act = svc._planner.active_ids()
        if op == "add" or len(act) < 3:
            sims, info = svc.add_input(
                rng.normal(size=D).astype(np.float32),
                float(np.clip(rng.zipf(1.6) / 32.0, 0.01, 0.30)))
        elif op == "remove":
            sims, info = svc.remove_input(int(rng.choice(act)))
        else:
            sims, info = svc.update_weight(
                int(rng.choice(act)),
                float(np.clip(rng.zipf(1.6) / 32.0, 0.01, 0.30)))
        ratio = info["delta_comm_rows"] / max(info["comm_cost"], 1e-12)
        print(f"{info['kind']:12s} {info['input_id']:4d} "
              f"{info['dirty_reducers']:5d}/{info['num_reducers']:<5d} "
              f"{info['recompute_fraction']:6.3f} {ratio:12.4f} "
              f"{info['gap_drift']:6.3f} "
              f"{'yes' if info['full_replan'] else '-':>6s} "
              f"{info['wall_s'] * 1e3:7.1f}ms")

    agg = svc.stats
    print(f"\naggregate over {agg['edits']} edits: "
          f"{agg['dirty_reducers']} dirty reducers of "
          f"{agg['edit_reducers_total']} "
          f"({agg['dirty_reducers'] / max(agg['edit_reducers_total'], 1):.1%}"
          f" recomputed), {agg['stream_replans']} full re-plans, "
          f"wall {agg['wall_s'] * 1e3:.0f}ms")
    print(f"service executor counters: {svc.executor_stats()}")
    print(f"planner counters: {svc._planner.stats}")


if __name__ == "__main__":
    main()
