"""Quickstart: the common-friends problem (paper Example 1) end to end.

m people, each with a friend list of a different size; every pair must be
compared.  The planner builds a capacity-q mapping schema, the engine
executes it on JAX, and we check the result against brute force.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import plan_a2a
from repro.mapreduce import pairwise_similarity

M_PEOPLE = 40
N_UNIVERSE = 500        # ids that can appear in a friend list
Q = 1.0                 # reducer capacity (normalized bytes)


def main():
    rng = np.random.default_rng(0)
    # friend lists of very different sizes
    list_sizes = np.clip(rng.lognormal(3.0, 1.0, M_PEOPLE), 5, 400).astype(int)
    friends = [rng.choice(N_UNIVERSE, size=s, replace=False)
               for s in list_sizes]
    # input size w_i proportional to list length (normalized to q units)
    weights = list_sizes / list_sizes.max() * 0.4

    # multi-hot encode: common friends count = dot product
    x = np.zeros((M_PEOPLE, N_UNIVERSE), np.float32)
    for i, f in enumerate(friends):
        x[i, f] = 1.0

    schema = plan_a2a(weights, Q)
    schema.validate("a2a")
    print(f"planner chose      : {schema.algorithm}")
    print(f"reducers           : {schema.num_reducers}")
    print(f"communication cost : {schema.communication_cost():.2f} "
          f"(lower bound {schema.lower_bound:.2f}, "
          f"gap {schema.optimality_gap():.2f}x)")
    print(f"max replication    : {schema.replication().max()} copies")

    sims, plan, _ = pairwise_similarity(
        jnp.asarray(x), q=Q, weights=weights, schema=schema, metric="dot")

    # verify vs brute force
    ref = x @ x.T * (1 - np.eye(M_PEOPLE))
    np.testing.assert_allclose(np.asarray(sims), ref, rtol=1e-5, atol=1e-5)
    i, j = divmod(int(np.argmax(ref)), M_PEOPLE)
    print(f"most common friends: persons {i} & {j} share {int(ref[i, j])}")
    print("OK: schema-driven result == brute force")


if __name__ == "__main__":
    main()
