"""Pairwise similarity serving on the fused shuffle executor.

A ``PairwiseService`` answers all-pairs / some-pairs similarity queries
through planned mapping schemas.  This example drives it like a serving
loop and prints the per-request telemetry the dashboards chart:

  * which executor ran (and, for ``fused``, whether the Pallas megakernel,
    its streamed twin, or the bucketed fallback did the work);
  * whether the registry planner's ``PLAN_CACHE`` served the weight
    profile without re-planning (repeat profiles are O(m) cache hits);
  * the engine jit-cache counters (bounded LRU — long loops with fresh
    reducer closures evict instead of growing without limit).

Run:  PYTHONPATH=src python examples/serve_pairwise.py
"""

import numpy as np

from repro.serve import PairwiseService

M, D, Q = 96, 32, 1.0


def main():
    rng = np.random.default_rng(0)
    svc = PairwiseService(q=Q, metric="dot", executor="fused")

    # three weight profiles; profile A repeats, so requests 3+ hit the
    # plan cache and pay neither planning nor schema construction
    profiles = {
        "A-zipf": np.clip(rng.zipf(1.7, M) / 24.0, 0.02, 0.45),
        "B-uniform": rng.uniform(0.05, 0.33, M),
        "C-zipf": np.clip(rng.zipf(1.5, M) / 32.0, 0.02, 0.45),
    }
    requests = ["A-zipf", "B-uniform", "A-zipf", "C-zipf", "A-zipf"]

    print(f"{'req':>3s} {'profile':10s} {'executor':8s} {'path':9s} "
          f"{'plan-cache':>10s} {'algorithm':22s} {'reducers':>8s} "
          f"{'pad-save':>8s} {'jit h/m':>8s} {'wall':>8s}")
    for i, name in enumerate(requests):
        x = rng.normal(size=(M, D)).astype(np.float32)
        sims, info = svc.similarity(x, weights=profiles[name])
        jc = info["jit_cache"]
        print(f"{i:3d} {name:10s} {info['executor']:8s} "
              f"{info['fused_path'] or '-':9s} "
              f"{'hit' if info['plan_cache_hit'] else 'miss':>10s} "
              f"{info['algorithm']:22s} {info['reducers']:8d} "
              f"{info['padding_savings']:7.2f}x "
              f"{jc['hits']:4d}/{jc['misses']:<3d} "
              f"{info['wall_s'] * 1e3:6.1f}ms")

    # one some-pairs request rides the same fused path (X2Y workload)
    pairs = [(0, 1), (5, 17), (30, 31), (2, 64)]
    _, info = svc.some_pairs(rng.normal(size=(M, D)).astype(np.float32),
                             pairs, weights=profiles["B-uniform"])
    print(f"\nsome-pairs request: executor={info['executor']} "
          f"path={info['fused_path']} algorithm={info['algorithm']} "
          f"gap={info['optimality_gap']:.2f}x")

    agg = svc.stats
    print(f"\naggregate over {agg['requests']} requests: "
          f"{agg['plan_cache_hits']} plan-cache hits, "
          f"fused kernel/streamed/fallback = "
          f"{agg['fused_kernel']}/{agg['fused_streamed']}/"
          f"{agg['fused_fallbacks']}, "
          f"padding savings {svc.padding_savings:.2f}x, "
          f"wall {agg['wall_s'] * 1e3:.0f}ms")
    # the service holds its OWN executor instance — these counters are
    # scoped to this service, not shared module globals
    print(f"service executor counters: {svc.executor_stats()}")


if __name__ == "__main__":
    main()
