"""Batched serving example: prefill + decode with KV caches (ring buffers
on windowed layers), greedy sampling, per-step latency stats.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.rules import rules_for
from repro.models import RuntimeFlags, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b",
                    help="served as its reduced() smoke config on CPU")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    mesh = make_local_mesh()
    flags = RuntimeFlags(param_dtype="float32", compute_dtype="float32",
                         remat="none")
    rules = rules_for(cfg, mesh, flags)
    model = build_model(cfg, flags, rules)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    B = args.batch
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.new_tokens
    cache = model.init_cache(B, max_len)

    step = jax.jit(model.decode_step)

    # prefill token by token (teacher forcing into the cache), then decode
    t0 = time.perf_counter()
    tok = prompts[:, :1]
    for t in range(args.prompt_len):
        logits, cache = step(params, cache,
                             {"tokens": prompts[:, t:t + 1],
                              "pos": jnp.asarray(t, jnp.int32)})
    prefill_s = time.perf_counter() - t0

    out_tokens = []
    lat = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for t in range(args.prompt_len, max_len):
        t1 = time.perf_counter()
        logits, cache = step(params, cache,
                             {"tokens": tok, "pos": jnp.asarray(t, jnp.int32)})
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        lat.append(time.perf_counter() - t1)
        out_tokens.append(np.asarray(tok)[:, 0])

    gen = np.stack(out_tokens, axis=1)
    print(f"arch: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params, "
          f"{'ring-buffer SWA cache' if cfg.window else 'full KV cache'})")
    print(f"prefill: {args.prompt_len} tokens x {B} seqs in "
          f"{prefill_s * 1e3:.0f} ms")
    print(f"decode : {args.new_tokens} steps, median "
          f"{np.median(lat) * 1e3:.1f} ms/step, p99 "
          f"{np.quantile(lat, 0.99) * 1e3:.1f} ms")
    print(f"sample generation (batch 0): {gen[0][:16].tolist()} ...")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("OK")


if __name__ == "__main__":
    main()
