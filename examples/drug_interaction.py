"""The drug-interaction problem (paper Example 2, after Ullman'12).

Each drug carries a medical-history record of a *different size*; every
pair of drugs must meet at a reducer to test for interaction.  We sweep
reducer capacity q to expose the paper's central tradeoff: communication
cost vs parallelism (number of reducers).

Run:  PYTHONPATH=src python examples/drug_interaction.py [--drugs 120]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import a2a_comm_lower_bound, plan_a2a
from repro.mapreduce import build_plan, pairwise_similarity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--drugs", type=int, default=120)
    ap.add_argument("--dim", type=int, default=96)
    args = ap.parse_args()

    rng = np.random.default_rng(1)
    # record sizes are heavy-tailed (some drugs have long histories)
    sizes_mb = np.clip(rng.lognormal(1.2, 0.9, args.drugs), 0.2, 30.0)
    x = jnp.asarray(rng.normal(size=(args.drugs, args.dim)) / args.dim ** 0.5,
                    jnp.float32)

    print(f"{args.drugs} drugs, record sizes {sizes_mb.min():.1f}-"
          f"{sizes_mb.max():.1f} MB, total {sizes_mb.sum():.0f} MB")
    print(f"\n{'q (MB)':>8s} {'algorithm':34s} {'reducers':>8s} "
          f"{'comm (MB)':>10s} {'LB':>9s} {'c/LB':>5s} {'max load':>9s}")
    for q in (64.0, 96.0, 160.0, 320.0):
        schema = plan_a2a(sizes_mb, q)
        schema.validate("a2a")
        lb = a2a_comm_lower_bound(sizes_mb, q)
        print(f"{q:8.0f} {schema.algorithm:34s} {schema.num_reducers:8d} "
              f"{schema.communication_cost():10.1f} {lb:9.1f} "
              f"{schema.communication_cost() / lb:5.2f} "
              f"{schema.max_load():9.1f}")

    # execute the q=96 plan: interaction score = similarity of records
    schema = plan_a2a(sizes_mb, 96.0)
    sims, plan, _ = pairwise_similarity(
        x, q=96.0, weights=sizes_mb, schema=schema, metric="dot")
    flat = np.asarray(sims)
    i, j = divmod(int(np.argmax(flat)), args.drugs)
    print(f"\nstrongest interaction candidate: drugs {i} & {j} "
          f"(score {flat[i, j]:.3f}) — checked {args.drugs * (args.drugs - 1) // 2} pairs "
          f"on {plan.num_reducers} reducers")


if __name__ == "__main__":
    main()
