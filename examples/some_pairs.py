"""Some-pairs similarity: only flagged pairs must be compared.

Ullman & Ullman's some-pairs problem ("Some Pairs Problems"): instead of
comparing every pair of inputs (A2A), a blocking step — here a cheap
locality-sensitive signature — flags a subset of candidate pairs, and the
mapping schema only has to co-locate those.  The planner exploits the
sparsity: inputs with no flagged partner are never shipped, and the schema
self-reports its distance from the replication-rate lower bound.

Run:  PYTHONPATH=src python examples/some_pairs.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import plan_a2a, plan_some_pairs
from repro.mapreduce import some_pairs_similarity

M = 60
D = 128
Q = 1.0


def main():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(M, D)).astype(np.float32)
    weights = rng.uniform(0.02, 0.3, M)

    # blocking step: a single random hyperplane signature; only pairs on the
    # same side become candidates (any real blocker works the same way)
    sig = (x @ rng.normal(size=(D, 4)) > 0)
    pairs = [(i, j) for i in range(M) for j in range(i + 1, M)
             if np.all(sig[i] == sig[j])]
    print(f"blocking kept {len(pairs)} of {M * (M - 1) // 2} pairs")

    schema = plan_some_pairs(weights, Q, pairs)
    schema.validate("some", required_pairs=pairs)
    dense = plan_a2a(weights, Q)
    print(f"planner chose      : {schema.algorithm}")
    print(f"portfolio          : "
          f"{ {k: round(v, 1) for k, v in schema.meta['portfolio'].items()} }")
    print(f"communication cost : {schema.communication_cost():.2f} "
          f"(lower bound {schema.lower_bound:.2f}, "
          f"gap {schema.optimality_gap():.2f}x)")
    print(f"vs all-pairs plan  : {dense.communication_cost():.2f} "
          f"({dense.communication_cost() / schema.communication_cost():.1f}x "
          f"more traffic)")

    sims, plan, _ = some_pairs_similarity(
        jnp.asarray(x), pairs, q=Q, weights=weights, schema=schema)

    ref = x @ x.T
    for i, j in pairs:
        np.testing.assert_allclose(float(sims[i, j]), ref[i, j],
                                   rtol=1e-4, atol=1e-4)
    print(f"OK: all {len(pairs)} required similarities match brute force "
          f"(plan: {plan.algorithm}, gap {plan.optimality_gap:.2f}x)")


if __name__ == "__main__":
    main()
