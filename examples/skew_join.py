"""Skew join of X(A,B) ⋈ Y(B,C) on a heavy hitter (paper Example 3).

All tuples sharing the heavy-hitter B-value must pairwise meet.  The X2Y
planner packs the (different-sized) tuples into bins; each reducer joins
one X-bin with one Y-bin.

Run:  PYTHONPATH=src python examples/skew_join.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import plan_x2y, x2y_comm_lower_bound
from repro.mapreduce import skew_join


def main():
    rng = np.random.default_rng(2)
    mx, my = 180, 12          # heavy hitter: many X tuples, a few Y tuples
    # tuple payload sizes differ (wide vs narrow rows)
    wx = np.clip(rng.lognormal(-2.0, 0.6, mx), 0.01, 0.3)
    wy = np.clip(rng.lognormal(-1.2, 0.5, my), 0.05, 0.45)
    q = 1.0

    schema = plan_x2y(wx, wy, q)
    schema.validate("x2y", x_ids=range(mx), y_ids=range(mx, mx + my))
    lb = x2y_comm_lower_bound(wx, wy, q)
    print(f"heavy hitter join: |X|={mx}, |Y|={my}")
    print(f"schema             : {schema.algorithm}")
    print(f"reducers           : {schema.num_reducers} "
          f"(= x_bins {schema.meta['x_bins']} x y_bins {schema.meta['y_bins']})")
    print(f"communication cost : {schema.communication_cost():.2f} "
          f"(lower bound {lb:.2f}, ratio "
          f"{schema.communication_cost() / lb:.2f})")

    # execute: join payloads
    xv = jnp.asarray(rng.normal(size=(mx, 3)).astype(np.float32))
    yv = jnp.asarray(rng.normal(size=(my, 2)).astype(np.float32))
    out, _ = skew_join(xv, yv, q=q, wx=wx, wy=wy, schema=schema)
    assert out.shape == (mx, my, 5)
    # spot-check completeness of the join
    ok = np.allclose(np.asarray(out[17, 3, :3]), np.asarray(xv[17])) and \
        np.allclose(np.asarray(out[17, 3, 3:]), np.asarray(yv[3]))
    print(f"join output        : {out.shape} tuples; "
          f"spot check {'OK' if ok else 'FAILED'}")


if __name__ == "__main__":
    main()
