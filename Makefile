# Repo CI entry points (documented in README.md "Verify").
# The tier-1 command is `make test`; `make ci` adds the compileall lint pass.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test lint ci bench bench-quick

test:
	$(PYTHON) -m pytest -q

lint:
	$(PYTHON) -m compileall -q src

ci: lint test

bench:
	$(PYTHON) benchmarks/bench_planner.py

bench-quick:
	$(PYTHON) benchmarks/bench_planner.py --quick
