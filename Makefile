# Repo CI entry points (documented in README.md "Verify").
# The tier-1 command is `make test`; `make ci` adds the compileall lint pass
# and runs the schema-conformance + executor-differential suites first
# (fail fast on the paper's invariants before the long e2e sweeps).

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast test-schemas test-stream test-x2y test-hierarchy \
	test-obs lint ci bench bench-quick bench-skewed bench-fused \
	bench-sharded bench-coded bench-stream bench-x2y bench-hierarchy \
	bench-obs

test:
	$(PYTHON) -m pytest -q

# tier-1 minus the `slow` marker (full arch/kernel/model-decode e2e sweeps)
test-fast:
	$(PYTHON) -m pytest -q -m "not slow"

# the paper's correctness core: schema conformance + bucketed-, fused-,
# sharded- and coded-executor differential tests
test-schemas:
	$(PYTHON) -m pytest -q tests/test_schema_conformance.py \
		tests/test_bucketed_executor.py tests/test_fused_executor.py \
		tests/test_sharded_executor.py tests/test_coded_executor.py

# streaming maintenance: edit-sequence conformance + streamed-vs-cold
# differential + serving edit API
test-stream:
	$(PYTHON) -m pytest -q tests/test_stream.py tests/test_stream_tail.py

# rectangular X2Y execution: the executor-generic conformance matrix
# (every registry executor x {allpairs, x2y, some-pairs, block} x skew
# profiles) plus the X2Y differential suite (rect kernel vs oracle, rect
# partition invariants, streaming X- and Y-side edits, skew-join routing)
test-x2y:
	$(PYTHON) -m pytest -q tests/test_schema_conformance.py \
		tests/test_x2y_executors.py

# hierarchical planning: prefix pack vs FFD/BFD oracles, composed gap
# ledger (gap_total == gap_outer * gap_inner), PlanCache keying by
# grouping factor, sampled pair coverage at large m, run_block vs dense
test-hierarchy:
	$(PYTHON) -m pytest -q tests/test_hierarchy.py

# observability layer: histogram quantiles vs numpy, span nesting +
# Chrome-trace schema, comm-ledger reconciliation exact on every
# executor (coded r=2 vs the analytic model on an 8-device mesh),
# FUSED_STATS isolation regression, cache-eviction events
test-obs:
	$(PYTHON) -m pytest -q tests/test_obs.py

lint:
	$(PYTHON) -m compileall -q src

ci: lint test-schemas test-stream test-x2y test-hierarchy test-obs test \
	bench-coded bench-obs

bench:
	$(PYTHON) benchmarks/bench_planner.py

bench-quick:
	$(PYTHON) benchmarks/bench_planner.py --quick

bench-skewed:
	$(PYTHON) benchmarks/bench_engine.py --skewed

# dense vs bucketed vs fused executor; writes benchmarks/BENCH_engine.json
bench-fused:
	$(PYTHON) benchmarks/bench_engine.py --fused

# sharded vs bucketed vs fused on a forced 8-device CPU mesh; merges the
# engine_sharded section into benchmarks/BENCH_engine.json
bench-sharded:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		JAX_PLATFORMS=$${JAX_PLATFORMS:-cpu} \
		$(PYTHON) benchmarks/bench_engine.py --sharded

# coded vs sharded assembly traffic on a forced 8-device CPU mesh +
# replication-vs-communication Pareto frontier; writes
# benchmarks/BENCH_coded.json and enforces the acceptance bars:
# allclose to dense, coded r=2 assembly bytes <= 0.6x uncoded sharded,
# frontier monotone in r, every point >= the Thm-8 lower bound
bench-coded:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		JAX_PLATFORMS=$${JAX_PLATFORMS:-cpu} \
		$(PYTHON) benchmarks/bench_coded.py

# streaming edits vs full re-planning on Zipf m=512 (first-edit p99,
# update latency, recompute fraction, delta-vs-replan comm bytes); writes
# benchmarks/BENCH_stream.json and enforces the acceptance bars:
# first-edit p99 < 200ms, sustained achievable gap <= 1.3x, nonzero
# drift_replans + repacks, <25% single-edit recompute, allclose/conformance
bench-stream:
	JAX_PLATFORMS=$${JAX_PLATFORMS:-cpu} \
		$(PYTHON) benchmarks/bench_stream.py

# X2Y planner bounds + every registry executor on the skew_join(200x8)
# and balanced(30x30) rectangular profiles; merges into
# benchmarks/BENCH_x2y.json
bench-x2y:
	JAX_PLATFORMS=$${JAX_PLATFORMS:-cpu} \
		$(PYTHON) -m benchmarks.bench_x2y

# hierarchical planner at m=10^6 (Zipf profile) + block serving; writes
# benchmarks/BENCH_hierarchy.json and enforces the acceptance bars:
# plan+bound < 10s, o(m^2) host index, sampled coverage == 1.0,
# gap_total <= 2x flat gap at m=1024, block-served allclose to dense
bench-hierarchy:
	JAX_PLATFORMS=$${JAX_PLATFORMS:-cpu} \
		$(PYTHON) benchmarks/bench_hierarchy.py

# observability overhead on the serving hot path: fused Zipf m=512
# obs-on vs obs-off (repro.obs.configure kill switch); writes
# benchmarks/BENCH_obs.json and enforces the acceptance bar: < 5%
bench-obs:
	JAX_PLATFORMS=$${JAX_PLATFORMS:-cpu} \
		$(PYTHON) benchmarks/bench_obs.py
