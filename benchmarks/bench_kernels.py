"""Benchmark: Pallas kernels (interpret mode) vs jnp oracles — correctness
delta + CPU wall time (TPU perf comes from the dry-run roofline, not here).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    rows = []
    rng = np.random.default_rng(0)

    from repro.kernels.pairwise.pairwise import pairwise_gram
    from repro.kernels.pairwise.ref import pairwise_gram_ref
    x = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    got = pairwise_gram(x, x, bm=64, bn=64, bk=64, interpret=True)
    ref = pairwise_gram_ref(x, x)
    rows.append(dict(
        name="pairwise_gram_256x128",
        max_err=float(jnp.max(jnp.abs(got - ref))),
        us_ref=_time(lambda a: pairwise_gram_ref(a, a), x),
        us_kernel_interpret=_time(
            lambda a: pairwise_gram(a, a, bm=64, bn=64, bk=64,
                                    interpret=True), x),
        flops=2 * 256 * 256 * 128))

    from repro.kernels.flash.flash_attention import flash_attention
    from repro.kernels.flash.ref import attention_ref
    q = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, interpret=True, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    rows.append(dict(
        name="flash_attention_256x64",
        max_err=float(jnp.max(jnp.abs(got - ref))),
        us_ref=_time(lambda a, b, c: attention_ref(a, b, c, causal=True),
                     q, k, v),
        us_kernel_interpret=_time(
            lambda a, b, c: flash_attention(a, b, c, causal=True,
                                            interpret=True, bq=64, bk=64),
            q, k, v),
        flops=2 * 2 * 256 * 256 * 64))

    from repro.kernels.ssd.ssd import ssd_scan
    from repro.kernels.ssd.ref import ssd_scan_ref
    xs = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
    la = jnp.asarray(-np.abs(rng.normal(size=256)).astype(np.float32))
    got = ssd_scan(xs, la, b, c, chunk=64, interpret=True)
    ref = ssd_scan_ref(xs, la, b, c)
    rows.append(dict(
        name="ssd_scan_256x64x32",
        max_err=float(jnp.max(jnp.abs(got - ref))),
        us_ref=_time(lambda *a: ssd_scan_ref(*a), xs, la, b, c),
        us_kernel_interpret=_time(
            lambda *a: ssd_scan(*a, chunk=64, interpret=True), xs, la, b, c),
        flops=2 * 256 * (64 * 32 * 3)))
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r['name']:26s} max_err={r['max_err']:.2e} "
              f"ref={r['us_ref']:9.1f}us interp={r['us_kernel_interpret']:9.1f}us")
    return rows


if __name__ == "__main__":
    main()
