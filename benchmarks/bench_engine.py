"""Benchmark: the MapReduce engine end-to-end — schema comm cost vs naive
replication, and wall time of the sharded execution on the local mesh.

This is the paper's headline claim in executable form: the mapping schema
moves far fewer bytes map->reduce than naive all-pairs replication, at
identical outputs.

``--skewed`` runs the bucketed-executor scenario: Zipf-distributed input
sizes (the paper's *different-sized inputs*, cranked up) make one reducer
far heavier than the rest, so the dense executor pads every reducer to the
global max slot count while the bucketed executor pads each reducer only
to its capacity-bucket width.  The run exits non-zero unless the two
executors produce allclose similarity matrices AND the padded-element
(peak-memory) reduction meets the 2x acceptance bar; the wall-clock
speedup is reported (machine-dependent, informational).  Warmup runs
populate the engine's jit cache, so the timed iterations measure
execution, not tracing.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import naive_pairs, plan_a2a
from repro.mapreduce import build_plan, pairwise_similarity


def run(m: int = 96, d: int = 64, q: float = 1.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.05, 0.33, m)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    rows = []
    for name, schema in [
        ("planner-auto", plan_a2a(w, q)),
        ("naive-all-pairs", naive_pairs(w, q)),
    ]:
        schema.validate("a2a")
        plan = build_plan(schema)
        t0 = time.perf_counter()
        sims, _, _ = pairwise_similarity(x, q=q, weights=w, schema=schema)
        jax.block_until_ready(sims)
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=name, algo=schema.algorithm,
            comm_cost=round(schema.communication_cost(), 2),
            reducers=schema.num_reducers,
            max_replication=int(schema.replication().max()),
            gather_rows=int(plan.mask.sum()),
            wall_ms=round(dt * 1e3, 1)))
    base = rows[1]["comm_cost"]
    for r in rows:
        r["comm_vs_naive"] = round(r["comm_cost"] / base, 3)
    return rows


def _time_executor(x, q, w, schema, executor, repeats: int = 3):
    """Median wall time over ``repeats`` after a compile warmup."""
    sims = None
    for _ in range(2):                               # warmup / compile
        sims, plan, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, executor=executor)
        jax.block_until_ready(sims)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out, _, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, executor=executor)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return sims, plan, float(np.median(times))


def run_skewed(m: int = 512, d: int = 64, q: float = 1.0,
               zipf_a: float = 1.6, seed: int = 0, repeats: int = 3):
    """Zipf-sized inputs: dense executor vs bucketed executor on one plan.

    Returns a dict with the padded-element reduction (peak gather memory),
    per-executor wall times, and the allclose check.  The acceptance bar is
    >= 2x padded-element reduction and a wall-clock win."""
    rng = np.random.default_rng(seed)
    # heavy-tailed sizes in (0, 0.45 q]: many tiny inputs, a few near q/2
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    schema = plan_a2a(w, q)
    schema.validate("a2a")

    sims_d, plan, dense_s = _time_executor(x, q, w, schema, "dense", repeats)
    sims_b, _, buck_s = _time_executor(x, q, w, schema, "bucketed", repeats)

    allclose = bool(np.allclose(np.asarray(sims_d), np.asarray(sims_b),
                                rtol=1e-4, atol=1e-4))
    rep = {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a,
        "algorithm": schema.algorithm,
        "reducers": plan.num_reducers,
        "dense_width": plan.L,
        "bucket_widths": plan.bucket_widths(),
        "dense_padded_elements": plan.dense_padded_elements,
        "bucketed_padded_elements": plan.bucketed_padded_elements,
        "padded_reduction": round(plan.padding_savings, 3),
        "dense_wall_ms": round(dense_s * 1e3, 1),
        "bucketed_wall_ms": round(buck_s * 1e3, 1),
        "speedup": round(dense_s / max(buck_s, 1e-12), 3),
        "allclose": allclose,
    }
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--skewed", action="store_true",
                    help="Zipf input sizes: dense vs bucketed executor")
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--zipf-a", type=float, default=1.6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.skewed:
        rep = run_skewed(m=args.m or 512, d=args.d, zipf_a=args.zipf_a,
                         seed=args.seed)
        print(f"skewed A2A  m={rep['m']} d={rep['d']} zipf_a={rep['zipf_a']} "
              f"[{rep['algorithm']}] reducers={rep['reducers']}")
        print(f"  dense    width={rep['dense_width']:5d} "
              f"padded={rep['dense_padded_elements']:9d} "
              f"wall={rep['dense_wall_ms']:8.1f}ms")
        print(f"  bucketed widths={rep['bucket_widths']} "
              f"padded={rep['bucketed_padded_elements']:9d} "
              f"wall={rep['bucketed_wall_ms']:8.1f}ms")
        print(f"  padded-elements reduction: {rep['padded_reduction']:.2f}x  "
              f"speedup: {rep['speedup']:.2f}x  allclose: {rep['allclose']}")
        if not rep["allclose"]:
            raise SystemExit("FAIL: bucketed output diverges from dense")
        if rep["padded_reduction"] < 2.0:
            raise SystemExit(
                f"FAIL: padded-element reduction "
                f"{rep['padded_reduction']:.2f}x below the 2x bar")
        return rep

    rows = run(m=args.m or 96, d=args.d, seed=args.seed)
    for r in rows:
        print(f"{r['name']:16s} comm={r['comm_cost']:9.2f} "
              f"({r['comm_vs_naive']:.3f}x naive) reducers={r['reducers']:5d} "
              f"gather_rows={r['gather_rows']:6d} wall={r['wall_ms']:7.1f}ms "
              f"[{r['algo']}]")
    return rows


if __name__ == "__main__":
    main()
