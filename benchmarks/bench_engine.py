"""Benchmark: the MapReduce engine end-to-end — schema comm cost vs naive
replication, and wall time of the sharded execution on the local mesh.

This is the paper's headline claim in executable form: the mapping schema
moves far fewer bytes map->reduce than naive all-pairs replication, at
identical outputs.

``--skewed`` runs the bucketed-executor scenario: Zipf-distributed input
sizes (the paper's *different-sized inputs*, cranked up) make one reducer
far heavier than the rest, so the dense executor pads every reducer to the
global max slot count while the bucketed executor pads each reducer only
to its capacity-bucket width.  The run exits non-zero unless the two
executors produce allclose similarity matrices AND the padded-element
(peak-memory) reduction meets the 2x acceptance bar; the wall-clock
speedup is reported (machine-dependent, informational).  Warmup runs
populate the engine's jit cache, so the timed iterations measure
execution, not tracing.

``--sharded`` runs the shard-balanced multi-device scenario on the same
Zipf workload: the plan is LPT-partitioned over the local device mesh
(``make bench-sharded`` forces an 8-device CPU mesh via ``XLA_FLAGS``)
and executed per shard under ``shard_map``.  Bars: sharded output
allclose to bucketed and fused, and LPT balance factor <= 1.25 on the
8-shard reference partition.  Both ``--fused`` and ``--sharded`` merge
their sections into ``benchmarks/BENCH_engine.json`` for cross-PR
tracking.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import naive_pairs, plan_a2a
from repro.mapreduce import build_plan, pairwise_similarity

try:                                    # run as a script from benchmarks/
    from bench_common import emit_bench_json as _emit_bench_json
except ImportError:                     # imported as benchmarks.bench_engine
    from benchmarks.bench_common import emit_bench_json as _emit_bench_json

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_engine.json")


def run(m: int = 96, d: int = 64, q: float = 1.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.05, 0.33, m)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    rows = []
    for name, schema in [
        ("planner-auto", plan_a2a(w, q)),
        ("naive-all-pairs", naive_pairs(w, q)),
    ]:
        schema.validate("a2a")
        plan = build_plan(schema)
        t0 = time.perf_counter()
        sims, _, _ = pairwise_similarity(x, q=q, weights=w, schema=schema)
        jax.block_until_ready(sims)
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=name, algo=schema.algorithm,
            comm_cost=round(schema.communication_cost(), 2),
            reducers=schema.num_reducers,
            max_replication=int(schema.replication().max()),
            gather_rows=int(plan.mask.sum()),
            wall_ms=round(dt * 1e3, 1)))
    base = rows[1]["comm_cost"]
    for r in rows:
        r["comm_vs_naive"] = round(r["comm_cost"] / base, 3)
    return rows


def _time_executor(x, q, w, schema, executor, repeats: int = 3):
    """Median wall time over ``repeats`` after a compile warmup."""
    sims = None
    for _ in range(2):                               # warmup / compile
        sims, plan, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, executor=executor)
        jax.block_until_ready(sims)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out, _, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, executor=executor)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return sims, plan, float(np.median(times))


def run_skewed(m: int = 512, d: int = 64, q: float = 1.0,
               zipf_a: float = 1.6, seed: int = 0, repeats: int = 3):
    """Zipf-sized inputs: dense executor vs bucketed executor on one plan.

    Returns a dict with the padded-element reduction (peak gather memory),
    per-executor wall times, and the allclose check.  The acceptance bar is
    >= 2x padded-element reduction and a wall-clock win."""
    rng = np.random.default_rng(seed)
    # heavy-tailed sizes in (0, 0.45 q]: many tiny inputs, a few near q/2
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    schema = plan_a2a(w, q)
    schema.validate("a2a")

    sims_d, plan, dense_s = _time_executor(x, q, w, schema, "dense", repeats)
    sims_b, _, buck_s = _time_executor(x, q, w, schema, "bucketed", repeats)

    allclose = bool(np.allclose(np.asarray(sims_d), np.asarray(sims_b),
                                rtol=1e-4, atol=1e-4))
    rep = {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a,
        "algorithm": schema.algorithm,
        "reducers": plan.num_reducers,
        "dense_width": plan.L,
        "bucket_widths": plan.bucket_widths(),
        "dense_padded_elements": plan.dense_padded_elements,
        "bucketed_padded_elements": plan.bucketed_padded_elements,
        "padded_reduction": round(plan.padding_savings, 3),
        "dense_wall_ms": round(dense_s * 1e3, 1),
        "bucketed_wall_ms": round(buck_s * 1e3, 1),
        "speedup": round(dense_s / max(buck_s, 1e-12), 3),
        "allclose": allclose,
    }
    return rep


def _executor_hlo(x_shape, plan, executor: str) -> str:
    """Compiled single-host HLO text of one executor's program (no mesh),
    dispatched through the executor registry."""
    from repro.mapreduce.allpairs import _block_fn
    from repro.mapreduce.executors import get_executor

    lowered = get_executor(executor).lower(
        x_shape, plan, reducer_fn=_block_fn("dot", False), metric="dot",
        mesh=None)
    return lowered.compile().as_text()


def _kernel_model(plan, d: int, itemsize: int = 4) -> dict:
    from repro.kernels.pairwise.fused_gather_gram import fused_traffic_model
    return {k: int(v)
            for k, v in fused_traffic_model(plan.buckets, d,
                                            itemsize).items()}


def run_fused(m: int = 512, d: int = 64, q: float = 1.0,
              zipf_a: float = 1.6, seed: int = 0, repeats: int = 3):
    """Fused-executor acceptance run on the Zipf skewed workload.

    Times all three executors on one plan, checks allclose, measures the
    HBM bytes of each lowered program, and verifies from the compiled HLO
    that the fused program never materializes the dense (R, L, d) gather
    buffer that the dense executor does.  Bars: fused >= 1.5x wall-clock
    over bucketed, no dense gather buffer in the fused HLO.
    """
    from repro.launch.hlo_analysis import analyze_hlo_text, has_buffer_shape

    rng = np.random.default_rng(seed)
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    schema = plan_a2a(w, q)
    schema.validate("a2a")

    sims_d, plan, dense_s = _time_executor(x, q, w, schema, "dense", repeats)
    sims_b, _, buck_s = _time_executor(x, q, w, schema, "bucketed", repeats)
    sims_f, _, fused_s = _time_executor(x, q, w, schema, "fused", repeats)

    allclose = bool(
        np.allclose(np.asarray(sims_b), np.asarray(sims_f),
                    rtol=1e-4, atol=1e-4)
        and np.allclose(np.asarray(sims_d), np.asarray(sims_f),
                        rtol=1e-4, atol=1e-4))

    gather_shape = (plan.R, plan.L, d)
    hlo = {name: _executor_hlo((m, d), plan, name)
           for name in ("dense", "fused")}
    hbm = {name: analyze_hlo_text(text).hbm_bytes
           for name, text in hlo.items()}
    # tiled dataflow check: with bl below the bucket widths, multi-tile
    # buckets must stream (Rb, bl, d) tiles — their full (Rb, Lb, d)
    # gather must not appear anywhere in the lowered program
    from repro.mapreduce.engine import lower_reducers_fused
    tiled_bl = 8
    tiled_hlo = lower_reducers_fused((m, d), plan, "dot", mesh=None,
                                     bl=tiled_bl).compile().as_text()
    tiled_gathers = {
        f"{b.idx.shape[0]}x{b.idx.shape[1]}x{d}": has_buffer_shape(
            tiled_hlo, (b.idx.shape[0], b.idx.shape[1], d))
        for b in plan.buckets if b.idx.shape[1] > tiled_bl}
    # bucketed: per-bucket programs, terms summed (runs back-to-back)
    from repro.mapreduce.allpairs import _block_fn
    from repro.mapreduce.engine import _gather_reduce
    from functools import partial
    buck_bytes = 0.0
    run = jax.jit(partial(_gather_reduce, reducer_fn=_block_fn("dot", False)))
    for b in plan.buckets:
        lowered = run.lower(
            jax.ShapeDtypeStruct((m, d), jnp.float32),
            jax.ShapeDtypeStruct(b.idx.shape, jnp.int32),
            jax.ShapeDtypeStruct(b.mask.shape, jnp.bool_))
        buck_bytes += analyze_hlo_text(lowered.compile().as_text()).hbm_bytes
    hbm["bucketed"] = buck_bytes

    rep = {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a,
        "algorithm": schema.algorithm,
        "reducers": plan.num_reducers,
        "dense_width": plan.L,
        "bucket_widths": plan.bucket_widths(),
        "padded_elements": {
            "dense": plan.dense_padded_elements,
            "bucketed": plan.bucketed_padded_elements,
            "fused": plan.bucketed_padded_elements,   # same buckets, no HBM
        },
        "wall_ms": {
            "dense": round(dense_s * 1e3, 1),
            "bucketed": round(buck_s * 1e3, 1),
            "fused": round(fused_s * 1e3, 1),
        },
        "hbm_bytes": {k: int(v) for k, v in hbm.items()},
        # the TPU kernel's analytic dataflow (VMEM streaming is a kernel
        # property the CPU-lowered streamed twin can't exhibit)
        "hbm_bytes_fused_kernel_model": _kernel_model(plan, d),
        "speedup_fused_vs_bucketed": round(buck_s / max(fused_s, 1e-12), 3),
        "speedup_fused_vs_dense": round(dense_s / max(fused_s, 1e-12), 3),
        "allclose": allclose,
        "dense_gather_buffer": list(gather_shape),
        "gather_buffer_in_dense_hlo": has_buffer_shape(hlo["dense"],
                                                       gather_shape),
        "gather_buffer_in_fused_hlo": has_buffer_shape(hlo["fused"],
                                                       gather_shape),
        # per-bucket full gathers in the bl=8 tiled lowering (must all be
        # False for buckets wider than one tile)
        "bucket_gather_in_tiled_fused_hlo": tiled_gathers,
    }
    return rep


def run_sharded(m: int = 512, d: int = 64, q: float = 1.0,
                zipf_a: float = 1.6, seed: int = 0, repeats: int = 3,
                balance_shards: int = 8):
    """Sharded-executor acceptance run on the Zipf skewed workload.

    Times bucketed / fused / sharded on one plan (the sharded executor uses
    all local devices — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a real
    multi-shard CPU mesh, which is what ``make bench-sharded`` does),
    checks allclose against both baselines, and reports the LPT partition:
    per-shard padded elements, shipped rows, and the balance factor over
    ``balance_shards`` shards.  Bars: allclose, and balance factor <= 1.25
    on the Zipf m=512 reference partition.
    """
    import jax as _jax
    from repro.core import partition_plan

    rng = np.random.default_rng(seed)
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    schema = plan_a2a(w, q)
    schema.validate("a2a")

    sims_b, plan, buck_s = _time_executor(x, q, w, schema, "bucketed",
                                          repeats)
    sims_f, _, fused_s = _time_executor(x, q, w, schema, "fused", repeats)
    sims_s, _, shard_s = _time_executor(x, q, w, schema, "sharded", repeats)

    allclose = bool(
        np.allclose(np.asarray(sims_b), np.asarray(sims_s),
                    rtol=1e-4, atol=1e-4)
        and np.allclose(np.asarray(sims_f), np.asarray(sims_s),
                        rtol=1e-4, atol=1e-4))

    # the acceptance partition: LPT balance over the reference shard count
    # (independent of how many devices this host happens to expose)
    part = partition_plan(plan, balance_shards)
    rep_part = part.report()
    # the partition actually executed on this host's devices
    n_dev = len(_jax.devices())
    exec_part = partition_plan(plan, n_dev).report()

    rep = {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a,
        "algorithm": schema.algorithm,
        "reducers": plan.num_reducers,
        "devices": n_dev,
        "bucket_widths": plan.bucket_widths(),
        "wall_ms": {
            "bucketed": round(buck_s * 1e3, 1),
            "fused": round(fused_s * 1e3, 1),
            "sharded": round(shard_s * 1e3, 1),
        },
        "speedup_sharded_vs_bucketed": round(buck_s / max(shard_s, 1e-12),
                                             3),
        "speedup_sharded_vs_fused": round(fused_s / max(shard_s, 1e-12), 3),
        "allclose": allclose,
        "balance_shards": balance_shards,
        "balance_factor": rep_part["balance_factor"],
        "padded_elements_per_shard": rep_part["padded_elements_per_shard"],
        "shipped_rows_per_shard": rep_part["shipped_rows"],
        "executed_num_shards": n_dev,
        "executed_balance_factor": exec_part["balance_factor"],
    }
    return rep


def emit_bench_json(payload: dict, path: str = BENCH_JSON):
    """Merge ``payload`` into BENCH_engine.json (canonical implementation
    lives in bench_common; this wrapper keeps the historical import site
    ``from bench_engine import emit_bench_json`` working)."""
    return _emit_bench_json(payload, path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--skewed", action="store_true",
                    help="Zipf input sizes: dense vs bucketed executor")
    ap.add_argument("--fused", action="store_true",
                    help="Zipf input sizes: fused vs bucketed vs dense; "
                         "writes BENCH_engine.json")
    ap.add_argument("--sharded", action="store_true",
                    help="Zipf input sizes: sharded vs bucketed vs fused "
                         "over the local device mesh; writes "
                         "BENCH_engine.json")
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--zipf-a", type=float, default=1.6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.fused:
        rep = run_fused(m=args.m or 512, d=args.d, zipf_a=args.zipf_a,
                        seed=args.seed)
        print(f"fused A2A  m={rep['m']} d={rep['d']} "
              f"zipf_a={rep['zipf_a']} [{rep['algorithm']}] "
              f"reducers={rep['reducers']}")
        for name in ("dense", "bucketed", "fused"):
            print(f"  {name:8s} wall={rep['wall_ms'][name]:8.1f}ms "
                  f"padded={rep['padded_elements'][name]:9d} "
                  f"hbm_bytes={rep['hbm_bytes'][name]:.3e}")
        print(f"  fused speedup: {rep['speedup_fused_vs_bucketed']:.2f}x "
              f"vs bucketed, {rep['speedup_fused_vs_dense']:.2f}x vs dense  "
              f"allclose: {rep['allclose']}")
        print(f"  dense (R,L,d) gather buffer {rep['dense_gather_buffer']}: "
              f"in dense HLO: {rep['gather_buffer_in_dense_hlo']}  "
              f"in fused HLO: {rep['gather_buffer_in_fused_hlo']}")
        print(f"  tiled (bl=8) fused HLO bucket gathers: "
              f"{rep['bucket_gather_in_tiled_fused_hlo']}")
        path = emit_bench_json({"engine_fused": rep})
        print(f"  wrote {path}")
        if not rep["allclose"]:
            raise SystemExit("FAIL: fused output diverges")
        if rep["gather_buffer_in_fused_hlo"]:
            raise SystemExit("FAIL: fused HLO materializes the (R, L, d) "
                             "gather buffer")
        if not rep["gather_buffer_in_dense_hlo"]:
            raise SystemExit("FAIL: buffer check is vacuous — dense HLO "
                             "does not show the (R, L, d) gather")
        if any(rep["bucket_gather_in_tiled_fused_hlo"].values()):
            raise SystemExit("FAIL: tiled fused HLO materializes a full "
                             "per-bucket gather")
        if rep["speedup_fused_vs_bucketed"] < 1.5:
            raise SystemExit(
                f"FAIL: fused speedup {rep['speedup_fused_vs_bucketed']:.2f}x"
                f" below the 1.5x bar")
        return rep

    if args.sharded:
        rep = run_sharded(m=args.m or 512, d=args.d, zipf_a=args.zipf_a,
                          seed=args.seed)
        print(f"sharded A2A  m={rep['m']} d={rep['d']} "
              f"zipf_a={rep['zipf_a']} [{rep['algorithm']}] "
              f"reducers={rep['reducers']} devices={rep['devices']}")
        for name in ("bucketed", "fused", "sharded"):
            print(f"  {name:8s} wall={rep['wall_ms'][name]:8.1f}ms")
        print(f"  sharded speedup: {rep['speedup_sharded_vs_bucketed']:.2f}x"
              f" vs bucketed, {rep['speedup_sharded_vs_fused']:.2f}x vs "
              f"fused  allclose: {rep['allclose']}")
        print(f"  LPT balance over {rep['balance_shards']} shards: "
              f"{rep['balance_factor']:.3f}  padded/shard: "
              f"{rep['padded_elements_per_shard']}  shipped/shard: "
              f"{rep['shipped_rows_per_shard']}")
        path = emit_bench_json({"engine_sharded": rep})
        print(f"  wrote {path}")
        if not rep["allclose"]:
            raise SystemExit("FAIL: sharded output diverges")
        if rep["balance_factor"] > 1.25:
            raise SystemExit(
                f"FAIL: LPT balance factor {rep['balance_factor']:.3f} "
                f"above the 1.25 bar")
        return rep

    if args.skewed:
        rep = run_skewed(m=args.m or 512, d=args.d, zipf_a=args.zipf_a,
                         seed=args.seed)
        print(f"skewed A2A  m={rep['m']} d={rep['d']} zipf_a={rep['zipf_a']} "
              f"[{rep['algorithm']}] reducers={rep['reducers']}")
        print(f"  dense    width={rep['dense_width']:5d} "
              f"padded={rep['dense_padded_elements']:9d} "
              f"wall={rep['dense_wall_ms']:8.1f}ms")
        print(f"  bucketed widths={rep['bucket_widths']} "
              f"padded={rep['bucketed_padded_elements']:9d} "
              f"wall={rep['bucketed_wall_ms']:8.1f}ms")
        print(f"  padded-elements reduction: {rep['padded_reduction']:.2f}x  "
              f"speedup: {rep['speedup']:.2f}x  allclose: {rep['allclose']}")
        if not rep["allclose"]:
            raise SystemExit("FAIL: bucketed output diverges from dense")
        if rep["padded_reduction"] < 2.0:
            raise SystemExit(
                f"FAIL: padded-element reduction "
                f"{rep['padded_reduction']:.2f}x below the 2x bar")
        return rep

    rows = run(m=args.m or 96, d=args.d, seed=args.seed)
    for r in rows:
        print(f"{r['name']:16s} comm={r['comm_cost']:9.2f} "
              f"({r['comm_vs_naive']:.3f}x naive) reducers={r['reducers']:5d} "
              f"gather_rows={r['gather_rows']:6d} wall={r['wall_ms']:7.1f}ms "
              f"[{r['algo']}]")
    return rows


if __name__ == "__main__":
    main()
