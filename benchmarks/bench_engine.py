"""Benchmark: the MapReduce engine end-to-end — schema comm cost vs naive
replication, and wall time of the sharded execution on the local mesh.

This is the paper's headline claim in executable form: the mapping schema
moves far fewer bytes map->reduce than naive all-pairs replication, at
identical outputs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import naive_pairs, plan_a2a
from repro.mapreduce import build_plan, pairwise_similarity


def run(m: int = 96, d: int = 64, q: float = 1.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.05, 0.33, m)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    rows = []
    for name, schema in [
        ("planner-auto", plan_a2a(w, q)),
        ("naive-all-pairs", naive_pairs(w, q)),
    ]:
        schema.validate("a2a")
        plan = build_plan(schema)
        t0 = time.perf_counter()
        sims, _, _ = pairwise_similarity(x, q=q, weights=w, schema=schema)
        jax.block_until_ready(sims)
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=name, algo=schema.algorithm,
            comm_cost=round(schema.communication_cost(), 2),
            reducers=schema.num_reducers,
            max_replication=int(schema.replication().max()),
            gather_rows=int(plan.mask.sum()),
            wall_ms=round(dt * 1e3, 1)))
    base = rows[1]["comm_cost"]
    for r in rows:
        r["comm_vs_naive"] = round(r["comm_cost"] / base, 3)
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r['name']:16s} comm={r['comm_cost']:9.2f} "
              f"({r['comm_vs_naive']:.3f}x naive) reducers={r['reducers']:5d} "
              f"gather_rows={r['gather_rows']:6d} wall={r['wall_ms']:7.1f}ms "
              f"[{r['algo']}]")
    return rows


if __name__ == "__main__":
    main()
