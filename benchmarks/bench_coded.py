"""Benchmark: coded shuffle execution — replication vs cross-shard traffic.

The sharded executor assembles the (m, m) matrix with one cross-shard
gather of every shard's Gram stacks; the coded executor (the
coded-MapReduce tradeoff of Afrati et al., arXiv:1206.4377) replicates
each reducer's sub-plan on r LPT-chosen shards so replica holders serve
their output row-slice locally and only the residual entries cross shards
in one batched all-to-all.  This run measures that tradeoff on the
acceptance workload — 8 shards, Zipf m=512, r=2 — via lowered HLO, and
sweeps r for the replication-vs-communication Pareto frontier.

Bars (run exits non-zero on failure):
  - coded output allclose to the dense executor's;
  - coded cross-shard assembly bytes at r=2 <= 0.6x the uncoded sharded
    executor's (HLO-measured collective bytes);
  - measured assembly bytes monotone non-increasing in r (the frontier
    never pays MORE traffic for MORE replication);
  - every frontier point's total communication >= the Thm-8 lower bound.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a
real 8-shard CPU mesh — that is what ``make bench-coded`` does.  Merges
results into ``benchmarks/BENCH_coded.json``.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan_a2a
from repro.launch.roofline import collective_bytes
from repro.mapreduce import get_executor, make_executor, pairwise_similarity
from repro.mapreduce.executors import choose_replication

try:                                    # run as a script from benchmarks/
    from bench_common import emit_bench_json
except ImportError:                     # imported as a package module
    from benchmarks.bench_common import emit_bench_json

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_coded.json")

ASSEMBLY_BYTES_BAR = 0.6                 # coded r=2 vs uncoded sharded


def run_coded(m: int = 512, d: int = 64, q: float = 1.0,
              zipf_a: float = 1.6, seed: int = 0, repeats: int = 3,
              replication: int = 2):
    """Acceptance run: Zipf m=512 on the local mesh (8 forced devices)."""
    rng = np.random.default_rng(seed)
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    schema = plan_a2a(w, q)
    schema.validate("a2a")
    n_dev = len(jax.devices())

    sims_d, plan, _ = pairwise_similarity(x, q=q, weights=w, schema=schema,
                                          executor="dense")
    coded = make_executor("coded")
    coded.replication = replication
    sims_c, _, _ = pairwise_similarity(x, q=q, weights=w, schema=schema,
                                       executor=coded)
    allclose = bool(np.allclose(np.asarray(sims_d), np.asarray(sims_c),
                                rtol=1e-4, atol=1e-4))

    t0 = time.perf_counter()
    for _ in range(repeats):
        sims_c, _, _ = pairwise_similarity(x, q=q, weights=w,
                                           schema=schema, executor=coded)
        jax.block_until_ready(sims_c)
    coded_s = (time.perf_counter() - t0) / repeats

    # HLO-measured assembly traffic: the uncoded sharded gather vs the
    # coded residual exchange at each replication rate
    hlo_sharded = get_executor("sharded").lower(
        (m, d), plan, metric="dot", m=m).compile().as_text()
    uncoded_bytes = collective_bytes(hlo_sharded)["total"]
    best_r, model_frontier = choose_replication(plan, n_dev, m, d,
                                                itemsize=4)
    lb_bytes = (float(plan.lower_bound) * d * 4
                if plan.lower_bound else None)
    frontier = []
    for rec in model_frontier:
        r = rec["replication"]
        hlo = coded.lower((m, d), plan, metric="dot", m=m,
                          replication=r).compile().as_text()
        measured = collective_bytes(hlo)["total"]
        total = rec["shipped_bytes"] + n_dev * measured
        frontier.append({
            "replication": r,
            "measured_assembly_bytes_per_shard": measured,
            "model_assembly_bytes_per_shard":
                rec["assembly_bytes_per_shard"],
            "local_fraction": rec["local_fraction"],
            "shipped_bytes": rec["shipped_bytes"],
            "total_comm_bytes": total,
            "ge_lower_bound": (total >= lb_bytes if lb_bytes else None),
        })
    measured_r = {p["replication"]: p["measured_assembly_bytes_per_shard"]
                  for p in frontier}
    coded_bytes = measured_r.get(replication)
    assembly = [p["measured_assembly_bytes_per_shard"] for p in frontier]

    st = coded.stats()
    return {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a,
        "algorithm": schema.algorithm,
        "reducers": plan.num_reducers,
        "devices": n_dev,
        "replication": replication,
        "allclose": allclose,
        "wall_ms_coded": round(coded_s * 1e3, 1),
        "balance_factor": st["balance_factor"],
        "local_fraction": st["local_fraction"],
        "residual_entries": st["residual_entries"],
        "uncoded_assembly_bytes_per_shard": uncoded_bytes,
        "coded_assembly_bytes_per_shard": coded_bytes,
        "assembly_bytes_reduction": (
            coded_bytes / max(uncoded_bytes, 1e-12)
            if coded_bytes is not None else None),
        "assembly_bytes_bar": ASSEMBLY_BYTES_BAR,
        "frontier_monotone": bool(
            all(b <= a for a, b in zip(assembly, assembly[1:]))),
        "best_replication": best_r,
        "schema_lower_bound_bytes": lb_bytes,
        "pareto_frontier": frontier,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)

    rep = run_coded(m=args.m, d=args.d, repeats=args.repeats)
    print(f"coded executor on {rep['devices']} devices "
          f"[{rep['algorithm']}], r={rep['replication']}: "
          f"allclose={rep['allclose']} "
          f"wall={rep['wall_ms_coded']}ms "
          f"balance={rep['balance_factor']:.3f}")
    print(f"assembly bytes/shard: uncoded sharded "
          f"{rep['uncoded_assembly_bytes_per_shard']/1e6:.2f} MB -> coded "
          f"{rep['coded_assembly_bytes_per_shard']/1e6:.2f} MB "
          f"({rep['assembly_bytes_reduction']:.3f}x, bar <= "
          f"{rep['assembly_bytes_bar']}x)")
    print(f"Pareto frontier (knee r={rep['best_replication']}, "
          f"LB {(rep['schema_lower_bound_bytes'] or 0)/1e6:.2f} MB):")
    for p in rep["pareto_frontier"]:
        print(f"  r={p['replication']:2d} assembly "
              f"{p['measured_assembly_bytes_per_shard']/1e6:.3f} MB/shard "
              f"(local {p['local_fraction']:.2f}) shipped "
              f"{p['shipped_bytes']/1e6:.2f} MB total "
              f"{p['total_comm_bytes']/1e6:.2f} MB >=LB:"
              f"{p['ge_lower_bound']}")
    path = emit_bench_json({"coded": rep}, path=BENCH_JSON)
    print(f"wrote {path}")

    if not rep["allclose"]:
        raise SystemExit("FAIL: coded output diverges from dense")
    if rep["assembly_bytes_reduction"] > ASSEMBLY_BYTES_BAR:
        raise SystemExit(
            f"FAIL: coded assembly bytes "
            f"{rep['assembly_bytes_reduction']:.3f}x uncoded, bar is "
            f"{ASSEMBLY_BYTES_BAR}x")
    if not rep["frontier_monotone"]:
        raise SystemExit("FAIL: measured assembly bytes not monotone "
                         "non-increasing in r")
    if any(p["ge_lower_bound"] is False for p in rep["pareto_frontier"]):
        raise SystemExit("FAIL: frontier point below the Thm-8 lower "
                         "bound")
    print("PASS: all coded-executor bars met")


if __name__ == "__main__":
    main()
