"""Assemble the §Roofline / §Dry-run tables from dry-run JSON records.

Usage:  PYTHONPATH=src:. python -m benchmarks.roofline_report [--tag __opt]
Emits a markdown table (stdout) — pasted into EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

ARCH_ORDER = [
    "mixtral-8x7b", "llama4-maverick-400b-a17b", "whisper-large-v3",
    "internvl2-26b", "mamba2-370m", "jamba-1.5-large-398b", "granite-34b",
    "stablelm-1.6b", "gemma3-4b", "stablelm-3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag: str = "", mesh: str = "pod_16x16"):
    rows = {}
    for path in glob.glob(os.path.join(RESULTS, f"*__{mesh}{tag}.json")):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        arch, shape = parts[0], parts[1]
        if tag and not base.endswith(tag):
            continue
        if not tag and len(parts) > 3:
            continue
        with open(path) as f:
            rows[(arch, shape)] = json.load(f)
    return rows


def fmt_sec(x):
    if x >= 100:
        return f"{x:7.0f}"
    if x >= 1:
        return f"{x:7.2f}"
    return f"{x:7.4f}"


def table(rows, kernel_resident=True):
    print("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
          " | bottleneck | roofline frac | useful FLOPs |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = rows.get((arch, shape))
            if r is None:
                continue
            if r.get("status") == "skipped":
                print(f"| {arch} | {shape} | — | — | — | *skipped:"
                      f" full-attention arch* | — | — |")
                continue
            if r.get("status") != "ok":
                print(f"| {arch} | {shape} | — | — | — | **ERROR** | — | — |")
                continue
            if kernel_resident:
                tm = r["t_memory_kernel_resident"]
                bn = r["bottleneck_kernel_resident"]
                fr = r["roofline_fraction_kernel_resident"]
            else:
                tm, bn, fr = r["t_memory"], r["bottleneck"], \
                    r["roofline_fraction"]
            print(f"| {arch} | {shape} | {fmt_sec(r['t_compute'])} | "
                  f"{fmt_sec(tm)} | {fmt_sec(r['t_collective'])} | {bn} | "
                  f"{fr:.3f} | {r['useful_flops_ratio']:.3f} |")


def memory_table(rows):
    print("| arch | shape | HLO args (GB/dev) | temps (GB/dev) | fits 16GB? |")
    print("|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = rows.get((arch, shape))
            if not r or r.get("status") != "ok" or \
                    not r.get("memory_per_device"):
                continue
            m = r["memory_per_device"]
            args = m["argument_bytes"] / 1e9
            temp = m["temp_bytes"] / 1e9
            # note: CPU-backend temps are not VMEM-scheduled; indicative only
            print(f"| {arch} | {shape} | {args:.2f} | {temp:.2f} | "
                  f"{'yes' if args + min(temp, 4) < 16 else 'needs remat/offload'} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default="pod_16x16",
                    choices=["pod_16x16", "multipod_2x16x16"])
    ap.add_argument("--naive", action="store_true",
                    help="use naive (non-kernel-resident) memory accounting")
    ap.add_argument("--memory", action="store_true")
    args = ap.parse_args()
    rows = load(args.tag, args.mesh)
    if args.memory:
        memory_table(rows)
    else:
        table(rows, kernel_resident=not args.naive)


if __name__ == "__main__":
    main()
