"""Benchmark: hierarchical million-input planning + block serving.

Three acceptance bars (ISSUE 8 / DESIGN.md section 1h):

  * plan_million — ``plan_a2a_hierarchical`` plans *and lower-bounds* an
    m=10^6 Zipf profile in < 10 s wall-clock, with host-side index state
    o(m^2) (reported as CSR entries and peak RSS; the dense met matrix
    alone would be 10^12 cells);
  * gap_vs_flat — at m=1024 the composed ledger's ``gap_total`` (the
    provable upper bound on the two-level plan's gap) stays <= 2x the
    flat planner's measured gap on the same profile;
  * block_allclose — every block of an m=1024 cross-check grid served
    through ``Executor.run_block`` matches the dense executor allclose.

Writes the machine-readable report to ``benchmarks/BENCH_hierarchy.json``
(next to BENCH_engine.json / BENCH_stream.json / BENCH_x2y.json).
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

try:                                    # run as a script from benchmarks/
    from bench_common import emit_bench_json as _emit_bench_json
except ImportError:                     # imported as a package module
    from benchmarks.bench_common import emit_bench_json as _emit_bench_json

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_hierarchy.json")

PLAN_WALL_BAR_S = 10.0
GAP_RATIO_BAR = 2.0
HOST_ENTRIES_PER_INPUT_BAR = 100      # o(m^2) witness: entries <= 100 m


def zipf_weights(m: int, q: float, a: float = 0.6, seed: int = 0):
    """Power-law rank profile w_k ~ k^-a, shuffled, clipped under q/4 so
    grouping factors c >= 2 stay feasible."""
    w = 1.0 / (np.arange(1, m + 1) ** a)
    w = w / w.max()
    w = np.clip(w, None, 0.24 * q)
    np.random.default_rng(seed).shuffle(w)
    return w


def bench_plan_million(m: int, seed: int) -> dict:
    from repro.core import PLAN_CACHE, plan_a2a_hierarchical, \
        sampled_pair_coverage
    from repro.mapreduce import build_sparse_plan

    q = 25.0
    w = zipf_weights(m, q, seed=seed)
    PLAN_CACHE.clear()
    t0 = time.perf_counter()
    schema = plan_a2a_hierarchical(w, q)
    gap = schema.optimality_gap()            # cost + Thm-8 bound computed
    plan_s = time.perf_counter() - t0
    h = schema.meta.get("hierarchy", {})

    t0 = time.perf_counter()
    sparse = build_sparse_plan(schema)
    sparse_s = time.perf_counter() - t0
    cov = sampled_pair_coverage(schema, 2048, seed=seed)

    try:
        import resource
        maxrss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss \
            / 1024.0
    except Exception:
        maxrss_mb = None
    return {
        "m": m, "q": q, "s": float(np.sum(w)),
        "algorithm": schema.algorithm,
        "reducers": schema.num_reducers,
        "plan_s": plan_s,
        "sparse_build_s": sparse_s,
        "optimality_gap": gap,
        "hierarchy": h,
        "sampled_coverage": cov,
        "host_entries": sparse.host_entries,
        "host_entries_per_input": sparse.host_entries / m,
        "maxrss_mb": maxrss_mb,
    }


def bench_gap_vs_flat(m: int, seed: int) -> dict:
    from repro.core import plan_a2a, plan_a2a_hierarchical

    q = 25.0
    w = zipf_weights(m, q, seed=seed)
    flat = plan_a2a(w, q, use_cache=False)
    flat_gap = flat.optimality_gap()
    hier = plan_a2a_hierarchical(w, q, c=2, use_cache=False)
    h = hier.meta["hierarchy"]
    return {
        "m": m, "q": q,
        "flat_algorithm": flat.algorithm,
        "flat_gap": flat_gap,
        "hier_algorithm": hier.algorithm,
        "hier_measured_gap": hier.optimality_gap(),
        "gap_total": h["gap_total"],
        "gap_inner": h["gap_inner"],
        "gap_outer": h["gap_outer"],
        "gap_ratio": h["gap_total"] / flat_gap if flat_gap else None,
    }


def bench_block_allclose(m: int, d: int, block: int, seed: int,
                         executors=("bucketed", "fused")) -> dict:
    import jax.numpy as jnp
    from repro.core import plan_a2a_hierarchical
    from repro.mapreduce.allpairs import (
        pairwise_similarity,
        pairwise_similarity_block,
    )

    q = 25.0
    w = zipf_weights(m, q, seed=seed)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    schema = plan_a2a_hierarchical(w, q, c=2, use_cache=False)
    ref, _, _ = pairwise_similarity(x, q=q, schema=schema,
                                    executor="dense")
    ref = np.asarray(ref)
    out = {"m": m, "d": d, "block": block, "executors": {}}
    for ex in executors:
        t0 = time.perf_counter()
        max_err, blocks, ok = 0.0, 0, True
        for i0 in range(0, m, block):
            for j0 in range(0, m, block):
                i1, j1 = min(i0 + block, m), min(j0 + block, m)
                blk, _, _ = pairwise_similarity_block(
                    x, i0, i1, j0, j1, q=q, schema=schema, executor=ex)
                err = float(np.abs(np.asarray(blk)
                                   - ref[i0:i1, j0:j1]).max())
                max_err = max(max_err, err)
                ok = ok and np.allclose(np.asarray(blk),
                                        ref[i0:i1, j0:j1], atol=1e-4)
                blocks += 1
        out["executors"][ex] = {
            "blocks": blocks, "allclose": bool(ok),
            "max_abs_err": max_err,
            "wall_s": time.perf_counter() - t0,
        }
    return out


def emit_bench_json(payload: dict, path: str = BENCH_JSON) -> str:
    return _emit_bench_json(payload, path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m-plan", type=int, default=1_000_000)
    ap.add_argument("--m-block", type=int, default=1024)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--block", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    plan = bench_plan_million(args.m_plan, args.seed)
    print(f"hierarchy  m={plan['m']} [{plan['algorithm']}] "
          f"plan+bound={plan['plan_s']:.2f}s "
          f"sparse={plan['sparse_build_s']:.2f}s "
          f"gap={plan['optimality_gap']:.3f} "
          f"gap_total={plan['hierarchy'].get('gap_total', 0):.3f} "
          f"coverage={plan['sampled_coverage']:.3f} "
          f"host_entries/m={plan['host_entries_per_input']:.1f}")

    gap = bench_gap_vs_flat(args.m_block, args.seed)
    print(f"  m={gap['m']} flat[{gap['flat_algorithm']}] "
          f"gap={gap['flat_gap']:.3f} vs hier[{gap['hier_algorithm']}] "
          f"gap_total={gap['gap_total']:.3f} "
          f"(measured {gap['hier_measured_gap']:.3f}) "
          f"ratio={gap['gap_ratio']:.2f}")

    blocks = bench_block_allclose(args.m_block, args.d, args.block,
                                  args.seed)
    for ex, r in blocks["executors"].items():
        print(f"  block-serve [{ex}] {r['blocks']} blocks of "
              f"{blocks['block']} allclose={r['allclose']} "
              f"max_err={r['max_abs_err']:.2e} wall={r['wall_s']:.1f}s")

    path = emit_bench_json({"hierarchy": {
        "plan_million": plan, "gap_vs_flat": gap,
        "block_allclose": blocks}})
    print(f"  wrote {path}")

    # ------------------------------------------------------- acceptance bars
    if plan["plan_s"] >= PLAN_WALL_BAR_S:
        raise SystemExit(f"FAIL: m={plan['m']} plan+bound took "
                         f"{plan['plan_s']:.1f}s (bar: < "
                         f"{PLAN_WALL_BAR_S:.0f}s)")
    if plan["sampled_coverage"] < 1.0:
        raise SystemExit("FAIL: sampled pair coverage "
                         f"{plan['sampled_coverage']:.4f} (bar: == 1.0)")
    if plan["host_entries_per_input"] > HOST_ENTRIES_PER_INPUT_BAR:
        raise SystemExit(
            f"FAIL: {plan['host_entries_per_input']:.0f} host index "
            f"entries per input (bar: <= {HOST_ENTRIES_PER_INPUT_BAR} — "
            f"o(m^2) violated)")
    if gap["gap_ratio"] is None or gap["gap_ratio"] > GAP_RATIO_BAR:
        raise SystemExit(f"FAIL: gap_total/flat_gap = {gap['gap_ratio']} "
                         f"(bar: <= {GAP_RATIO_BAR})")
    for ex, r in blocks["executors"].items():
        if not r["allclose"]:
            raise SystemExit(f"FAIL: [{ex}] block-served values diverge "
                             f"from dense (max err {r['max_abs_err']:.2e})")
    return {"plan": plan, "gap": gap, "blocks": blocks}


if __name__ == "__main__":
    main()
