"""Shared benchmark plumbing: BENCH_*.json emission + run metadata.

Every ``bench_*.py`` writes its machine-readable perf trajectory through
:func:`emit_bench_json` (one canonical copy — bench_engine/bench_hierarchy/
bench_stream used to carry three identical private copies).  Sections merge
into the existing file so e.g. ``--fused`` and ``--sharded`` runs
accumulate instead of clobbering each other's history, and every write
stamps a uniform ``meta`` block (git revision, jax version, device kind)
so a stored number is traceable to the build that produced it.
"""

from __future__ import annotations

import json
import os
import subprocess

_HERE = os.path.dirname(os.path.abspath(__file__))


def bench_json_path(name: str) -> str:
    """Absolute path of ``benchmarks/BENCH_<name>.json``."""
    return os.path.join(_HERE, f"BENCH_{name}.json")


def _git_rev() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_HERE,
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def bench_metadata() -> dict:
    """Uniform provenance block stamped into every BENCH_*.json write."""
    meta = {"git_rev": _git_rev()}
    try:
        import jax
        meta["jax_version"] = jax.__version__
        meta["device_kind"] = jax.devices()[0].device_kind
        meta["device_count"] = jax.device_count()
    except Exception:                      # jax absent or no backend
        meta["jax_version"] = "unavailable"
        meta["device_kind"] = "unknown"
        meta["device_count"] = 0
    return meta


def emit_bench_json(payload: dict, path: str) -> str:
    """Merge ``payload`` (plus a fresh ``meta`` block) into ``path``.

    Machine-readable perf trajectory read by CI across PRs: existing
    sections survive, same-named sections are replaced.
    """
    existing = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = {}
    existing.update(payload)
    existing["meta"] = bench_metadata()
    with open(path, "w") as f:
        json.dump(existing, f, indent=1, sort_keys=True)
        f.write("\n")
    return os.path.abspath(path)
