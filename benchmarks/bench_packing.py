"""Benchmark: FFD sequence packing vs no packing (paper applied to data).

Reports token efficiency (non-pad fraction) and rows needed for a fixed
document stream — the training-pipeline face of the paper's bins.
"""

from __future__ import annotations

import numpy as np

from repro.data import PackedLMDataset, packing_efficiency


def run(seq_len: int = 4096, batches: int = 4):
    rows = []
    for pack in (True, False):
        ds = PackedLMDataset(vocab_size=32000, seq_len=seq_len,
                             batch_size=32, seed=7, pack=pack)
        it = iter(ds)
        effs, count = [], 0
        for _ in range(batches):
            b = next(it)
            effs.append(packing_efficiency(b))
            count += b["tokens"].shape[0]
        rows.append(dict(mode="ffd-packed" if pack else "one-doc-per-row",
                         token_efficiency=round(float(np.mean(effs)), 4),
                         rows_consumed=count))
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r['mode']:18s} efficiency={r['token_efficiency']:.4f} "
              f"rows={r['rows_consumed']}")
    gain = rows[0]["token_efficiency"] / max(rows[1]["token_efficiency"],
                                             1e-9)
    print(f"packing gain: {gain:.2f}x useful tokens per row")
    return rows


if __name__ == "__main__":
    main()
