"""Benchmark: FFD sequence packing vs no packing (paper applied to data).

Reports token efficiency (non-pad fraction) and rows needed for a fixed
document stream — the training-pipeline face of the paper's bins — plus the
packer microbenchmark: the O(n log n) FFD/BFD used by the strategy-registry
planner vs the textbook O(n^2) scans they replaced (bit-identical bins).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.binpack import bfd, bfd_reference, ffd, ffd_reference
from repro.data import PackedLMDataset, packing_efficiency


def run(seq_len: int = 4096, batches: int = 4):
    rows = []
    for pack in (True, False):
        ds = PackedLMDataset(vocab_size=32000, seq_len=seq_len,
                             batch_size=32, seed=7, pack=pack)
        it = iter(ds)
        effs, count = [], 0
        for _ in range(batches):
            b = next(it)
            effs.append(packing_efficiency(b))
            count += b["tokens"].shape[0]
        rows.append(dict(mode="ffd-packed" if pack else "one-doc-per-row",
                         token_efficiency=round(float(np.mean(effs)), 4),
                         rows_consumed=count))
    return rows


def run_packers(sizes=(1_000, 5_000, 20_000), seed: int = 0):
    """Fast vs reference FFD/BFD: same bins, asymptotically faster."""
    rng = np.random.default_rng(seed)
    rows = []
    for n in sizes:
        w = rng.uniform(0.005, 0.25, n)
        t0 = time.perf_counter()
        fast_f = ffd(w, 0.5)
        t1 = time.perf_counter()
        fast_b = bfd(w, 0.5)
        t2 = time.perf_counter()
        if n <= 5_000:      # the O(n^2) scans get slow quickly
            ref_f = ffd_reference(w, 0.5)
            t3 = time.perf_counter()
            ref_b = bfd_reference(w, 0.5)
            t4 = time.perf_counter()
            assert fast_f == ref_f and fast_b == ref_b, "packers diverged"
            ref_ffd_ms, ref_bfd_ms = (t3 - t2) * 1e3, (t4 - t3) * 1e3
        else:
            ref_ffd_ms = ref_bfd_ms = None
        rows.append(dict(n=n, bins=len(fast_f),
                         ffd_ms=(t1 - t0) * 1e3, bfd_ms=(t2 - t1) * 1e3,
                         ref_ffd_ms=ref_ffd_ms, ref_bfd_ms=ref_bfd_ms))
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r['mode']:18s} efficiency={r['token_efficiency']:.4f} "
              f"rows={r['rows_consumed']}")
    gain = rows[0]["token_efficiency"] / max(rows[1]["token_efficiency"],
                                             1e-9)
    print(f"packing gain: {gain:.2f}x useful tokens per row")
    print("\npacker microbenchmark (fast vs reference, identical bins):")
    for r in run_packers():
        ref = (f" | reference ffd={r['ref_ffd_ms']:8.1f}ms "
               f"bfd={r['ref_bfd_ms']:8.1f}ms"
               if r["ref_ffd_ms"] is not None else " | reference skipped")
        print(f"  n={r['n']:6d} bins={r['bins']:5d} "
              f"ffd={r['ffd_ms']:7.1f}ms bfd={r['bfd_ms']:7.1f}ms{ref}")
    return rows


if __name__ == "__main__":
    main()
