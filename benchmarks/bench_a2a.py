"""Benchmark: A2A mapping-schema algorithms vs the paper's Table 1.

For each algorithm and input profile we report measured communication cost,
reducer count, the paper's lower/upper bounds, and the achieved ratio.
This is the faithful-reproduction validation: measured costs must sit
between the lower bound and the paper's upper bound for that algorithm.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    a2a_algk_comm_upper_bound,
    a2a_comm_lower_bound,
    a2a_k2_comm_upper_bound,
    a2a_unit_comm_lower_bound,
    big_input_comm_upper_bound,
    plan_a2a,
    plan_unit,
    unit_schemas as us,
)


def _row(name, comm, lb, ub, reducers, extra=""):
    ratio = comm / lb if lb else float("nan")
    return dict(case=name, comm=round(comm, 2), lower=round(lb, 2),
                upper=(round(ub, 2) if ub else None),
                ratio_to_lb=round(ratio, 3), reducers=reducers, extra=extra)


def profiles(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "uniform_small(m=64,w<=q/4)": rng.uniform(0.02, 0.25, 64),
        "mixed(m=48,w<=q/2)": rng.uniform(0.05, 0.5, 48),
        "heavy_tail(m=80)": np.clip(rng.lognormal(-2.5, 0.8, 80), 0.01, 0.5),
        "one_big(m=40)": np.concatenate([[0.62], rng.uniform(0.02, 0.2, 39)]),
        "paper_example4(m=7)": np.array(
            [0.20, 0.20, 0.20, 0.19, 0.19, 0.18, 0.18]),
    }


def run(q: float = 1.0):
    rows = []
    # ---- unit-size optimal constructions vs exact lower bounds
    for p in (3, 5, 7):
        reds = us.au_square(p)
        comm = sum(len(r) for r in reds)
        lb = a2a_unit_comm_lower_bound(p * p, p)
        rows.append(_row(f"AU q={p} m={p * p}", comm, lb, lb, len(reds),
                         "optimal: meets LB exactly"))
    for p in (3, 5):
        reds = us.au_projective(p)
        n = p * p + p + 1
        comm = sum(len(r) for r in reds)
        lb = n * (n - 1) // p
        rows.append(_row(f"projective q={p + 1} m={n}", comm, lb, lb,
                         len(reds), "optimal"))
    n = 16
    teams = us.round_robin_teams(n)
    comm = 2 * sum(len(t) for t in teams)
    rows.append(_row(f"q=2 teams m={n}", comm,
                     a2a_unit_comm_lower_bound(n, 2),
                     a2a_unit_comm_lower_bound(n, 2),
                     sum(len(t) for t in teams), "optimal"))
    for (nn, k) in [(40, 5), (64, 8), (81, 3)]:
        reds, name = plan_unit(nn, k)
        comm = sum(len(r) for r in reds)
        lb = a2a_unit_comm_lower_bound(nn, k)
        rows.append(_row(f"unit m={nn} q={k} [{name}]", comm, lb, None,
                         len(reds)))

    # ---- different-sized inputs through the planner
    for pname, w in profiles().items():
        lb = a2a_comm_lower_bound(w, q)
        s = float(np.sum(w))
        t0 = time.perf_counter()
        best = plan_a2a(w, q, method="auto")
        dt = time.perf_counter() - t0
        best.validate("a2a")
        if np.max(w) > q / 2:
            ub = big_input_comm_upper_bound(w, q)
            ub_name = "Thm24"
        else:
            ub = a2a_k2_comm_upper_bound(w, q)
            ub_name = "Thm10(4s²/q)"
        rows.append(_row(
            f"auto::{pname}", best.communication_cost(), lb, ub,
            best.num_reducers,
            f"algo={best.algorithm} plan_time={dt * 1e3:.1f}ms ub={ub_name}"))
        # paper's fixed k=2 strategy for comparison (when applicable)
        if np.max(w) <= q / 2:
            k2 = plan_a2a(w, q, method="binpack-k2")
            k2.validate("a2a")
            rows.append(_row(
                f"  paper-k2::{pname}", k2.communication_cost(), lb,
                a2a_k2_comm_upper_bound(w, q), k2.num_reducers,
                "paper's Section 4.1 choice"))
    return rows


def main():
    rows = run()
    bad = 0
    print(f"{'case':42s} {'comm':>10s} {'LB':>9s} {'UB':>10s} "
          f"{'c/LB':>6s} {'reducers':>8s}  notes")
    for r in rows:
        ub = r["upper"]
        ok = (r["comm"] >= r["lower"] - 1e-6 and
              (ub is None or r["comm"] <= ub + 1e-6))
        bad += (not ok)
        print(f"{r['case']:42s} {r['comm']:10.2f} {r['lower']:9.2f} "
              f"{(f'{ub:10.2f}' if ub else '         -')} "
              f"{r['ratio_to_lb']:6.3f} {r['reducers']:8d}  "
              f"{'' if ok else '** OUT OF BOUNDS ** '}{r['extra']}")
    print(f"\n{len(rows)} cases, {bad} out of bounds")
    return rows


if __name__ == "__main__":
    main()
