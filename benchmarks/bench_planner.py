"""Benchmark: estimate-all/build-one planner vs materialize-everything.

The seed planner's ``method='auto'`` portfolio built *every* applicable
candidate schema (every feasible k, the hybrid) and kept the argmin by
measured communication cost.  The strategy-registry planner estimates every
candidate with an exact closed form and builds only the winner.  This
benchmark shows:

  * the speedup curve over n (same winning cost, one build instead of many);
  * against the *seed-faithful* baseline (O(n^2) reference packing +
    per-reducer set-based cost measurement, exactly the seed hot path) and
    against a *modernized* materialize-everything baseline that already
    benefits from this PR's fast packing and vectorized costing;
  * cost parity on the paper's case profiles: the estimate-based planner
    must return schemas of identical (or lower) cost;
  * the PlanCache hit path (repeat traffic, e.g. a serving tier planning
    the same size profile per wave).

Run:  PYTHONPATH=src python benchmarks/bench_planner.py [--quick]
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import PLAN_CACHE, plan_a2a, plan_a2a_materialized
from repro.core.binpack import bfd_reference, ffd_reference
from repro.core.schema import MappingSchema
from repro.core.strategies import A2AProfile, a2a_portfolio


# ---------------------------------------------------------------------------
# seed-faithful baseline: reference packing, build everything, set-based cost
# ---------------------------------------------------------------------------
def _seed_cost(s: MappingSchema) -> float:
    """The seed's communication_cost: python sets per reducer."""
    total = 0.0
    for red in s.reducers:
        ids: set[int] = set()
        for b in red:
            ids.update(s.bins[b])
        total += sum(s.weights[i] for i in ids)
    return total


def plan_seed_portfolio(w: np.ndarray, q: float) -> MappingSchema:
    """Materialize every candidate the way the seed did: O(n^2) FFD/BFD,
    build each schema, measure each with per-reducer set expansion."""
    prof = A2AProfile(w, q)
    for k in range(2, prof.kmax + 1):
        b = q / k
        if prof.wmax > b + 1e-12:
            continue
        fa, fb = ffd_reference(w, b), bfd_reference(w, b)
        bins = fa if len(fa) <= len(fb) else fb
        bw = np.array([float(np.sum(w[np.asarray(x)])) for x in bins])
        prof._packs[k] = (bins, bw)
    cands = [strat.build(prof) for strat, _ in a2a_portfolio(prof)]
    assert cands
    return min(cands, key=_seed_cost)


# ---------------------------------------------------------------------------
# profiles
# ---------------------------------------------------------------------------
def scale_profile(n: int, seed: int = 0) -> np.ndarray:
    """Many small inputs (w <= q/10): the planning-throughput regime where
    the portfolio has ~9 applicable k values and candidate schemas run to
    ~10^6 reducers each — the regime where materializing losers hurts."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0.02, 0.1, n)


def paper_profiles(seed: int = 0) -> dict[str, np.ndarray]:
    """The case profiles of benchmarks/bench_a2a.py (paper Sections 4-9)."""
    rng = np.random.default_rng(seed)
    return {
        "uniform_small(m=64,w<=q/4)": rng.uniform(0.02, 0.25, 64),
        "mixed(m=48,w<=q/2)": rng.uniform(0.05, 0.5, 48),
        "heavy_tail(m=80)": np.clip(rng.lognormal(-2.5, 0.8, 80), 0.01, 0.5),
        "one_big(m=40)": np.concatenate([[0.62], rng.uniform(0.02, 0.2, 39)]),
        "paper_example4(m=7)": np.array(
            [0.20, 0.20, 0.20, 0.19, 0.19, 0.18, 0.18]),
    }


# ---------------------------------------------------------------------------
# measurements
# ---------------------------------------------------------------------------
def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    return out, time.perf_counter() - t0


def run_speedup_curve(sizes=(1_000, 3_000, 10_000), q: float = 1.0,
                      with_seed_baseline: bool = True):
    rows = []
    for n in sizes:
        w = scale_profile(n)
        PLAN_CACHE.clear()
        fast, t_fast = _timed(plan_a2a, w, q)
        _, t_hit = _timed(plan_a2a, w, q)          # cache-hit path
        modern, t_modern = _timed(plan_a2a_materialized, w, q)
        c_fast = fast.communication_cost()
        assert c_fast <= modern.communication_cost() + 1e-9
        row = dict(n=n, algo=fast.algorithm,
                   candidates=len(fast.meta.get("portfolio", {})),
                   comm=c_fast, gap=fast.optimality_gap(),
                   t_fast=t_fast, t_hit=t_hit, t_modern=t_modern,
                   speedup_vs_modern=t_modern / max(t_fast, 1e-12))
        if with_seed_baseline:
            seed_schema, t_seed = _timed(plan_seed_portfolio, w, q)
            assert c_fast <= _seed_cost(seed_schema) + 1e-9
            row["t_seed"] = t_seed
            row["speedup_vs_seed"] = t_seed / max(t_fast, 1e-12)
        rows.append(row)
    return rows


def run_cost_parity(q: float = 1.0):
    """On the paper's case profiles the estimate-based planner must match
    the materialized argmin cost exactly (or beat it: unit-strategy
    selection is weighted here)."""
    rows = []
    for name, w in paper_profiles().items():
        PLAN_CACHE.clear()
        fast, t_fast = _timed(plan_a2a, w, q)
        slow, t_slow = _timed(plan_seed_portfolio, w, q) \
            if float(np.max(w)) <= q / 2 else _timed(plan_a2a_materialized, w, q)
        c_fast, c_slow = fast.communication_cost(), _seed_cost(slow)
        rows.append(dict(case=name, algo=fast.algorithm,
                         comm_fast=c_fast, comm_materialized=c_slow,
                         equal_or_lower=bool(c_fast <= c_slow + 1e-9),
                         gap=fast.optimality_gap(),
                         t_fast=t_fast, t_materialized=t_slow))
    return rows


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    sizes = (1_000, 3_000) if quick else (1_000, 3_000, 10_000)

    print("== cost parity on the paper's case profiles ==")
    parity = run_cost_parity()
    ok = True
    for r in parity:
        ok &= r["equal_or_lower"]
        print(f"{r['case']:28s} {r['algo']:28s} "
              f"comm={r['comm_fast']:9.2f} vs materialized="
              f"{r['comm_materialized']:9.2f} "
              f"gap={r['gap']:5.2f} "
              f"[{'OK' if r['equal_or_lower'] else 'WORSE'}]")
    assert ok, "estimate-based planner returned a costlier schema"

    print("\n== estimate-vs-build speedup curve "
          "(scale profile, w <= q/10) ==")
    hdr = (f"{'n':>7s} {'cands':>5s} {'winner':24s} {'build-one':>10s} "
           f"{'cache-hit':>10s} {'modernized':>11s} {'seed':>9s} "
           f"{'x modern':>9s} {'x seed':>8s}")
    print(hdr)
    curve = run_speedup_curve(sizes)
    for r in curve:
        print(f"{r['n']:7d} {r['candidates']:5d} {r['algo']:24s} "
              f"{r['t_fast']*1e3:9.1f}ms {r['t_hit']*1e3:9.2f}ms "
              f"{r['t_modern']*1e3:10.1f}ms "
              f"{r.get('t_seed', float('nan'))*1e3:8.1f}ms "
              f"{r['speedup_vs_modern']:8.1f}x "
              f"{r.get('speedup_vs_seed', float('nan')):7.1f}x")
    top = curve[-1]
    if not quick:
        assert top["n"] == 10_000
        assert top["speedup_vs_seed"] >= 5.0, (
            f"speedup vs seed portfolio at n=10k is only "
            f"{top['speedup_vs_seed']:.1f}x (need >= 5x)")
        print(f"\nn=10_000: {top['speedup_vs_seed']:.1f}x faster than the "
              f"seed materialize-everything portfolio "
              f"({top['speedup_vs_modern']:.1f}x vs the modernized one), "
              f"identical winning cost.")
    return dict(parity=parity, curve=curve)


if __name__ == "__main__":
    main()
