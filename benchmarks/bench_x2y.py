"""Benchmark: X2Y mapping schemas vs Theorems 25 (LB) and 26 (UB)."""

from __future__ import annotations

import numpy as np

from repro.core import plan_x2y, x2y_comm_lower_bound, x2y_comm_upper_bound


def run(q: float = 1.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    cases = {
        "balanced(30x30)": (rng.uniform(0.05, 0.45, 30),
                            rng.uniform(0.05, 0.45, 30)),
        "skew_join(200x8)": (rng.uniform(0.01, 0.1, 200),
                             rng.uniform(0.2, 0.45, 8)),
        "tiny_y(60x3)": (rng.uniform(0.05, 0.3, 60),
                         rng.uniform(0.3, 0.5, 3)),
        "uniform(50x20)": (np.full(50, 0.2), np.full(20, 0.25)),
    }
    rows = []
    for name, (wx, wy) in cases.items():
        s = plan_x2y(wx, wy, q)
        s.validate("x2y", x_ids=range(len(wx)),
                   y_ids=range(len(wx), len(wx) + len(wy)))
        lb = x2y_comm_lower_bound(wx, wy, q)
        ub = x2y_comm_upper_bound(wx, wy, q / 2)
        comm = s.communication_cost()
        rows.append(dict(case=name, comm=round(comm, 2), lower=round(lb, 2),
                         upper=round(ub, 2),
                         ratio=round(comm / lb, 3),
                         reducers=s.num_reducers, algo=s.algorithm))
    return rows


def main():
    rows = run()
    print(f"{'case':20s} {'comm':>9s} {'LB':>9s} {'UB':>9s} {'c/LB':>6s} "
          f"{'reducers':>8s}  algo")
    bad = 0
    for r in rows:
        ok = r["lower"] - 1e-6 <= r["comm"] <= r["upper"] + 1e-6
        bad += not ok
        print(f"{r['case']:20s} {r['comm']:9.2f} {r['lower']:9.2f} "
              f"{r['upper']:9.2f} {r['ratio']:6.3f} {r['reducers']:8d}  "
              f"{r['algo']}{'' if ok else '  ** OUT OF BOUNDS **'}")
    print(f"\n{len(rows)} cases, {bad} out of bounds")
    return rows


if __name__ == "__main__":
    main()
