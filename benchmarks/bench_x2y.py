"""Benchmark: X2Y mapping schemas vs Theorems 25 (LB) and 26 (UB), plus
rectangular execution timing across every registry executor.

Two sections:

* ``run``       — schema-level: planner cost vs the paper's bounds.
* ``run_executors`` — execution-level: ``x2y_similarity`` through each
  registry executor on the Example-3-shaped ``skew_join(200x8)`` profile
  and the ``balanced(30x30)`` profile, asserting allclose vs dense and
  recording median wall times.

``main`` prints both tables and merges the machine-readable payload into
``benchmarks/BENCH_x2y.json`` (same accumulate-don't-clobber contract as
``BENCH_engine.json``; read by CI across PRs).
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import plan_x2y, x2y_comm_lower_bound, x2y_comm_upper_bound

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_x2y.json")

EXEC_CASES = ("skew_join(200x8)", "balanced(30x30)")


def _cases(q: float, seed: int):
    rng = np.random.default_rng(seed)
    return {
        "balanced(30x30)": (rng.uniform(0.05, 0.45, 30),
                            rng.uniform(0.05, 0.45, 30)),
        "skew_join(200x8)": (rng.uniform(0.01, 0.1, 200),
                             rng.uniform(0.2, 0.45, 8)),
        "tiny_y(60x3)": (rng.uniform(0.05, 0.3, 60),
                         rng.uniform(0.3, 0.5, 3)),
        "uniform(50x20)": (np.full(50, 0.2), np.full(20, 0.25)),
    }


def run(q: float = 1.0, seed: int = 0):
    rows = []
    for name, (wx, wy) in _cases(q, seed).items():
        s = plan_x2y(wx, wy, q)
        s.validate("x2y", x_ids=range(len(wx)),
                   y_ids=range(len(wx), len(wx) + len(wy)))
        lb = x2y_comm_lower_bound(wx, wy, q)
        ub = x2y_comm_upper_bound(wx, wy, q / 2)
        comm = s.communication_cost()
        rows.append(dict(case=name, comm=round(comm, 2), lower=round(lb, 2),
                         upper=round(ub, 2),
                         ratio=round(comm / lb, 3),
                         reducers=s.num_reducers, algo=s.algorithm))
    return rows


def run_executors(q: float = 1.0, d: int = 16, seed: int = 0,
                  repeats: int = 3):
    """Time every registry executor's rectangular path on the skewed and
    balanced X2Y profiles; assert each matches the dense execution."""
    import jax
    import jax.numpy as jnp

    import repro.stream  # noqa: F401  registers the streaming executor
    from repro.mapreduce import x2y_similarity
    from repro.mapreduce.executors import list_executors

    rng = np.random.default_rng(seed)
    cases = _cases(q, seed)
    rows = []
    for case in EXEC_CASES:
        wx, wy = cases[case]
        mx, my = len(wx), len(wy)
        x = jnp.asarray(rng.normal(size=(mx, d)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(my, d)).astype(np.float32))
        schema = plan_x2y(wx, wy, q)
        ref, _, _ = x2y_similarity(x, y, q=q, schema=schema,
                                   executor="dense")
        ref = np.asarray(ref)
        for executor in list_executors():
            sims = None
            for _ in range(2):                       # warmup / compile
                sims, plan, _ = x2y_similarity(
                    x, y, q=q, schema=schema, executor=executor)
                jax.block_until_ready(sims)
            times = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                out, _, _ = x2y_similarity(
                    x, y, q=q, schema=schema, executor=executor)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
            allclose = bool(np.allclose(np.asarray(sims), ref,
                                        rtol=1e-4, atol=1e-4))
            rows.append(dict(
                case=case, executor=executor,
                shape=[mx, my], reducers=plan.num_reducers,
                wall_ms=round(float(np.median(times)) * 1e3, 2),
                allclose=allclose))
    return rows


def main():
    rows = run()
    print(f"{'case':20s} {'comm':>9s} {'LB':>9s} {'UB':>9s} {'c/LB':>6s} "
          f"{'reducers':>8s}  algo")
    bad = 0
    for r in rows:
        ok = r["lower"] - 1e-6 <= r["comm"] <= r["upper"] + 1e-6
        bad += not ok
        print(f"{r['case']:20s} {r['comm']:9.2f} {r['lower']:9.2f} "
              f"{r['upper']:9.2f} {r['ratio']:6.3f} {r['reducers']:8d}  "
              f"{r['algo']}{'' if ok else '  ** OUT OF BOUNDS **'}")
    print(f"\n{len(rows)} cases, {bad} out of bounds")

    erows = run_executors()
    print(f"\n{'case':20s} {'executor':10s} {'wall_ms':>8s} {'reducers':>8s}"
          f"  allclose")
    for r in erows:
        print(f"{r['case']:20s} {r['executor']:10s} {r['wall_ms']:8.2f} "
              f"{r['reducers']:8d}  {r['allclose']}"
              f"{'' if r['allclose'] else '  ** MISMATCH **'}")

    try:
        from bench_common import emit_bench_json
    except ImportError:
        from benchmarks.bench_common import emit_bench_json
    emit_bench_json({"x2y_bounds": rows, "x2y_executors": erows},
                    BENCH_JSON)
    return rows + erows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
    main()
