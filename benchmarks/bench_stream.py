"""Benchmark: streaming mapping-schema maintenance vs full re-planning.

The static planner pays a full re-plan and a full re-shuffle for *any*
change to the input list; the streaming subsystem (``repro.stream``) pays
only for the reducers one edit dirties.  This bench measures that claim on
the Zipf m=512 skewed workload across edit rates:

  * update latency   — wall time of one streamed edit (planner repair +
    dirty-reducer recompute + matrix patch) vs a cold full re-plan +
    rebuild of the same table;
  * recompute fraction — dirty reducers over total reducers per edit
    (acceptance bar: single-input edits < 25% on Zipf m=512);
  * delta vs re-plan comm bytes — weighted rows the delta ships vs what a
    full re-shuffle ships, next to the replication-rate lower bound;
  * correctness — after every edit batch the streamed matrix must be
    allclose to a cold full re-plan on the dense executor, and the
    maintained schema must pass validate('a2a') conformance.

Writes the machine-readable trajectory to the repo root
(``BENCH_stream.json``); ``benchmarks/run.py`` runs it as the
``bench_stream`` section.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "..", "BENCH_stream.json")


def _make_table(m: int, d: int, q: float, zipf_a: float, seed: int):
    rng = np.random.default_rng(seed)
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = rng.normal(size=(m, d)).astype(np.float32)
    return rng, w, x


def _cold_reference(table, planner, q, repeats: int = 1):
    """Cold full re-plan + dense rebuild of the live table: the oracle the
    streamed matrix must match, and the latency a static planner pays per
    edit.  Plans with ``use_cache=False`` so the timing includes the
    planning work an unseen profile costs."""
    from repro.core import plan_a2a
    from repro.mapreduce import pairwise_similarity

    act = planner.active_ids()
    xa = jnp.asarray(table[act])
    wa = planner.active_weights()
    times, sims = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        schema = plan_a2a(wa, q, use_cache=False)
        sims, _, _ = pairwise_similarity(xa, q=q, weights=wa, schema=schema,
                                         executor="dense")
        sims = jax.block_until_ready(sims)
        times.append(time.perf_counter() - t0)
    return np.asarray(sims), act, float(np.median(times))


def run_stream(m: int = 512, d: int = 64, q: float = 1.0,
               zipf_a: float = 1.6, seed: int = 0,
               edit_rates=(1, 16, 64)) -> dict:
    from repro.serve import PairwiseService

    rng, w, x = _make_table(m, d, q, zipf_a, seed)
    svc = PairwiseService(q, executor="streaming")

    t0 = time.perf_counter()
    sims, info0 = svc.load_table(x, w)
    cold_s = time.perf_counter() - t0

    planner = svc._planner
    rates = []
    itemsize = np.dtype(np.float32).itemsize
    for n_edits in edit_rates:
        lat, fracs, dirty, replans = [], [], 0, 0
        delta_rows, replan_rows = 0.0, 0.0
        insert_fracs = []
        for _ in range(int(n_edits)):
            op = rng.choice(["insert", "delete", "reweight"],
                            p=[0.6, 0.25, 0.15])
            act = planner.active_ids()
            if op == "insert" or len(act) < 3:
                sims, info = svc.add_input(
                    rng.normal(size=(1, d)).astype(np.float32),
                    float(np.clip(rng.zipf(zipf_a) / 32.0,
                                  0.01, 0.45 * q)))
                insert_fracs.append(info["recompute_fraction"])
            elif op == "delete":
                sims, info = svc.remove_input(int(rng.choice(act)))
            else:
                sims, info = svc.update_weight(
                    int(rng.choice(act)),
                    float(np.clip(rng.zipf(zipf_a) / 32.0, 0.01, 0.45 * q)))
            lat.append(info["wall_s"])
            fracs.append(info["recompute_fraction"])
            dirty += info["dirty_reducers"]
            replans += int(info["full_replan"])
            delta_rows += info["delta_comm_rows"]
            replan_rows += info["comm_cost"]

        # correctness at the batch boundary: allclose to a cold full
        # re-plan on the dense executor + schema conformance
        ref, act, replan_s = _cold_reference(svc._table, planner, q)
        got = np.asarray(sims)[np.ix_(act, act)]
        allclose = bool(np.allclose(got, ref, rtol=1e-4, atol=1e-4))
        snap = planner.snapshot()
        snap.validate("a2a")
        conform = bool(
            snap.communication_cost() >= planner.lower_bound - 1e-9)

        rates.append({
            "edits": int(n_edits),
            "update_ms_median": round(float(np.median(lat)) * 1e3, 2),
            "update_ms_mean": round(float(np.mean(lat)) * 1e3, 2),
            "full_replan_ms": round(replan_s * 1e3, 2),
            "speedup_vs_replan": round(
                replan_s / max(float(np.median(lat)), 1e-12), 2),
            "recompute_fraction_mean": round(float(np.mean(fracs)), 4),
            "recompute_fraction_max": round(float(np.max(fracs)), 4),
            "insert_recompute_fraction_mean": round(
                float(np.mean(insert_fracs)), 4) if insert_fracs else None,
            "dirty_reducers": int(dirty),
            "replans": int(replans),
            "delta_comm_bytes": int(delta_rows * d * itemsize),
            "replan_comm_bytes": int(replan_rows * d * itemsize),
            "delta_vs_replan_bytes": round(
                delta_rows / max(replan_rows, 1e-12), 4),
            "allclose": allclose,
            "conformance": conform,
        })

    lb_bytes = planner.lower_bound * d * itemsize
    return {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a, "seed": seed,
        "algorithm": info0["algorithm"],
        "reducers_initial": info0["reducers"],
        "cold_build_ms": round(cold_s * 1e3, 1),
        "optimality_gap_final": round(planner.optimality_gap, 4),
        "lower_bound_bytes_final": int(lb_bytes),
        "edit_rates": rates,
        "planner_stats": dict(planner.stats),
        "executor_stats": svc.executor_stats(),
    }


def emit_bench_json(payload: dict, path: str = BENCH_JSON) -> str:
    """Merge ``payload`` into the repo-root BENCH_stream.json (sections
    accumulate across runs, like benchmarks/BENCH_engine.json)."""
    existing = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = {}
    existing.update(payload)
    with open(path, "w") as f:
        json.dump(existing, f, indent=1, sort_keys=True)
        f.write("\n")
    return os.path.abspath(path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--zipf-a", type=float, default=1.6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--edits", type=int, nargs="*", default=[1, 16, 64])
    args = ap.parse_args(argv)

    rep = run_stream(m=args.m, d=args.d, zipf_a=args.zipf_a, seed=args.seed,
                     edit_rates=tuple(args.edits))
    print(f"stream A2A  m={rep['m']} d={rep['d']} zipf_a={rep['zipf_a']} "
          f"[{rep['algorithm']}] reducers={rep['reducers_initial']} "
          f"cold={rep['cold_build_ms']:.0f}ms")
    for r in rep["edit_rates"]:
        print(f"  edits={r['edits']:3d} update={r['update_ms_median']:7.1f}ms"
              f" (replan {r['full_replan_ms']:7.1f}ms, "
              f"{r['speedup_vs_replan']:.1f}x) "
              f"recompute={r['recompute_fraction_mean']:.3f} "
              f"delta/replan bytes={r['delta_vs_replan_bytes']:.3f} "
              f"replans={r['replans']} allclose={r['allclose']} "
              f"conform={r['conformance']}")
    path = emit_bench_json({"stream_edits": rep})
    print(f"  wrote {path}")

    for r in rep["edit_rates"]:
        if not r["allclose"]:
            raise SystemExit("FAIL: streamed matrix diverges from the cold "
                             "full re-plan")
        if not r["conformance"]:
            raise SystemExit("FAIL: maintained schema under-ships the "
                             "lower bound")
        frac = r["insert_recompute_fraction_mean"]
        if frac is not None and frac >= 0.25:
            raise SystemExit(
                f"FAIL: single-input edits recompute {frac:.3f} of "
                f"reducers (bar: < 0.25)")
    return rep


if __name__ == "__main__":
    main()
