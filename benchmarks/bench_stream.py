"""Benchmark: streaming mapping-schema maintenance vs full re-planning.

The static planner pays a full re-plan and a full re-shuffle for *any*
change to the input list; the streaming subsystem (``repro.stream``) pays
only for the reducers one edit dirties.  This bench measures that claim on
the Zipf m=512 skewed workload across edit rates:

  * first-edit (cold) latency — the edit right after ``load_table``,
    sampled over fresh services, reported *separately* from steady state
    (it used to hide inside the mean, skewing it 2x above the median);
    with AOT delta-shape warmup the bar is p99 < 200ms;
  * update latency   — wall time of one streamed edit (planner repair +
    dirty-reducer recompute + matrix patch) vs a cold full re-plan +
    rebuild of the same table;
  * recompute fraction — dirty reducers over total reducers per edit
    (acceptance bar: single-input edits < 25% on Zipf m=512);
  * sustained gap — the *achievable* optimality gap (cost over the
    binpack strategy bound of Thm 9 — the Thm-8 bound is ~2x loose for
    binpack-k2, which is what killed the old drift trigger) must stay
    <= 1.3x through the churn and through a deletion-heavy shrink phase
    that exercises the repack / drift-replan machinery;
  * correctness — after every edit batch the streamed matrix must be
    allclose to a cold full re-plan on the dense executor, and the
    maintained schema must pass validate('a2a') conformance.

Writes the machine-readable trajectory to ``benchmarks/BENCH_stream.json``
(next to BENCH_engine.json / BENCH_x2y.json); ``benchmarks/run.py`` runs
it as the ``bench_stream`` section.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

try:                                    # run as a script from benchmarks/
    from bench_common import emit_bench_json as _emit_bench_json
except ImportError:                     # imported as a package module
    from benchmarks.bench_common import emit_bench_json as _emit_bench_json

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_stream.json")

# planner thresholds the bench (and its bars) run with: a hard achievable-
# gap ceiling well under the 1.3x bar, a soft repack threshold just above
# a fresh plan's own gap, and background (double-buffered) re-plans
MAX_GAP = 1.2
REPACK_GAP = 1.03


def _make_table(m: int, d: int, q: float, zipf_a: float, seed: int):
    rng = np.random.default_rng(seed)
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = rng.normal(size=(m, d)).astype(np.float32)
    return rng, w, x


def _load(x, w, q: float, *, warmup: bool = True):
    from repro.serve import PairwiseService
    svc = PairwiseService(q, executor="streaming")
    t0 = time.perf_counter()
    _, info = svc.load_table(x, w, max_gap=MAX_GAP, repack_gap=REPACK_GAP,
                             background=True, warmup=warmup)
    return svc, info, time.perf_counter() - t0


def _cold_reference(table, planner, q, repeats: int = 1):
    """Cold full re-plan + dense rebuild of the live table: the oracle the
    streamed matrix must match, and the latency a static planner pays per
    edit.  Plans with ``use_cache=False`` so the timing includes the
    planning work an unseen profile costs."""
    from repro.core import plan_a2a
    from repro.mapreduce import pairwise_similarity

    act = planner.active_ids()
    xa = jnp.asarray(table[act])
    wa = planner.active_weights()
    times, sims = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        schema = plan_a2a(wa, q, use_cache=False)
        sims, _, _ = pairwise_similarity(xa, q=q, weights=wa, schema=schema,
                                         executor="dense")
        sims = jax.block_until_ready(sims)
        times.append(time.perf_counter() - t0)
    return np.asarray(sims), act, float(np.median(times))


def _check_batch(svc, sims) -> dict:
    """Batch-boundary correctness: allclose vs a cold re-plan on the dense
    executor, schema conformance, and the gap telemetry the bars read."""
    planner = svc._planner
    ref, act, replan_s = _cold_reference(svc._table, planner, svc.q)
    got = np.asarray(sims)[np.ix_(act, act)]
    snap = planner.snapshot()
    snap.validate("a2a")
    return {
        "allclose": bool(np.allclose(got, ref, rtol=1e-4, atol=1e-4)),
        "conformance": bool(
            snap.communication_cost() >= planner.lower_bound - 1e-9),
        "replan_s": replan_s,
        "optimality_gap_thm8": round(float(planner.optimality_gap), 4),
        "achievable_gap": round(float(planner.achievable_gap), 4),
    }


def bench_first_edit(m: int, d: int, q: float, zipf_a: float, seed: int,
                     samples: int = 3) -> dict:
    """The edit right after ``load_table``, on fresh services: the cold
    tail the AOT warmup exists to kill.  p99 over a handful of fresh
    services is the max sample."""
    lat = []
    warmed = 0
    for s in range(samples):
        rng, w, x = _make_table(m, d, q, zipf_a, seed + 101 + s)
        svc, info0, _ = _load(x, w, q, warmup=True)
        warmed = info0["warmed_shapes"]
        _, info = svc.add_input(
            rng.normal(size=(1, d)).astype(np.float32),
            float(np.clip(rng.zipf(zipf_a) / 32.0, 0.01, 0.45 * q)))
        lat.append(info["wall_s"])
    # one unwarmed sample for the before/after story
    rng, w, x = _make_table(m, d, q, zipf_a, seed + 97)
    svc, _, _ = _load(x, w, q, warmup=False)
    _, info = svc.add_input(
        rng.normal(size=(1, d)).astype(np.float32),
        float(np.clip(rng.zipf(zipf_a) / 32.0, 0.01, 0.45 * q)))
    return {
        "samples": samples,
        "warmed_shapes": int(warmed),
        "first_edit_ms_p99": round(float(np.max(lat)) * 1e3, 2),
        "first_edit_ms_median": round(float(np.median(lat)) * 1e3, 2),
        "first_edit_ms_nowarm": round(info["wall_s"] * 1e3, 2),
    }


def run_stream(m: int = 512, d: int = 64, q: float = 1.0,
               zipf_a: float = 1.6, seed: int = 0,
               edit_rates=(1, 16, 64)) -> dict:
    rng, w, x = _make_table(m, d, q, zipf_a, seed)
    svc, info0, cold_s = _load(x, w, q)
    planner = svc._planner

    rates = []
    itemsize = np.dtype(np.float32).itemsize
    max_ach_gap = 0.0
    sims = None
    for n_edits in edit_rates:
        lat, fracs, dirty = [], [], 0
        delta_rows, replan_rows = 0.0, 0.0
        insert_fracs = []
        for _ in range(int(n_edits)):
            op = rng.choice(["insert", "delete", "reweight"],
                            p=[0.6, 0.25, 0.15])
            act = planner.active_ids()
            if op == "insert" or len(act) < 3:
                sims, info = svc.add_input(
                    rng.normal(size=(1, d)).astype(np.float32),
                    float(np.clip(rng.zipf(zipf_a) / 32.0,
                                  0.01, 0.45 * q)))
                insert_fracs.append(info["recompute_fraction"])
            elif op == "delete":
                sims, info = svc.remove_input(int(rng.choice(act)))
            else:
                sims, info = svc.update_weight(
                    int(rng.choice(act)),
                    float(np.clip(rng.zipf(zipf_a) / 32.0, 0.01, 0.45 * q)))
            lat.append(info["wall_s"])
            fracs.append(info["recompute_fraction"])
            dirty += info["dirty_reducers"]
            delta_rows += info["delta_comm_rows"]
            replan_rows += info["comm_cost"]

        check = _check_batch(svc, sims)
        max_ach_gap = max(max_ach_gap, check["achievable_gap"])
        rates.append({
            "edits": int(n_edits),
            "update_ms_median": round(float(np.median(lat)) * 1e3, 2),
            "update_ms_mean": round(float(np.mean(lat)) * 1e3, 2),
            "update_ms_p99": round(float(np.max(lat)) * 1e3, 2),
            "full_replan_ms": round(check["replan_s"] * 1e3, 2),
            "speedup_vs_replan": round(
                check["replan_s"] / max(float(np.median(lat)), 1e-12), 2),
            "recompute_fraction_mean": round(float(np.mean(fracs)), 4),
            "recompute_fraction_max": round(float(np.max(fracs)), 4),
            "insert_recompute_fraction_mean": round(
                float(np.mean(insert_fracs)), 4) if insert_fracs else None,
            "dirty_reducers": int(dirty),
            "delta_comm_bytes": int(delta_rows * d * itemsize),
            "replan_comm_bytes": int(replan_rows * d * itemsize),
            "delta_vs_replan_bytes": round(
                delta_rows / max(replan_rows, 1e-12), 4),
            "allclose": check["allclose"],
            "conformance": check["conformance"],
            "optimality_gap_thm8": check["optimality_gap_thm8"],
            "achievable_gap": check["achievable_gap"],
        })

    # ------------------------------------------------------- shrink phase
    # deletion-heavy churn empties bins and leaves stranded reducers — the
    # drift the repack / drift-replan machinery exists to absorb
    n_shrink = planner.num_active // 2
    shrink_lat = []
    for _ in range(n_shrink):
        act = planner.active_ids()
        if len(act) <= 4:
            break
        if rng.random() < 0.9:
            sims, info = svc.remove_input(int(rng.choice(act)))
        else:
            sims, info = svc.add_input(
                rng.normal(size=(1, d)).astype(np.float32),
                float(np.clip(rng.zipf(zipf_a) / 32.0, 0.01, 0.45 * q)))
        shrink_lat.append(info["wall_s"])
    check = _check_batch(svc, sims)
    max_ach_gap = max(max_ach_gap, check["achievable_gap"])
    shrink = {
        "edits": int(len(shrink_lat)),
        "update_ms_median": round(
            float(np.median(shrink_lat)) * 1e3, 2) if shrink_lat else None,
        "allclose": check["allclose"],
        "conformance": check["conformance"],
        "optimality_gap_thm8": check["optimality_gap_thm8"],
        "achievable_gap": check["achievable_gap"],
    }

    pstats = dict(planner.stats)
    return {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a, "seed": seed,
        "max_gap": MAX_GAP, "repack_gap": REPACK_GAP, "background": True,
        "algorithm": info0["algorithm"],
        "reducers_initial": info0["reducers"],
        "cold_build_ms": round(cold_s * 1e3, 1),
        "warmed_shapes": int(info0["warmed_shapes"]),
        "optimality_gap_thm8_final": round(planner.optimality_gap, 4),
        "achievable_gap_final": round(planner.achievable_gap, 4),
        "achievable_gap_max": round(max_ach_gap, 4),
        "lower_bound_bytes_final": int(
            planner.lower_bound * d * itemsize),
        "edit_rates": rates,
        "shrink": shrink,
        "drift_replans": int(pstats["drift_replans"]),
        "repacks": int(pstats["repacks"]),
        "swaps": int(pstats["swaps"]),
        "planner_stats": pstats,
        "executor_stats": svc.executor_stats(),
    }


def emit_bench_json(payload: dict, path: str = BENCH_JSON) -> str:
    """Merge ``payload`` into benchmarks/BENCH_stream.json (canonical
    implementation: bench_common.emit_bench_json)."""
    return _emit_bench_json(payload, path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--zipf-a", type=float, default=1.6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--edits", type=int, nargs="*", default=[1, 16, 64])
    args = ap.parse_args(argv)

    first = bench_first_edit(args.m, args.d, 1.0, args.zipf_a, args.seed)
    print(f"stream A2A  first edit after load_table "
          f"(warmed {first['warmed_shapes']} shapes): "
          f"p99={first['first_edit_ms_p99']:.1f}ms "
          f"median={first['first_edit_ms_median']:.1f}ms "
          f"(unwarmed: {first['first_edit_ms_nowarm']:.1f}ms)")

    rep = run_stream(m=args.m, d=args.d, zipf_a=args.zipf_a, seed=args.seed,
                     edit_rates=tuple(args.edits))
    rep["first_edit"] = first
    print(f"stream A2A  m={rep['m']} d={rep['d']} zipf_a={rep['zipf_a']} "
          f"[{rep['algorithm']}] reducers={rep['reducers_initial']} "
          f"cold={rep['cold_build_ms']:.0f}ms")
    for r in rep["edit_rates"]:
        print(f"  edits={r['edits']:3d} update={r['update_ms_median']:7.1f}ms"
              f" (replan {r['full_replan_ms']:7.1f}ms, "
              f"{r['speedup_vs_replan']:.1f}x) "
              f"recompute={r['recompute_fraction_mean']:.3f} "
              f"gap(ach)={r['achievable_gap']:.3f} "
              f"allclose={r['allclose']} conform={r['conformance']}")
    s = rep["shrink"]
    print(f"  shrink edits={s['edits']:3d} gap(ach)={s['achievable_gap']:.3f}"
          f" (thm8 {s['optimality_gap_thm8']:.3f}) "
          f"drift_replans={rep['drift_replans']} repacks={rep['repacks']} "
          f"swaps={rep['swaps']} allclose={s['allclose']} "
          f"conform={s['conformance']}")
    path = emit_bench_json({"stream_edits": rep})
    print(f"  wrote {path}")

    # ------------------------------------------------------- acceptance bars
    if first["first_edit_ms_p99"] >= 200.0:
        raise SystemExit(
            f"FAIL: first edit p99 {first['first_edit_ms_p99']:.1f}ms "
            f"(bar: < 200ms)")
    checks = rep["edit_rates"] + [rep["shrink"]]
    for r in checks:
        if not r["allclose"]:
            raise SystemExit("FAIL: streamed matrix diverges from the cold "
                             "full re-plan")
        if not r["conformance"]:
            raise SystemExit("FAIL: maintained schema under-ships the "
                             "lower bound")
    if rep["achievable_gap_max"] > 1.3:
        raise SystemExit(
            f"FAIL: sustained achievable gap {rep['achievable_gap_max']} "
            f"(bar: <= 1.3)")
    if rep["drift_replans"] + rep["repacks"] < 1:
        raise SystemExit("FAIL: churn triggered no drift replan and no "
                         "repack — the trigger is dead again")
    for r in rep["edit_rates"]:
        frac = r["insert_recompute_fraction_mean"]
        if frac is not None and frac >= 0.25:
            raise SystemExit(
                f"FAIL: single-input edits recompute {frac:.3f} of "
                f"reducers (bar: < 0.25)")
    return rep


if __name__ == "__main__":
    main()
