"""Benchmark harness: one function per paper table / claim.

  bench_a2a      — Table 1 (A2A bounds, optimal + approx algorithms)
  bench_x2y      — Table 1 X2Y rows (Thm 25/26)
  bench_engine   — schema comm vs naive replication, end-to-end engine
  bench_engine --fused — dense/bucketed/fused executor shootout on the
                   Zipf workload; emits benchmarks/BENCH_engine.json
                   (wall-clock, padded elements, HBM bytes per executor)
                   so the perf trajectory is machine-readable across PRs
  bench_engine --sharded — bucketed/fused/sharded shootout + LPT balance
                   report (per-shard padded elements, balance factor);
                   merges the engine_sharded section into
                   benchmarks/BENCH_engine.json
  bench_coded    — coded shuffle executor: replication-vs-communication
                   Pareto frontier on a forced 8-device mesh (assembly
                   bytes vs the uncoded sharded gather, Thm-8 LB check);
                   writes benchmarks/BENCH_coded.json
  bench_stream   — streaming-maintenance edits vs full re-planning
  bench_obs      — observability overhead bar: obs-on vs obs-off on the
                   fused Zipf m=512 serving path (< 5%)
                   (first-edit p99, update latency, recompute fraction,
                   sustained achievable gap, delta-vs-replan comm bytes
                   across edit rates on Zipf m=512); writes
                   benchmarks/BENCH_stream.json
  bench_packing  — FFD bins applied to the data pipeline
  bench_kernels  — Pallas kernels vs oracles

Prints ``name,us_per_call,derived`` CSV lines plus detailed tables; the
roofline table lives in benchmarks/roofline_report.py (reads dry-run JSON).
"""

from __future__ import annotations

import os
import subprocess
import sys
import time


def _bench_8dev(script_name: str, *args: str):
    """Run a bench script in a SUBPROCESS with a forced 8-device CPU
    mesh: XLA_FLAGS cannot change the device count of this already-
    initialized process, and a 1-device in-process run would overwrite the
    committed multi-device sections of the BENCH json with trivial
    numbers."""
    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          script_name)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
               PYTHONPATH="src" + (
                   os.pathsep + os.environ["PYTHONPATH"]
                   if os.environ.get("PYTHONPATH") else ""))
    res = subprocess.run([sys.executable, script, *args], env=env)
    if res.returncode != 0:
        raise SystemExit(
            f"{script_name} {' '.join(args)} failed ({res.returncode})")
    return [res]


def _bench_engine_sharded():
    return _bench_8dev("bench_engine.py", "--sharded")


def _bench_coded():
    return _bench_8dev("bench_coded.py")


def main() -> None:
    from benchmarks import bench_a2a, bench_engine, bench_kernels, \
        bench_obs, bench_packing, bench_stream, bench_x2y

    sections = [
        ("bench_a2a", bench_a2a.main),
        ("bench_x2y", bench_x2y.main),
        ("bench_engine", bench_engine.main),
        ("bench_engine_fused", lambda: [bench_engine.main(["--fused"])]),
        ("bench_engine_sharded", _bench_engine_sharded),
        ("bench_coded", _bench_coded),
        ("bench_stream", lambda: [bench_stream.main([])]),
        ("bench_obs", lambda: [bench_obs.main([])]),
        ("bench_packing", bench_packing.main),
        ("bench_kernels", bench_kernels.main),
    ]
    csv = []
    for name, fn in sections:
        print(f"\n===== {name} " + "=" * (60 - len(name)))
        t0 = time.perf_counter()
        rows = fn()
        dt = (time.perf_counter() - t0) * 1e6
        derived = len(rows) if rows is not None else 0
        csv.append(f"{name},{dt:.0f},{derived}")
    print("\n# name,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
