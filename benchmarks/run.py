"""Benchmark harness: one function per paper table / claim.

  bench_a2a      — Table 1 (A2A bounds, optimal + approx algorithms)
  bench_x2y      — Table 1 X2Y rows (Thm 25/26)
  bench_engine   — schema comm vs naive replication, end-to-end engine
  bench_engine --fused — dense/bucketed/fused executor shootout on the
                   Zipf workload; emits benchmarks/BENCH_engine.json
                   (wall-clock, padded elements, HBM bytes per executor)
                   so the perf trajectory is machine-readable across PRs
  bench_packing  — FFD bins applied to the data pipeline
  bench_kernels  — Pallas kernels vs oracles

Prints ``name,us_per_call,derived`` CSV lines plus detailed tables; the
roofline table lives in benchmarks/roofline_report.py (reads dry-run JSON).
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import bench_a2a, bench_engine, bench_kernels, \
        bench_packing, bench_x2y

    sections = [
        ("bench_a2a", bench_a2a.main),
        ("bench_x2y", bench_x2y.main),
        ("bench_engine", bench_engine.main),
        ("bench_engine_fused", lambda: [bench_engine.main(["--fused"])]),
        ("bench_packing", bench_packing.main),
        ("bench_kernels", bench_kernels.main),
    ]
    csv = []
    for name, fn in sections:
        print(f"\n===== {name} " + "=" * (60 - len(name)))
        t0 = time.perf_counter()
        rows = fn()
        dt = (time.perf_counter() - t0) * 1e6
        derived = len(rows) if rows is not None else 0
        csv.append(f"{name},{dt:.0f},{derived}")
    print("\n# name,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
