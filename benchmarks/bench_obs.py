"""Benchmark: observability overhead on the serving hot path.

Acceptance bar (ISSUE 10 / DESIGN.md 1j): the obs layer — per-request
metrics, spans, and the comm-ledger reconciler — must cost **< 5%** wall
time on the fused Zipf m=512 serving workload versus the same workload
with obs disabled (``repro.obs.configure(enabled=False)``, the global
kill switch that turns every publish into one attribute test).

Method: one warm-up request compiles the jit programs, then
``repeats`` timed requests per mode, medians compared, obs-on first so
a cold cache would hurt the obs side, not flatter it.  Alternating
A/B ordering across ``rounds`` absorbs thermal drift.

Writes ``benchmarks/BENCH_obs.json``; ``make bench-obs`` runs this and
fails CI when the bar breaks.
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

try:                                    # run as a script from benchmarks/
    from bench_common import emit_bench_json
except ImportError:                     # imported as a package module
    from benchmarks.bench_common import emit_bench_json

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_obs.json")

OVERHEAD_BAR = 0.05                     # < 5% obs-on vs obs-off


def _workload(m: int, d: int, q: float, zipf_a: float, seed: int):
    rng = np.random.default_rng(seed)
    w = np.clip(rng.zipf(zipf_a, m).astype(np.float64) / 32.0,
                0.01, 0.45 * q)
    x = rng.normal(size=(m, d)).astype(np.float32)
    return x, w


def _median_request_s(svc, x, w, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        svc.similarity(x, weights=w)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run_overhead(m: int = 512, d: int = 64, q: float = 1.0,
                 zipf_a: float = 1.6, seed: int = 0,
                 repeats: int = 5, rounds: int = 3) -> dict:
    import repro.obs as obs
    from repro.serve import PairwiseService

    x, w = _workload(m, d, q, zipf_a, seed)
    svc = PairwiseService(q, executor="fused")
    svc.similarity(x, weights=w)        # compile warm-up (both modes share)

    on_meds, off_meds = [], []
    prior = obs.enabled()
    try:
        for r in range(rounds):
            # alternate A/B order so drift hits both sides equally
            modes = (True, False) if r % 2 == 0 else (False, True)
            for mode in modes:
                obs.configure(enabled=mode)
                med = _median_request_s(svc, x, w, repeats)
                (on_meds if mode else off_meds).append(med)
    finally:
        obs.configure(enabled=prior)

    on_s, off_s = float(np.median(on_meds)), float(np.median(off_meds))
    overhead = on_s / off_s - 1.0
    return {
        "m": m, "d": d, "q": q, "zipf_a": zipf_a,
        "repeats": repeats, "rounds": rounds,
        "obs_on_s": on_s,
        "obs_off_s": off_s,
        "overhead_fraction": round(overhead, 5),
        "bar": OVERHEAD_BAR,
        "pass": bool(overhead < OVERHEAD_BAR),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rep = run_overhead(m=args.m, d=args.d, repeats=args.repeats,
                       rounds=args.rounds, seed=args.seed)
    print(f"obs overhead  fused Zipf m={rep['m']}: "
          f"on={rep['obs_on_s'] * 1e3:.2f}ms "
          f"off={rep['obs_off_s'] * 1e3:.2f}ms "
          f"overhead={rep['overhead_fraction'] * 100:+.2f}% "
          f"(bar < {rep['bar'] * 100:.0f}%)")
    path = emit_bench_json({"obs_overhead": rep}, BENCH_JSON)
    print(f"  wrote {path}")
    if not rep["pass"]:
        raise SystemExit(
            f"FAIL: obs overhead {rep['overhead_fraction'] * 100:.2f}% "
            f"exceeds the {rep['bar'] * 100:.0f}% bar")


if __name__ == "__main__":
    main()
