"""Pipeline parallelism: GPipe schedule == sequential layer application.

Runs in a subprocess with a 4-host-device mesh (the main test process keeps
1 device so smoke tests and benches see the default)."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply, bubble_fraction

    from repro.compat import make_mesh
    mesh = make_mesh((4,), ("stage",))
    rng = np.random.default_rng(0)
    S, M, Bm, D = 4, 8, 2, 16
    w = jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) / D**0.5)
    x = jnp.asarray(rng.normal(size=(M, Bm, D)).astype(np.float32))

    def stage_fn(p, h):
        return jnp.tanh(h @ p)

    out = pipeline_apply(stage_fn, w, x, mesh)

    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
    print("PIPELINE_OK")
""")


def test_gpipe_matches_sequential():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # force-host-device script must not probe TPU hardware; without
             # this the plugin retries GCP metadata for minutes and the test
             # times out instead of running
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "HOME": os.environ.get("HOME", "/tmp")},
    )
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr
