"""Engine semantics: schema-driven execution == brute-force all-pairs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan_a2a, plan_x2y
from repro.mapreduce import (
    build_plan,
    pairwise_similarity,
    skew_join,
)
from repro.mapreduce.engine import run_reducers


class TestAllPairs:
    @pytest.mark.parametrize("metric", ["dot", "l2", "cosine"])
    def test_matches_bruteforce(self, metric):
        rng = np.random.default_rng(0)
        m, d = 23, 16
        x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        w = rng.uniform(0.05, 0.3, size=m)
        sims, plan, schema = pairwise_similarity(
            x, q=1.0, weights=w, metric=metric)
        # brute force
        if metric == "dot":
            ref = x @ x.T
        elif metric == "l2":
            n2 = jnp.sum(x * x, axis=-1)
            ref = n2[:, None] + n2[None, :] - 2 * (x @ x.T)
        else:
            nrm = jnp.linalg.norm(x, axis=-1)
            ref = (x @ x.T) / (nrm[:, None] * nrm[None, :])
        ref = ref * (1 - jnp.eye(m))
        np.testing.assert_allclose(np.asarray(sims), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_kernel_path_matches(self):
        rng = np.random.default_rng(1)
        m, d = 17, 8
        x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        s_ref, _, sch = pairwise_similarity(x, q=5.0, metric="dot")
        s_k, _, _ = pairwise_similarity(x, q=5.0, metric="dot",
                                        schema=sch, use_kernel=True)
        np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_k),
                                   rtol=1e-4, atol=1e-4)

    def test_comm_cost_equals_gather_volume(self):
        rng = np.random.default_rng(2)
        w = rng.uniform(0.05, 0.3, size=20)
        schema = plan_a2a(w, 1.0)
        plan = build_plan(schema)
        # engine ships one row per (reducer, valid slot): unit-size rows
        assert plan.mask.sum() == sum(len(r) for r in schema.expand())

    def test_run_reducers_mesh_single_device(self):
        mesh = jax.make_mesh((1,), ("data",))
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
        schema = plan_a2a(np.full(10, 0.3), 1.0)
        plan = build_plan(schema, pad_reducers_to=mesh.devices.size)
        out = run_reducers(
            x, plan, lambda blk, msk: jnp.sum(blk * msk[:, None]), mesh=mesh)
        assert out.shape == (plan.R,)
        assert np.isfinite(np.asarray(out)).all()


class TestSkewJoin:
    def test_join_complete(self):
        rng = np.random.default_rng(4)
        mx, my = 14, 9
        xv = jnp.asarray(rng.normal(size=(mx, 3)).astype(np.float32))
        yv = jnp.asarray(rng.normal(size=(my, 2)).astype(np.float32))
        out, schema = skew_join(xv, yv, q=6.0)
        assert out.shape == (mx, my, 5)
        # every (x, y) pair produced with the right payload
        for i in range(mx):
            for j in range(my):
                np.testing.assert_allclose(
                    np.asarray(out[i, j, :3]), np.asarray(xv[i]), rtol=1e-6)
                np.testing.assert_allclose(
                    np.asarray(out[i, j, 3:]), np.asarray(yv[j]), rtol=1e-6)

    def test_weighted_tuples(self):
        rng = np.random.default_rng(5)
        mx, my = 8, 6
        xv = jnp.asarray(rng.normal(size=(mx, 2)).astype(np.float32))
        yv = jnp.asarray(rng.normal(size=(my, 2)).astype(np.float32))
        wx = rng.uniform(0.1, 0.9, mx)
        wy = rng.uniform(0.1, 0.9, my)
        out, schema = skew_join(xv, yv, q=2.0, wx=wx, wy=wy)
        schema.validate("x2y", x_ids=range(mx), y_ids=range(mx, mx + my))
        assert out.shape == (mx, my, 4)
