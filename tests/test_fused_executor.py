"""Differential tests: fused executor == bucketed == dense.

The fused gather+Gram path (kernel, streamed twin, and the one-program
executor with inverse-shuffle assembly) must be a pure execution-plan
change: identical outputs on random, Zipf-skewed, and degenerate schemas.
All Pallas paths run in ``interpret=True`` mode (CPU CI); the same
``pallas_call`` lowers to the real scalar-prefetch kernel on TPU.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan_a2a
from repro.kernels.pairwise.fused_gather_gram import (
    fused_gather_gram,
    fused_gather_gram_ref,
    fused_gather_gram_streamed,
)
from repro.kernels.pairwise.pairwise import (
    _clamp_block,
    min_tile_sublanes,
    pairwise_gram,
)
from repro.mapreduce import (
    build_plan,
    pairwise_similarity,
    run_reducers,
    run_reducers_bucketed,
    run_reducers_fused,
    some_pairs_similarity,
)
from repro.mapreduce import engine as engine_mod
from repro.mapreduce.allpairs import _block_fn
from repro.mapreduce.engine import ReducerBucket, ReducerPlan


def _weights(kind: str, m: int, seed: int, q: float = 1.0):
    rng = np.random.default_rng(seed)
    return {
        "uniform": lambda: rng.uniform(0.05, 0.33, m),
        "zipf": lambda: np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45 * q),
        "one-giant": lambda: np.concatenate(
            [[0.8 * q], rng.uniform(0.02, 0.1, m - 1)]),
        "single-reducer": lambda: np.full(m, q / (m + 1)),
    }[kind]()


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32), dtype)


# ------------------------------------------------------------------- kernel
class TestFusedGatherGramKernel:
    @pytest.mark.parametrize("R,L,m,d,bl", [
        (3, 5, 17, 8, 8),          # single tile, ragged width
        (5, 16, 37, 16, 8),        # two row tiles
        (4, 24, 50, 12, 8),        # three row tiles
        (1, 1, 2, 4, 8),           # minimal
    ])
    def test_kernel_matches_ref(self, R, L, m, d, bl):
        rng = np.random.default_rng(R * 100 + L)
        x = _rand(rng, (m, d))
        idx = jnp.asarray(rng.integers(0, m, (R, L)).astype(np.int32))
        mask = jnp.asarray(rng.uniform(size=(R, L)) > 0.3)
        got = fused_gather_gram(x, idx, mask, bl=bl, interpret=True)
        ref = fused_gather_gram_ref(x, idx, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("L,bl", [(5, 8), (20, 8), (37, 16)])
    def test_streamed_matches_ref(self, L, bl):
        rng = np.random.default_rng(L)
        x = _rand(rng, (29, 8))
        idx = jnp.asarray(rng.integers(0, 29, (6, L)).astype(np.int32))
        mask = jnp.asarray(rng.uniform(size=(6, L)) > 0.4)
        got = fused_gather_gram_streamed(x, idx, mask, bl=bl)
        ref = fused_gather_gram_ref(x, idx, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_bf16_table(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, (31, 16), jnp.bfloat16)
        idx = jnp.asarray(rng.integers(0, 31, (4, 18)).astype(np.int32))
        mask = jnp.asarray(rng.uniform(size=(4, 18)) > 0.3)
        got = fused_gather_gram(x, idx, mask, bl=16, interpret=True)
        ref = fused_gather_gram_ref(x, idx, mask)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_all_masked_rows_are_zero(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, (11, 4))
        idx = jnp.asarray(rng.integers(0, 11, (3, 6)).astype(np.int32))
        mask = jnp.zeros((3, 6), bool)
        got = fused_gather_gram(x, idx, mask, bl=8, interpret=True)
        assert float(jnp.abs(got).max()) == 0.0


# ---------------------------------------------------------------- executor
KINDS = ["uniform", "zipf", "one-giant", "single-reducer"]


class TestFusedExecutorDifferential:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("m", [5, 29])
    def test_dense_combine_matches_both_executors(self, kind, m):
        w = _weights(kind, m, seed=m)
        plan = build_plan(plan_a2a(w, 1.0))
        rng = np.random.default_rng(m)
        x = _rand(rng, (m, 6))
        fn = _block_fn("dot", False)
        dense = run_reducers(x, plan, fn)
        buck = run_reducers_bucketed(x, plan, fn)
        fused = run_reducers_fused(x, plan, fn, use_kernel=False)
        assert fused.shape == dense.shape
        np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)

    def test_kernel_path_dense_combine(self):
        """The Pallas megakernel inside the fused program (interpret)."""
        m = 23
        w = _weights("zipf", m, seed=3)
        plan = build_plan(plan_a2a(w, 1.0))
        rng = np.random.default_rng(5)
        x = _rand(rng, (m, 8))
        fn = _block_fn("dot", False)
        dense = run_reducers(x, plan, fn)
        fused = run_reducers_fused(x, plan, fn, use_kernel=True,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("metric", ["dot", "l2", "cosine"])
    def test_pairwise_similarity_fused_agrees(self, metric):
        m, q = 26, 1.0
        w = _weights("zipf", m, seed=7)
        rng = np.random.default_rng(7)
        x = _rand(rng, (m, 8))
        schema = plan_a2a(w, q)
        s_b, plan_b, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, metric=metric,
            executor="bucketed")
        s_f, plan_f, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, metric=metric,
            executor="fused")
        np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)
        assert plan_f.comm_cost == plan_b.comm_cost

    def test_pairwise_similarity_fused_kernel_interpret(self):
        m, q = 19, 1.0
        w = _weights("uniform", m, seed=2)
        rng = np.random.default_rng(2)
        x = _rand(rng, (m, 8))
        schema = plan_a2a(w, q)
        s_d, _, _ = pairwise_similarity(x, q=q, weights=w, schema=schema,
                                        executor="dense")
        s_f, _, _ = pairwise_similarity(x, q=q, weights=w, schema=schema,
                                        executor="fused", use_kernel=True,
                                        interpret=True)
        np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_d),
                                   rtol=1e-4, atol=1e-4)

    def test_single_input_degenerate(self):
        """m=1: no pairs, plan degenerates — fused must not crash."""
        x = jnp.ones((1, 4), jnp.float32)
        s_f, _, _ = pairwise_similarity(x, q=1.0, weights=[0.3],
                                        executor="fused")
        s_b, _, _ = pairwise_similarity(x, q=1.0, weights=[0.3],
                                        executor="bucketed")
        np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_b))

    def test_all_masked_bucket(self):
        """Handmade plan whose only bucket is entirely padding rows."""
        idx = np.zeros((2, 3), np.int32)
        mask = np.zeros((2, 3), bool)
        plan = ReducerPlan(
            idx=idx, mask=mask, num_reducers=0, comm_cost=0.0, max_inputs=3,
            buckets=(ReducerBucket(width=3,
                                   rows=np.full(2, -1, np.int64),
                                   idx=idx, mask=mask),))
        x = jnp.ones((4, 5), jnp.float32)
        fn = _block_fn("dot", False)
        fused = run_reducers_fused(x, plan, fn, use_kernel=False)
        buck = run_reducers_bucketed(x, plan, fn)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(buck))
        assert float(jnp.abs(fused).max()) == 0.0

    def test_non_gram_reducer_falls_back(self):
        m = 17
        w = _weights("zipf", m, seed=3)
        plan = build_plan(plan_a2a(w, 1.0))
        rng = np.random.default_rng(5)
        x = _rand(rng, (m, 4))

        def colsum(blk, msk):
            return jnp.sum(blk * msk[:, None], axis=0)

        before = engine_mod.fused_stats()
        fused = run_reducers_fused(x, plan, colsum)
        after = engine_mod.fused_stats()
        buck = run_reducers_bucketed(x, plan, colsum)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)
        assert after["fallbacks"] == before["fallbacks"] + 1
        assert after["calls"] == before["calls"] + 1


class TestSomePairsFused:
    def test_x2y_some_pairs_fused_agrees(self):
        """The some-pairs (X2Y) workload on the same fused path."""
        m, q = 20, 1.0
        rng = np.random.default_rng(13)
        w = rng.uniform(0.02, 0.3, m)
        pairs = [(0, 1), (2, 9), (5, 17), (3, 4), (11, 12)]
        x = _rand(rng, (m, 8))
        s_b, _, sch = some_pairs_similarity(x, pairs, q=q, weights=w,
                                            executor="bucketed")
        s_f, _, _ = some_pairs_similarity(x, pairs, q=q, weights=w,
                                          schema=sch, executor="fused")
        np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)
        # required pairs must carry the true similarity
        ref = np.asarray(x) @ np.asarray(x).T
        for i, j in pairs:
            np.testing.assert_allclose(float(s_f[i, j]), ref[i, j],
                                       rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ jit cache LRU
class TestJitCacheLRU:
    def test_bounded_with_eviction(self, monkeypatch):
        monkeypatch.setattr(engine_mod, "_JIT_CACHE_MAX", 4)
        w = np.full(6, 0.3)
        plan = build_plan(plan_a2a(w, 1.0))
        x = jnp.ones((6, 3), jnp.float32)
        before_evictions = engine_mod._JIT_CACHE_STATS["evictions"]
        for i in range(8):
            # fresh closure every iteration — the anti-pattern the bound
            # protects against
            fn = (lambda k: lambda blk, msk: jnp.sum(blk, axis=0) * k)(i)
            run_reducers(x, plan, fn)
        assert len(engine_mod._JIT_CACHE) <= 4
        assert engine_mod._JIT_CACHE_STATS["evictions"] > before_evictions

    def test_stats_shape_and_hits(self):
        stats = engine_mod.jit_cache_stats()
        for key in ("size", "max_size", "hits", "misses", "evictions",
                    "per_key"):
            assert key in stats
        w = np.full(5, 0.3)
        plan = build_plan(plan_a2a(w, 1.0))
        x = jnp.ones((5, 3), jnp.float32)
        fn = _block_fn("dot", False)
        run_reducers(x, plan, fn)
        h0 = engine_mod.jit_cache_stats()["hits"]
        run_reducers(x, plan, fn)
        assert engine_mod.jit_cache_stats()["hits"] == h0 + 1

    def test_per_key_hit_counts(self, monkeypatch):
        """Per-key counters: repeat lookups of one key accumulate under its
        label; fresh keys start at zero."""
        from collections import OrderedDict
        monkeypatch.setattr(engine_mod, "_JIT_CACHE", OrderedDict())
        monkeypatch.setattr(engine_mod, "_JIT_CACHE_HITS", {})
        for _ in range(4):
            engine_mod._cache_get(("stable_key",), lambda: object())
        engine_mod._cache_get(("fresh_key",), lambda: object())
        per_key = engine_mod.jit_cache_stats()["per_key"]
        assert per_key["stable_key"] == 3
        assert per_key["fresh_key"] == 0

    def test_eviction_order_is_lru(self, monkeypatch):
        """A freshly-touched entry must survive eviction; the
        least-recently-used one goes first."""
        from collections import OrderedDict
        monkeypatch.setattr(engine_mod, "_JIT_CACHE", OrderedDict())
        monkeypatch.setattr(engine_mod, "_JIT_CACHE_HITS", {})
        monkeypatch.setattr(engine_mod, "_JIT_CACHE_MAX", 2)
        engine_mod._cache_get("A", lambda: "a")
        engine_mod._cache_get("B", lambda: "b")
        engine_mod._cache_get("A", lambda: "a")       # touch A: B is LRU now
        engine_mod._cache_get("C", lambda: "c")       # evicts B, not A
        assert "A" in engine_mod._JIT_CACHE
        assert "B" not in engine_mod._JIT_CACHE
        assert "C" in engine_mod._JIT_CACHE
        # evicted keys drop out of the per-key counters too
        assert "B" not in engine_mod.jit_cache_stats()["per_key"]

    def test_env_configurable_cap(self, monkeypatch):
        """REPRO_JIT_CACHE_SIZE drives the LRU cap via
        configure_jit_cache(); shrinking below the live size evicts
        immediately in LRU order."""
        from collections import OrderedDict
        monkeypatch.setattr(engine_mod, "_JIT_CACHE", OrderedDict())
        monkeypatch.setattr(engine_mod, "_JIT_CACHE_HITS", {})
        monkeypatch.setattr(engine_mod, "_JIT_CACHE_MAX",
                            engine_mod._JIT_CACHE_MAX)
        monkeypatch.setenv("REPRO_JIT_CACHE_SIZE", "3")
        assert engine_mod.configure_jit_cache() == 3
        assert engine_mod.jit_cache_stats()["max_size"] == 3
        for k in "ABC":
            engine_mod._cache_get(k, lambda: k)
        monkeypatch.setenv("REPRO_JIT_CACHE_SIZE", "1")
        assert engine_mod.configure_jit_cache() == 1
        assert list(engine_mod._JIT_CACHE) == ["C"]   # oldest evicted first
        monkeypatch.delenv("REPRO_JIT_CACHE_SIZE")
        assert engine_mod.configure_jit_cache() == 64  # default restored
        # malformed / non-positive values fall back to the default instead
        # of crashing the import or setting a cap-0 evict-everything cache
        for bad in ("abc", "0", "-3", ""):
            monkeypatch.setenv("REPRO_JIT_CACHE_SIZE", bad)
            assert engine_mod.configure_jit_cache() == 64, bad


# ------------------------------------------------- pairwise_gram block clamp
class TestPairwiseGramClamp:
    @pytest.mark.parametrize("dtype,sub", [
        (jnp.float32, 8), (jnp.bfloat16, 16), (jnp.int8, 32)])
    def test_min_tile_sublanes(self, dtype, sub):
        assert min_tile_sublanes(dtype) == sub

    def test_clamped_blocks_are_tile_aligned(self):
        # sub-tile extents round UP to the dtype tile, not to raw max(8, M)
        assert _clamp_block(128, 10, jnp.bfloat16) == 16
        assert _clamp_block(128, 10, jnp.float32) == 16
        assert _clamp_block(128, 3, jnp.float32) == 8
        assert _clamp_block(128, 200, jnp.float32) == 128
        assert _clamp_block(512, 9, jnp.float32, lane=True) == 128

    @pytest.mark.parametrize("M,dtype", [(10, jnp.bfloat16), (3, jnp.float32),
                                         (1, jnp.bfloat16)])
    def test_sub_tile_widths_still_correct(self, M, dtype):
        rng = np.random.default_rng(M)
        x = _rand(rng, (M, 20), dtype)
        got = pairwise_gram(x, x, interpret=True)
        ref = np.asarray(x, np.float32) @ np.asarray(x, np.float32).T
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(got, np.float32), ref,
                                   rtol=tol, atol=tol)
