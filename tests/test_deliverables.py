"""Deliverable-integrity guards: dry-run artifacts complete, ring caches
sized to the window, configs registry consistent."""

import glob
import json
import os

import jax
import pytest

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.specs import shape_applicable

RESULTS = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "results", "dryrun")


class TestDryRunArtifacts:
    @pytest.mark.parametrize("mesh", ["pod_16x16", "multipod_2x16x16"])
    def test_all_cells_recorded_and_ok(self, mesh):
        if not os.path.isdir(RESULTS):
            pytest.skip("dry-run not executed in this checkout")
        missing, bad = [], []
        for arch in list_archs():
            for shape in SHAPES:
                path = os.path.join(RESULTS, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(path):
                    missing.append((arch, shape))
                    continue
                with open(path) as f:
                    rec = json.load(f)
                ok, reason = shape_applicable(get_config(arch), shape)
                want = "ok" if ok else "skipped"
                if rec.get("status") != want:
                    bad.append((arch, shape, rec.get("status"), want))
        assert not missing, f"missing cells: {missing}"
        assert not bad, f"wrong status: {bad}"

    def test_roofline_terms_present(self):
        if not os.path.isdir(RESULTS):
            pytest.skip("dry-run not executed")
        files = [f for f in glob.glob(os.path.join(RESULTS, "*.json"))
                 if "__opt" not in f and "engine" not in f]
        assert files
        for path in files[:10]:
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                continue
            for key in ("t_compute", "t_memory", "t_collective",
                        "bottleneck", "roofline_fraction",
                        "useful_flops_ratio"):
                assert key in rec, (path, key)


class TestRingCache:
    def test_windowed_arch_allocates_window_cache(self):
        from repro.launch.mesh import make_local_mesh
        from repro.launch.rules import rules_for
        from repro.models import RuntimeFlags, build_model

        cfg = get_config("mixtral-8x7b").reduced()   # window=8 reduced
        assert cfg.window == 8
        mesh = make_local_mesh()
        flags = RuntimeFlags(param_dtype="float32", compute_dtype="float32",
                             remat="none")
        model = build_model(cfg, flags, rules_for(cfg, mesh, flags))
        cache = model.init_cache(2, 64)
        k = cache["pos0"]["mixer"]["k"]
        # (layers, B, ring, Hkv, D): ring = window, not max_len
        assert k.shape[2] == cfg.window, k.shape

    def test_full_attention_arch_allocates_max_len(self):
        from repro.launch.mesh import make_local_mesh
        from repro.launch.rules import rules_for
        from repro.models import RuntimeFlags, build_model

        cfg = get_config("stablelm-1.6b").reduced()
        mesh = make_local_mesh()
        flags = RuntimeFlags(param_dtype="float32", compute_dtype="float32",
                             remat="none")
        model = build_model(cfg, flags, rules_for(cfg, mesh, flags))
        cache = model.init_cache(2, 64)
        assert cache["pos0"]["mixer"]["k"].shape[2] == 64


class TestRegistry:
    def test_ten_archs_plus_shapes(self):
        archs = list_archs()
        assert len(archs) == 10
        assert len(SHAPES) == 4
        # 40 grid cells; skips only where documented
        skipped = [(a, s) for a in archs for s in SHAPES
                   if not shape_applicable(get_config(a), s)[0]]
        assert len(skipped) == 6  # long_500k x 6 full-attention archs

    def test_reduced_configs_are_small(self):
        for a in list_archs():
            r = get_config(a).reduced()
            assert r.param_count() < 20e6, (a, r.param_count())
