"""Serving engine: wave batching produces the same tokens as sequential
decode, handles queues longer than the slot count, and respects limits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.rules import rules_for
from repro.models import RuntimeFlags, build_model
from repro.serve import BatchedServer, PairwiseService, Request

CFG = ArchConfig(name="tiny-serve", family="dense", num_layers=2,
                 d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                 d_ff=64, vocab_size=128)


def make_model():
    mesh = make_local_mesh()
    flags = RuntimeFlags(param_dtype="float32", compute_dtype="float32",
                         remat="none")
    rules = rules_for(CFG, mesh, flags)
    model = build_model(CFG, flags, rules)
    return model, model.init(jax.random.key(0))


def sequential_decode(model, params, prompt, n_new, max_len):
    cache = model.init_cache(1, max_len)
    out = []
    tok = None
    for t in range(len(prompt) + n_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        logits, cache = model.decode_step(
            params, cache,
            {"tokens": jnp.asarray([[cur]], jnp.int32),
             "pos": jnp.asarray(t, jnp.int32)})
        nxt = int(jnp.argmax(logits[0, -1]))
        if t >= len(prompt) - 1:
            out.append(nxt)
    return out


@pytest.mark.slow          # model-decode e2e, excluded from test-fast
class TestBatchedServer:
    def test_matches_sequential(self):
        model, params = make_model()
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, 128, n).astype(np.int32)
                   for n in (3, 5, 4, 3)]
        server = BatchedServer(model, params, batch_slots=2, max_len=32)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            server.submit(r)
        server.run()
        assert all(r.done for r in reqs)
        for r, p in zip(reqs, prompts):
            want = sequential_decode(model, params, list(map(int, p)), 4, 32)
            assert r.out == want, (r.rid, r.out, want)

    def test_queue_larger_than_slots(self):
        model, params = make_model()
        rng = np.random.default_rng(1)
        server = BatchedServer(model, params, batch_slots=2, max_len=16)
        reqs = [Request(rid=i, prompt=rng.integers(1, 128, 2).astype(
            np.int32), max_new_tokens=2) for i in range(7)]
        for r in reqs:
            server.submit(r)
        server.run()
        assert all(r.done and len(r.out) == 2 for r in reqs)

    def test_max_len_cap(self):
        model, params = make_model()
        server = BatchedServer(model, params, batch_slots=1, max_len=6)
        r = Request(rid=0, prompt=np.asarray([5, 6], np.int32),
                    max_new_tokens=100)
        server.submit(r)
        server.run()
        assert r.done
        assert len(r.out) <= 6


@pytest.mark.slow          # model-decode e2e, excluded from test-fast
class TestKVQuant:
    def test_int8_cache_decode_close_to_fp(self):
        """int8 KV cache: logits close to the fp path; cache 2x smaller."""
        from repro.models.configs_runtime import RuntimeFlags as RF
        import dataclasses
        model, params = make_model()
        rng = np.random.default_rng(2)
        prompt = rng.integers(1, 128, 6).astype(np.int32)
        fp = sequential_decode(model, params, list(map(int, prompt)), 3, 16)

        flags_q = dataclasses.replace(model.flags, kv_quant="int8")
        model_q = dataclasses.replace(model, flags=flags_q)
        cache = model_q.init_cache(1, 16)
        k = cache["pos0"]["mixer"]["k"]
        assert k.dtype == jnp.int8
        out = []
        for t in range(len(prompt) + 2):
            cur = int(prompt[t]) if t < len(prompt) else out[-1]
            logits, cache = model_q.decode_step(
                params, cache,
                {"tokens": jnp.asarray([[cur]], jnp.int32),
                 "pos": jnp.asarray(t, jnp.int32)})
            if t >= len(prompt) - 1:
                out.append(int(jnp.argmax(logits[0, -1])))
        # greedy tokens usually agree; require at least the first to match
        assert out[0] == fp[0], (out, fp)


class TestPairwiseService:
    """Paper-workload serving: planned similarity on the bucketed executor."""

    def test_matches_bruteforce_and_reports_telemetry(self):
        rng = np.random.default_rng(0)
        m, d = 24, 8
        x = rng.normal(size=(m, d)).astype(np.float32)
        w = np.clip(rng.zipf(1.7, m) / 30.0, 0.02, 0.45)
        svc = PairwiseService(q=1.0)
        sims, info = svc.similarity(x, weights=w)
        ref = x @ x.T * (1 - np.eye(m, dtype=np.float32))
        np.testing.assert_allclose(np.asarray(sims), ref,
                                   rtol=1e-4, atol=1e-4)
        assert info["executor"] == "bucketed"
        assert info["bucketed_padded_elements"] <= \
            info["dense_padded_elements"]
        assert info["optimality_gap"] is None or info["optimality_gap"] >= 1.0
        assert svc.stats["requests"] == 1

    def test_some_pairs_masked_to_request(self):
        rng = np.random.default_rng(1)
        m = 16
        x = rng.normal(size=(m, 4)).astype(np.float32)
        w = np.full(m, 0.2)
        pairs = [(0, 3), (5, 9)]
        svc = PairwiseService(q=1.0)
        sims, info = svc.some_pairs(x, pairs, weights=w)
        want = np.zeros((m, m), dtype=bool)
        for i, j in pairs:
            want[i, j] = want[j, i] = True
        assert np.all(np.asarray(sims)[~want] == 0.0)
        for i, j in pairs:
            np.testing.assert_allclose(float(sims[i, j]),
                                       float(x[i] @ x[j]), rtol=1e-4)
        assert svc.padding_savings >= 1.0
