"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU, asserting output shapes and finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs.base import get_config, list_archs
from repro.models import RuntimeFlags, build_model
from repro.parallel.sharding import ShardingRules

# excluded from `make test-fast` (full arch/kernel e2e sweeps)
pytestmark = pytest.mark.slow

ARCHS = list_archs()

FLAGS = RuntimeFlags(param_dtype="float32", compute_dtype="float32",
                     remat="none")


def make_model(arch):
    cfg = get_config(arch).reduced()
    mesh = make_mesh((1,), ("data",))
    rules = ShardingRules.create(mesh)
    return cfg, build_model(cfg, FLAGS, rules)


def make_batch(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.frontend == "audio":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        F = cfg.num_frontend_tokens
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, F, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg, model = make_model(arch)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)

    @jax.jit
    def step(p, b):
        return jax.value_and_grad(lambda p: model.loss(p, b)[0])(p)

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), \
            f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg, model = make_model(arch)
    params = model.init(jax.random.key(1))
    B, max_len = 2, 32
    cache = model.init_cache(B, max_len)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)),
                                   jnp.int32),
             "pos": jnp.zeros((), jnp.int32)}
    if cfg.frontend == "audio":
        # precomputed encoder output (stub frontend)
        enc_batch = make_batch(cfg, B=B, S=1)
        enc_out = model._encode(params, enc_batch["audio_embeds"])
        batch["enc_out"] = enc_out
    logits, cache2 = jax.jit(model.decode_step)(params, cache, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab()), arch
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    # a second step advances the cache
    batch2 = dict(batch, pos=jnp.ones((), jnp.int32))
    logits2, _ = jax.jit(model.decode_step)(params, cache2, batch2)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


def test_decode_matches_teacher_forcing():
    """Greedy decode logits == teacher-forced logits position by position."""
    cfg, model = make_model("stablelm-1.6b")
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    B, S = 1, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_tf, _, _ = model.forward(params, {"tokens": tokens})
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        step_batch = {"tokens": tokens[:, t:t + 1],
                      "pos": jnp.asarray(t, jnp.int32)}
        lg, cache = model.decode_step(params, cache, step_batch)
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_tf), np.asarray(logits_dec),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_published():
    """Full-size configs should land near the published parameter counts."""
    expect = {
        "mixtral-8x7b": (45e9, 49e9),
        "llama4-maverick-400b-a17b": (330e9, 440e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "jamba-1.5-large-398b": (330e9, 440e9),
        "granite-34b": (30e9, 38e9),
        "stablelm-1.6b": (1.3e9, 1.9e9),
        "gemma3-4b": (3.2e9, 5e9),
        "stablelm-3b": (2.5e9, 3.4e9),
        "whisper-large-v3": (1.2e9, 2.1e9),
        "internvl2-26b": (19e9, 27e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    active = cfg.active_param_count()
    assert 11e9 <= active <= 15e9, active / 1e9  # ~12.9B active
