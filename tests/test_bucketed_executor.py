"""Differential tests: bucketed executor == dense executor.

The bucketed (skew-aware) path must be a pure execution-plan change: same
outputs (allclose), same plan provenance (comm cost, algorithm, lower
bound), strictly fewer-or-equal padded gather elements.  Degenerate
schemas — single reducer, all-equal sizes, one giant input — are the cases
where bucket construction is most likely to be off by one.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bucket_summary, compute_buckets, plan_a2a
from repro.core.planner import naive_pairs
from repro.mapreduce import (
    build_plan,
    pairwise_similarity,
    run_reducers,
    run_reducers_bucketed,
    some_pairs_similarity,
)


def _weights(kind: str, m: int, seed: int, q: float = 1.0):
    rng = np.random.default_rng(seed)
    return {
        "uniform": lambda: rng.uniform(0.05, 0.33, m),
        "zipf": lambda: np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45 * q),
        "equal": lambda: np.full(m, 0.21 * q),
        "one-giant": lambda: np.concatenate(
            [[0.8 * q], rng.uniform(0.02, 0.1, m - 1)]),
        "single-reducer": lambda: np.full(m, q / (m + 1)),
    }[kind]()


def _block_gram(blk, msk):
    s = blk @ blk.T
    v = msk[:, None] & msk[None, :]
    return jnp.where(v, s, 0.0)


# ------------------------------------------------------------ compute_buckets
class TestComputeBuckets:
    def test_partition_and_widths(self):
        counts = [1, 2, 3, 5, 9, 17, 33, 64, 64, 2]
        buckets = compute_buckets(counts)
        seen = np.concatenate([ids for _, ids in buckets])
        assert sorted(seen.tolist()) == list(range(len(counts)))
        for width, ids in buckets:
            for r in ids:
                assert counts[r] <= width          # never under-padded
        widths = [w for w, _ in buckets]
        assert widths == sorted(widths)
        assert max(widths) <= 64                   # clamped to dense width

    def test_max_buckets_merges_upward(self):
        counts = [1, 2, 4, 8, 16, 32, 64, 128, 256]
        buckets = compute_buckets(counts, max_buckets=3)
        assert len(buckets) <= 3
        for width, ids in buckets:
            for r in ids:
                assert counts[r] <= width

    def test_pad_slots_to_alignment(self):
        buckets = compute_buckets([3, 10, 100], pad_slots_to=8)
        for width, _ in buckets:
            assert width % 8 == 0

    def test_empty(self):
        assert compute_buckets([]) == []

    @given(st.lists(st.integers(1, 300), min_size=1, max_size=100),
           st.integers(1, 8))
    @settings(max_examples=40, deadline=None)
    def test_property_partition(self, counts, max_buckets):
        buckets = compute_buckets(counts, max_buckets=max_buckets)
        seen = sorted(int(i) for _, ids in buckets for i in ids)
        assert seen == list(range(len(counts)))
        assert len(buckets) <= max_buckets
        for width, ids in buckets:
            assert all(counts[r] <= width for r in ids)


# ----------------------------------------------------------- executor parity
KINDS = ["uniform", "zipf", "equal", "one-giant", "single-reducer"]


class TestExecutorDifferential:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("m", [5, 29])
    def test_block_outputs_allclose(self, kind, m):
        q = 1.0
        w = _weights(kind, m, seed=m)
        schema = plan_a2a(w, q)
        plan = build_plan(schema)
        rng = np.random.default_rng(m)
        x = jnp.asarray(rng.normal(size=(m, 6)).astype(np.float32))
        dense = run_reducers(x, plan, _block_gram)
        buck = run_reducers_bucketed(x, plan, _block_gram)
        assert dense.shape == buck.shape
        np.testing.assert_allclose(np.asarray(dense), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("kind", KINDS)
    def test_reduction_outputs_allclose(self, kind):
        """Reducers whose output drops the slot axis entirely."""
        m, q = 17, 1.0
        w = _weights(kind, m, seed=3)
        plan = build_plan(plan_a2a(w, q))
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(m, 4)).astype(np.float32))
        fn = lambda blk, msk: jnp.sum(blk * msk[:, None], axis=0)
        dense = run_reducers(x, plan, fn)
        buck = run_reducers_bucketed(x, plan, fn)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)

    def test_plan_provenance_shared(self):
        """Bucketing changes execution layout only — cost, provenance and
        bounds are properties of the schema, identical on both paths."""
        w = _weights("zipf", 40, seed=9)
        schema = plan_a2a(w, 1.0)
        plan = build_plan(schema)
        assert plan.comm_cost == pytest.approx(schema.communication_cost())
        assert plan.algorithm == schema.algorithm
        assert plan.lower_bound == schema.lower_bound
        rows = np.concatenate([b.rows for b in plan.buckets])
        real = np.sort(rows[rows >= 0])
        assert real.tolist() == list(range(plan.num_reducers))
        valid_dense = int(plan.mask.sum())
        valid_buckets = int(sum(b.mask.sum() for b in plan.buckets))
        assert valid_dense == valid_buckets     # same shipped rows = comm cost
        assert plan.bucketed_padded_elements <= plan.dense_padded_elements

    def test_mesh_padded_rows(self):
        """pad_reducers_to pads every bucket to the device-count multiple."""
        w = _weights("zipf", 30, seed=11)
        plan = build_plan(plan_a2a(w, 1.0), pad_reducers_to=4)
        assert plan.R % 4 == 0
        for b in plan.buckets:
            assert b.R % 4 == 0
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(30, 5)).astype(np.float32))
        dense = run_reducers(x, plan, _block_gram)
        buck = run_reducers_bucketed(x, plan, _block_gram)
        n = plan.num_reducers
        np.testing.assert_allclose(np.asarray(dense[:n]),
                                   np.asarray(buck[:n]),
                                   rtol=1e-5, atol=1e-5)

    @given(st.lists(st.floats(0.02, 0.45), min_size=2, max_size=32),
           st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_property_random_schemas(self, weights, seed):
        w = np.asarray(weights)
        schema = plan_a2a(w, 1.0)
        plan = build_plan(schema)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(len(w), 4)).astype(np.float32))
        dense = run_reducers(x, plan, _block_gram)
        buck = run_reducers_bucketed(x, plan, _block_gram)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)


# -------------------------------------------------- end-to-end (application)
class TestApplicationDifferential:
    @pytest.mark.parametrize("metric", ["dot", "l2", "cosine"])
    def test_pairwise_similarity_executors_agree(self, metric):
        m, q = 26, 1.0
        w = _weights("zipf", m, seed=7)
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(m, 8)).astype(np.float32))
        schema = plan_a2a(w, q)
        s_d, plan_d, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, metric=metric,
            executor="dense")
        s_b, plan_b, _ = pairwise_similarity(
            x, q=q, weights=w, schema=schema, metric=metric,
            executor="bucketed")
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)
        assert plan_d.comm_cost == plan_b.comm_cost

    def test_some_pairs_executors_agree(self):
        m, q = 20, 1.0
        rng = np.random.default_rng(13)
        w = rng.uniform(0.02, 0.3, m)
        pairs = [(0, 1), (2, 9), (5, 17), (3, 4), (11, 12)]
        x = jnp.asarray(rng.normal(size=(m, 8)).astype(np.float32))
        s_d, _, sch = some_pairs_similarity(x, pairs, q=q, weights=w,
                                            executor="dense")
        s_b, _, _ = some_pairs_similarity(x, pairs, q=q, weights=w,
                                          schema=sch, executor="bucketed")
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)

    def test_naive_plan_buckets(self):
        """naive-pairs: every reducer has 2 slots -> exactly one bucket."""
        w = np.full(10, 0.3)
        plan = build_plan(naive_pairs(w, 1.0))
        assert plan.bucket_widths() == [2]
        assert plan.bucketed_padded_elements == plan.dense_padded_elements

    def test_summary_matches_plan(self):
        w = _weights("zipf", 35, seed=21)
        schema = plan_a2a(w, 1.0)
        plan = build_plan(schema)
        summ = bucket_summary(schema)
        assert summ["dense_padded_slots"] == plan.dense_padded_elements
        assert summ["num_reducers"] == plan.num_reducers
        # summary assumes no row padding; with pad_reducers_to=1 they agree
        assert summ["bucketed_padded_slots"] == plan.bucketed_padded_elements
        assert summ["padding_savings"] == pytest.approx(plan.padding_savings)
