"""Correctness of the paper's mapping-schema constructions.

Every test validates the two mapping-schema constraints (capacity, pair
coverage) and, where the paper states a bound, checks it.
"""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    InfeasibleError,
    a2a_comm_lower_bound,
    a2a_k2_comm_upper_bound,
    a2a_unit_comm_lower_bound,
    big_input_comm_upper_bound,
    naive_pairs,
    plan_a2a,
    plan_unit,
    plan_x2y,
    unit_schemas as us,
    x2y_comm_lower_bound,
    x2y_comm_upper_bound,
)
from repro.core.binpack import bfd, ffd
from repro.core.schema import MappingSchema


def unit_schema(reducers, n, k) -> MappingSchema:
    w = np.ones(n)
    return MappingSchema(w, float(k), [[i] for i in range(n)], reducers,
                         algorithm="unit")


# ---------------------------------------------------------------- bin packing
class TestBinPacking:
    @given(st.lists(st.floats(0.01, 1.0), min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_ffd_valid_and_half_full(self, weights):
        bins = ffd(weights, 1.0)
        w = np.asarray(weights)
        loads = [sum(w[i] for i in b) for b in bins]
        assert all(l <= 1.0 + 1e-9 for l in loads)
        assert sorted(np.concatenate([b for b in bins]).tolist()) \
            == list(range(len(weights)))
        # all but one bin at least half full (FFD guarantee used in Thm 10)
        under = sum(1 for l in loads if l < 0.5 - 1e-9)
        assert under <= 1

    @given(st.lists(st.floats(0.01, 1.0), min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_bfd_valid(self, weights):
        bins = bfd(weights, 1.0)
        w = np.asarray(weights)
        assert all(sum(w[i] for i in b) <= 1.0 + 1e-9 for b in bins)

    def test_oversize_item_raises(self):
        with pytest.raises(ValueError):
            ffd([1.5], 1.0)


# ------------------------------------------------------- q=2 (Section 5.1)
class TestRoundRobinTeams:
    @pytest.mark.parametrize("n", [2, 4, 6, 8, 10, 16, 20, 32])
    def test_one_factorization(self, n):
        teams = us.round_robin_teams(n)
        assert len(teams) == n - 1           # optimal team count (Thm 13)
        seen = set()
        for team in teams:
            flat = [x for p in team for x in p]
            # each team: every input exactly once
            assert sorted(flat) == list(range(n))
            for a, b in team:
                key = (min(a, b), max(a, b))
                assert key not in seen       # each pair exactly once
                seen.add(key)
        assert len(seen) == n * (n - 1) // 2

    def test_q2_meets_lower_bound(self):
        # r(m,2) = m(m-1)/2, comm = m(m-1) — optimal (Table 1)
        n = 16
        teams = us.round_robin_teams(n)
        nred = sum(len(t) for t in teams)
        assert nred == n * (n - 1) // 2
        assert 2 * nred == a2a_unit_comm_lower_bound(n, 2)


# ------------------------------------------------- Algorithms 1 & 2 (Sec 6)
class TestAlgOddEven:
    @pytest.mark.parametrize("n,k", [
        (4, 3), (5, 3), (7, 3), (15, 3), (16, 3), (31, 3),
        (10, 5), (23, 5), (40, 5), (9, 7), (50, 7), (100, 9),
    ])
    def test_alg_odd_covers(self, n, k):
        reds = us.alg_odd(n, k)
        s = unit_schema(reds, n, k)
        s.validate("a2a")
        assert max(len(r) for r in reds) <= k

    @pytest.mark.parametrize("n,k", [
        (3, 2), (8, 2), (9, 2), (10, 4), (23, 4), (40, 6), (64, 8), (100, 10),
    ])
    def test_alg_even_covers(self, n, k):
        reds = us.alg_even(n, k)
        s = unit_schema(reds, n, k)
        s.validate("a2a")
        assert max(len(r) for r in reds) <= k

    @given(st.integers(2, 60), st.integers(2, 12))
    @settings(max_examples=80, deadline=None)
    def test_property_all_pairs(self, n, k):
        reds = us.alg_even(n, k * 2) if True else None
        s = unit_schema(reds, n, 2 * k)
        s.validate("a2a")

    @given(st.integers(4, 60), st.integers(1, 6))
    @settings(max_examples=80, deadline=None)
    def test_property_all_pairs_odd(self, n, j):
        k = 2 * j + 1
        reds = us.alg_odd(n, k)
        s = unit_schema(reds, n, k)
        s.validate("a2a")

    def test_q3_optimality_small(self):
        # Section 5.2: for m = 2n-1 with n a power of two the construction
        # meets m(m-1)/6 reducers; allow the doc'd bound with small slack.
        n = 15
        reds = us.alg_odd(n, 3)
        lb = n * (n - 1) // 6
        assert len(reds) <= lb * 1.2 + 2


# --------------------------------------------------- AU method (Section 5.3)
class TestAUMethod:
    @pytest.mark.parametrize("p", [2, 3, 5, 7, 11])
    def test_au_square_optimal(self, p):
        reds, teams = us.au_square(p, with_teams=True)
        n = p * p
        s = unit_schema(reds, n, p)
        s.validate("a2a")
        assert len(reds) == p * (p + 1)
        assert all(len(r) == p for r in reds)
        # communication meets the lower bound exactly: m(p+1)
        comm = sum(len(r) for r in reds)
        assert comm == a2a_unit_comm_lower_bound(n, p)
        # team property: every team holds every input exactly once
        for rids in teams:
            flat = sorted(i for rid in rids for i in reds[rid])
            assert flat == list(range(n))

    @pytest.mark.parametrize("p", [2, 3, 5, 7])
    def test_au_projective_optimal(self, p):
        reds = us.au_projective(p)
        n = p * p + p + 1
        q = p + 1
        s = unit_schema(reds, n, q)
        s.validate("a2a")
        assert len(reds) == n     # r(q^2+q+1, q+1) = q^2+q+1 with q=p
        comm = sum(len(r) for r in reds)
        # meets m*floor((m-1)/(q-1)) with q=p+1: (m-1)/p = p+1 exactly
        assert comm == n * (n - 1) // p

    def test_every_pair_meets_exactly_once_projective(self):
        p = 3
        reds = us.au_projective(p)
        n = p * p + p + 1
        count = np.zeros((n, n), dtype=int)
        for r in reds:
            for i in r:
                for j in r:
                    if i < j:
                        count[i, j] += 1
        iu = np.triu_indices(n, 1)
        assert np.all(count[iu] == 1)  # projective plane: exactly once


# ------------------------------------------------ Algorithms 3 & 4 (Sec 7)
class TestAUExtensions:
    @pytest.mark.parametrize("n,k", [(30, 6), (36, 6), (29, 7), (60, 8),
                                     (11, 4), (127, 12)])
    def test_alg3_covers(self, n, k):
        reds = us.alg3(n, k)
        if reds is None:
            pytest.skip("no prime accommodates this (n, k)")
        s = unit_schema(reds, n, k)
        s.validate("a2a")

    @pytest.mark.parametrize("k,l", [(2, 2), (2, 3), (2, 4), (3, 2), (3, 3),
                                     (3, 4), (5, 2), (5, 3), (7, 2)])
    def test_alg4_covers(self, k, l):
        n = k ** l
        reds = us.alg4(n, k)
        assert reds is not None
        s = unit_schema(reds, n, k)
        s.validate("a2a")
        # Theorem 23 bound on reducers
        assert len(reds) <= k * (k * (k + 1)) ** (l - 1)

    def test_alg4_reducer_count_example(self):
        # worked example from the paper: q=3, m=81 -> (q(q+1))^(l-1) final bins
        reds = us.alg4(81, 3)
        assert len(reds) == 12 ** 3


# ------------------------------------------------------- planner, A2A mixed
class TestPlanA2A:
    @given(st.lists(st.floats(0.01, 0.5), min_size=2, max_size=40),
           st.sampled_from(["auto", "binpack-k2", "hybrid"]))
    @settings(max_examples=60, deadline=None)
    def test_property_valid(self, weights, method):
        q = 1.0
        try:
            s = plan_a2a(weights, q, method=method)
        except InfeasibleError:
            pytest.skip("method inapplicable")
        s.validate("a2a")

    @given(st.lists(st.floats(0.001, 0.33), min_size=2, max_size=50))
    @settings(max_examples=40, deadline=None)
    def test_auto_beats_k2_bound(self, weights):
        q = 1.0
        s = plan_a2a(weights, q, method="auto")
        s.validate("a2a")
        # paper Theorem 10: the k=2 strategy stays under 4 s^2 / q; our
        # portfolio must too (it includes k=2)
        total = float(np.sum(weights))
        if total > q:  # bound meaningful
            assert s.communication_cost() <= \
                max(a2a_k2_comm_upper_bound(weights, q), total)

    def test_big_input_path(self):
        w = [0.6] + [0.05] * 20
        s = plan_a2a(w, 1.0)
        s.validate("a2a")
        assert s.communication_cost() <= big_input_comm_upper_bound(w, 1.0)

    def test_two_big_inputs_infeasible(self):
        with pytest.raises(InfeasibleError):
            plan_a2a([0.6, 0.7, 0.1], 1.0)

    def test_oversize_infeasible(self):
        with pytest.raises(InfeasibleError):
            plan_a2a([1.2, 0.1], 1.0)

    def test_single_reducer_when_fits(self):
        s = plan_a2a([0.2, 0.3, 0.4], 1.0)
        assert s.num_reducers == 1
        s.validate("a2a")

    def test_paper_example4(self):
        # Example 4: seven inputs, sizes ~0.2q -> 3 reducers achievable
        w = [0.20, 0.20, 0.20, 0.19, 0.19, 0.18, 0.18]
        s = plan_a2a(w, 1.0)
        s.validate("a2a")
        # portfolio should find something close to the 3-reducer optimum
        assert s.num_reducers <= 6
        assert s.communication_cost() <= 4.2 + 1e-9

    def test_auto_never_worse_than_naive(self):
        rng = np.random.default_rng(0)
        for _ in range(5):
            w = rng.uniform(0.02, 0.4, size=25)
            s = plan_a2a(w, 1.0)
            nv = naive_pairs(w, 1.0)
            s.validate("a2a")
            assert s.communication_cost() <= nv.communication_cost() + 1e-9

    def test_comm_above_lower_bound(self):
        rng = np.random.default_rng(1)
        w = rng.uniform(0.01, 0.3, size=40)
        s = plan_a2a(w, 1.0)
        assert s.communication_cost() >= a2a_comm_lower_bound(w, 1.0) * 0.999


# ----------------------------------------------------------------- X2Y
class TestPlanX2Y:
    @given(st.lists(st.floats(0.01, 0.45), min_size=1, max_size=25),
           st.lists(st.floats(0.01, 0.45), min_size=1, max_size=25))
    @settings(max_examples=50, deadline=None)
    def test_property_valid(self, wx, wy):
        q = 1.0
        s = plan_x2y(wx, wy, q)
        m = len(wx)
        s.validate("x2y", x_ids=range(m), y_ids=range(m, m + len(wy)))

    def test_bounds(self):
        rng = np.random.default_rng(2)
        wx = rng.uniform(0.05, 0.45, 30)
        wy = rng.uniform(0.05, 0.45, 20)
        q = 1.0
        s = plan_x2y(wx, wy, q)
        c = s.communication_cost()
        assert c >= x2y_comm_lower_bound(wx, wy, q) * 0.999 or \
            c >= float(np.sum(wx)) + float(np.sum(wy))
        assert c <= x2y_comm_upper_bound(wx, wy, q / 2) + 1e-9

    def test_infeasible(self):
        with pytest.raises(InfeasibleError):
            plan_x2y([0.7], [0.6], 1.0)

    def test_paper_example5_shape(self):
        # Example-5 style: 12 X-inputs, 4 Y-inputs.  With these sizes each
        # Y-holding reducer has q/2 spare => 2 X per reducer => 24 reducers
        # is optimal for this structure (lower bound 2 sx sy / q^2 = 12).
        wx = [0.25] * 12
        wy = [0.5] * 4
        s = plan_x2y(wx, wy, 1.0)
        s.validate("x2y", x_ids=range(12), y_ids=range(12, 16))
        assert s.num_reducers <= 24


# ----------------------------------------------------------- plan_unit auto
class TestPlanUnit:
    @given(st.integers(2, 80), st.integers(2, 10))
    @settings(max_examples=80, deadline=None)
    def test_property(self, n, k):
        reds, name = plan_unit(n, k)
        s = unit_schema(reds, n, k)
        s.validate("a2a")

    def test_prefers_optimal_au(self):
        reds, name = plan_unit(25, 5)   # m = q^2, q prime -> AU optimal
        comm = sum(len(r) for r in reds)
        assert comm == a2a_unit_comm_lower_bound(25, 5)
