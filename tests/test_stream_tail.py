"""Stream-tail regressions: the drift-replan trigger, AOT delta-shape
warmup, background (double-buffered) re-plans, and local repacking.

Pins the BENCH_stream failure mode this work fixed: the Thm-8 bound is
~2x loose for binpack-k2, so a relative-only drift trigger measured
1.007x while the schema actually sat at gap 2.05x — ``drift_replans: 0``
forever.  The absolute ``max_gap`` ceiling (on the *achievable* gap) must
fire even with the relative trigger disabled.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

import repro.stream as st
from repro.core import a2a_comm_lower_bound, plan_a2a
from repro.mapreduce import jit_cache_stats, make_executor
from repro.mapreduce import pairwise_similarity
from repro.mapreduce.allpairs import _block_fn_x2y

TOL = dict(rtol=1e-4, atol=1e-4)


def _zipf(rng, m, q):
    return np.clip(rng.zipf(1.6, m) / 32.0, 0.01, 0.45 * q)


def _service(m, q=1.0, d=8, seed=0, **load_kw):
    from repro.serve import PairwiseService
    rng = np.random.default_rng(seed)
    w = _zipf(rng, m, q)
    x = rng.normal(size=(m, d)).astype(np.float32)
    svc = PairwiseService(q, executor="streaming")
    sims, info = svc.load_table(x, w, **load_kw)
    return rng, svc, sims, info


def _cold_dense(svc):
    """Cold full re-plan on the dense executor: the oracle a streamed
    matrix must match on the active block."""
    planner = svc._planner
    act = planner.active_ids()
    wa = planner.active_weights()
    schema = plan_a2a(wa, svc.q, use_cache=False)
    sims, _, _ = pairwise_similarity(svc._table[act], q=svc.q, weights=wa,
                                     schema=schema, executor="dense")
    return np.asarray(sims), act


def _assert_conformant(planner):
    snap = planner.snapshot()
    snap.validate("a2a")
    assert abs(snap.communication_cost() - planner.comm_cost) < 1e-6


class TestAOTWarmup:
    def test_first_edit_compiles_nothing_new(self):
        # seed 0 picks binpack-k2 on this profile (partition schemas are
        # the warmable family; overlapping hybrid schemas are opaque to
        # delta_shapes and fall back to edit-time compilation)
        rng, svc, _, info0 = _service(64, seed=0, warmup=True)
        assert svc._planner.algorithm.startswith("binpack")
        assert info0["warmed_shapes"] > 0
        before = jit_cache_stats()
        _, info = svc.add_input(
            rng.normal(size=(1, 8)).astype(np.float32), 0.2)
        after = jit_cache_stats()
        # the cold tail: zero new programs AND zero new arg shapes on the
        # very first edit after load_table
        assert after["misses"] == before["misses"]
        assert after["shape_misses"] == before["shape_misses"]
        assert info["dirty_reducers"] >= 1

    def test_warmup_counts_into_executor_stats(self):
        _, svc, _, info0 = _service(64, seed=0, warmup=True)
        assert svc.executor_stats()["warmed_shapes"] == \
            info0["warmed_shapes"]

    def test_warmup_off_by_request(self):
        _, svc, _, info0 = _service(64, seed=0, warmup=False)
        assert info0["warmed_shapes"] == 0

    def test_x2y_first_edits_compile_nothing_new(self):
        rng = np.random.default_rng(0)
        d, q = 8, 4.0
        wx = np.clip(rng.zipf(1.6, 24) / 8.0, 0.05, 0.45 * q)
        wy = np.clip(rng.zipf(1.6, 16) / 8.0, 0.05, 0.45 * q)
        inc = st.IncrementalX2YPlanner(q, wx=wx, wy=wy)
        ex = make_executor("streaming")
        fn = _block_fn_x2y("dot")
        X = rng.normal(size=(24, d)).astype(np.float32)
        Y = rng.normal(size=(16, d)).astype(np.float32)
        ex.run_x2y((jnp.asarray(X), jnp.asarray(Y)), inc.plan(),
                   fn, (24, 16))
        warmed = ex.warm_delta_shapes_x2y(
            (jnp.asarray(X), jnp.asarray(Y)), inc.delta_shapes(), fn)
        assert warmed > 0
        before = jit_cache_stats()
        delta = inc.insert_x(0.7)
        X = np.concatenate([X, rng.normal(size=(1, d)).astype(np.float32)])
        ex.apply_delta_x2y((jnp.asarray(X), jnp.asarray(Y)), delta, fn,
                           (X.shape[0], Y.shape[0]), plan_provider=inc.plan)
        delta = inc.insert_y(0.5)
        Y = np.concatenate([Y, rng.normal(size=(1, d)).astype(np.float32)])
        ex.apply_delta_x2y((jnp.asarray(X), jnp.asarray(Y)), delta, fn,
                           (X.shape[0], Y.shape[0]), plan_provider=inc.plan)
        after = jit_cache_stats()
        assert after["misses"] == before["misses"]
        assert after["shape_misses"] == before["shape_misses"]


class TestMaxGapCeiling:
    def test_ceiling_fires_when_relative_trigger_is_dead(self):
        # the BENCH_stream regression: disable the relative trigger
        # entirely (replan_drift=1e9 — the old behaviour for a schema
        # whose theorem gap starts ~2x) and drift the profile with
        # deletions; the absolute ceiling on the achievable gap must
        # still fire
        rng, svc, _, _ = _service(
            128, seed=0, warmup=False, replan_drift=1e9, max_gap=1.05)
        planner = svc._planner
        assert planner.algorithm.startswith("binpack")
        # Thm 8 is loose for binpack-k2: the theorem gap sits far above
        # the achievable gap from the very first plan
        assert planner.optimality_gap > 1.5
        assert planner.achievable_gap < 1.3
        for _ in range(64):
            act = planner.active_ids()
            if len(act) <= 6:
                break
            svc.remove_input(int(rng.choice(act)))
            # the relative trigger alone would never have fired
            assert planner.gap_drift < 1e9
        assert planner.stats["drift_replans"] >= 1
        assert svc.stats["stream_replans"] >= 1
        _assert_conformant(planner)

    def test_lower_bound_recomputed_on_every_path(self):
        # repair, drift-replan and repack paths must all report bounds
        # for the *live* profile
        rng, svc, _, _ = _service(
            96, seed=0, warmup=False, max_gap=1.1, repack_gap=1.02)
        planner = svc._planner
        for _ in range(48):
            act = planner.active_ids()
            if rng.random() < 0.4 or len(act) <= 6:
                svc.add_input(rng.normal(size=(1, 8)).astype(np.float32),
                              float(_zipf(rng, 1, svc.q)[0]))
            else:
                svc.remove_input(int(rng.choice(act)))
            fresh = a2a_comm_lower_bound(planner.active_weights(), svc.q)
            assert planner.lower_bound == pytest.approx(fresh, rel=1e-12)
            assert planner.achievable_gap >= 1.0 - 1e-9
        # the churn exercised at least one non-repair path
        s = planner.stats
        assert s["drift_replans"] + s["repacks"] >= 1

    def test_x2y_ceiling_fires(self):
        rng = np.random.default_rng(1)
        q = 4.0
        wx = np.clip(rng.zipf(1.6, 32) / 8.0, 0.05, 0.45 * q)
        wy = np.clip(rng.zipf(1.6, 24) / 8.0, 0.05, 0.45 * q)
        inc = st.IncrementalX2YPlanner(q, wx=wx, wy=wy,
                                       replan_drift=1e9, max_gap=1.05)
        for _ in range(30):
            ax, ay = inc.active_x_ids(), inc.active_y_ids()
            if len(ax) > 4 and rng.random() < 0.6:
                delta = inc.delete_x(int(rng.choice(ax)))
            elif len(ay) > 4:
                delta = inc.delete_y(int(rng.choice(ay)))
            else:
                break
            delta.verify_x2y(inc.x_expanded(), inc.y_expanded(),
                             inc.active_x_ids(), inc.active_y_ids())
        assert inc.stats["drift_replans"] >= 1


class TestBackgroundReplan:
    def test_edits_during_inflight_replan_stay_correct(self):
        rng, svc, sims, _ = _service(
            64, seed=0, warmup=False, max_gap=1.02, background=True)
        planner = svc._planner
        pending = swaps = 0
        for _ in range(40):
            act = planner.active_ids()
            if rng.random() < 0.3 or len(act) < 6:
                sims, info = svc.add_input(
                    rng.normal(size=(1, 8)).astype(np.float32),
                    float(_zipf(rng, 1, svc.q)[0]))
            else:
                sims, info = svc.remove_input(int(rng.choice(act)))
            pending += int(info["replan_pending"])
            swaps += int(info["swap"])
            assert not info["full_replan"]
            ref, act = _cold_dense(svc)
            got = np.asarray(sims)[np.ix_(act, act)]
            np.testing.assert_allclose(got, ref, **TOL)
        # the replan genuinely ran off the edit path and landed
        assert pending >= 1
        assert swaps >= 1
        assert planner.stats["swaps"] == swaps == \
            svc.stats["stream_swaps"]
        # double-buffering: the executor's cold build was paid exactly
        # once, at load time — never on a replan
        assert svc.executor_stats()["full_builds"] == 1

    def test_swap_preserves_conformance_and_flush(self):
        rng, svc, _, _ = _service(
            64, seed=0, warmup=False, max_gap=1.02, background=True)
        planner = svc._planner
        for _ in range(40):
            act = planner.active_ids()
            if rng.random() < 0.3 or len(act) < 6:
                svc.add_input(rng.normal(size=(1, 8)).astype(np.float32),
                              float(_zipf(rng, 1, svc.q)[0]))
            else:
                svc.remove_input(int(rng.choice(act)))
            _assert_conformant(planner)
        svc.flush_replan()  # drain any still-in-flight plan
        _assert_conformant(planner)


class TestRepack:
    def test_deletion_churn_triggers_repack(self):
        rng, svc, sims, _ = _service(
            128, seed=0, warmup=False, max_gap=3.0, repack_gap=1.0)
        planner = svc._planner
        repack_edits = 0
        for _ in range(64):
            act = planner.active_ids()
            if len(act) <= 6:
                break
            sims, info = svc.remove_input(int(rng.choice(act)))
            repack_edits += int(info["repack"])
        s = planner.stats
        assert s["repacks"] >= 1
        assert s["migrations"] >= 1
        assert repack_edits == s["repacks"] == svc.stats["stream_repacks"]
        # repacking is pure planning-state surgery: the served matrix is
        # untouched and still matches a cold re-plan
        ref, act = _cold_dense(svc)
        got = np.asarray(sims)[np.ix_(act, act)]
        np.testing.assert_allclose(got, ref, **TOL)
        _assert_conformant(planner)

    def test_repack_never_increases_cost(self):
        # churn with repacking disabled, then invoke the pass directly:
        # on a fixed profile, committed migrations + pruning can only
        # shave communication cost
        rng, svc, _, _ = _service(
            128, seed=0, warmup=False, replan_drift=1e9, max_gap=None)
        planner = svc._planner
        for _ in range(48):
            act = planner.active_ids()
            if len(act) <= 6:
                break
            svc.remove_input(int(rng.choice(act)))
        assert planner.kind == "binpack"
        cost_before = planner.comm_cost
        moved, pruned = planner._repack_pass()
        assert moved + pruned >= 1
        assert planner.comm_cost <= cost_before + 1e-9
        _assert_conformant(planner)
