"""Rectangular (X2Y) execution: kernel, partition, streaming, skew join.

The X2Y differential suite behind the conformance matrix: the rectangular
fused gather+Gram kernel against its materializing oracle (multi-tile,
bf16, masked tails, non-power-of-two |X| != |Y|), ``partition_plan``
invariants on rectangular sub-plans, streaming edits on both the X and Y
sides with ``PlanDelta.verify_x2y`` coverage proofs and
streamed == cold-dense equality after every edit, and the
``skew_join(executor=...)`` regression on the paper's Example 3
heavy-hitter profile.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition_plan, plan_x2y
from repro.core.planner import reducer_work
from repro.kernels.pairwise.fused_gather_gram import (
    fused_gather_gram_rect,
    fused_gather_gram_rect_ref,
    fused_gather_gram_rect_streamed,
)
from repro.mapreduce import build_x2y_plan, skew_join
from repro.mapreduce.allpairs import (
    _block_fn_x2y,
    block_similarity_x2y,
    x2y_similarity,
)

TOL = dict(rtol=1e-5, atol=1e-5)


def _rect_case(R, Lx, Ly, mx, my, d, seed, dtype=np.float32,
               tail_masks=True):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(mx, d)).astype(dtype))
    y = jnp.asarray(rng.normal(size=(my, d)).astype(dtype))
    xidx = jnp.asarray(rng.integers(0, mx, size=(R, Lx)), jnp.int32)
    yidx = jnp.asarray(rng.integers(0, my, size=(R, Ly)), jnp.int32)
    if tail_masks:
        xmask = jnp.asarray(
            np.arange(Lx)[None, :] < rng.integers(1, Lx + 1, size=(R, 1)))
        ymask = jnp.asarray(
            np.arange(Ly)[None, :] < rng.integers(1, Ly + 1, size=(R, 1)))
    else:
        xmask = jnp.ones((R, Lx), bool)
        ymask = jnp.ones((R, Ly), bool)
    return x, y, xidx, xmask, yidx, ymask


class TestRectKernel:
    """Rect Pallas kernel (interpret mode) == streamed twin == oracle."""

    @pytest.mark.parametrize("R,Lx,Ly,bl", [
        (3, 8, 8, 8),              # single tile per side
        (5, 19, 11, 8),            # multi-tile, masked tails, |X| != |Y|
        (4, 9, 9, 8),              # square through the rect path
        (2, 7, 23, 8),             # non-pow2, Y side much wider
    ])
    def test_kernel_matches_reference(self, R, Lx, Ly, bl):
        x, y, xidx, xmask, yidx, ymask = _rect_case(
            R, Lx, Ly, mx=31, my=17, d=6, seed=R + Lx)
        ref = fused_gather_gram_rect_ref(x, y, xidx, xmask, yidx, ymask)
        got = fused_gather_gram_rect(x, y, xidx, xmask, yidx, ymask,
                                     bl=bl, interpret=True)
        streamed = fused_gather_gram_rect_streamed(x, y, xidx, xmask,
                                                   yidx, ymask, bl=bl)
        assert got.shape == (R, Lx, Ly)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
        np.testing.assert_allclose(np.asarray(streamed), np.asarray(ref),
                                   **TOL)

    def test_bf16_tables_accumulate_fp32(self):
        x, y, xidx, xmask, yidx, ymask = _rect_case(
            4, 12, 7, mx=20, my=15, d=8, seed=0)
        xb = x.astype(jnp.bfloat16)
        yb = y.astype(jnp.bfloat16)
        ref = fused_gather_gram_rect_ref(xb, yb, xidx, xmask, yidx, ymask)
        got = fused_gather_gram_rect(xb, yb, xidx, xmask, yidx, ymask,
                                     bl=8, interpret=True)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-2, atol=1e-2)

    def test_all_masked_rows_are_zero(self):
        x, y, xidx, _, yidx, ymask = _rect_case(
            3, 5, 4, mx=9, my=9, d=3, seed=2, tail_masks=False)
        xmask = jnp.zeros((3, 5), bool)
        got = fused_gather_gram_rect(x, y, xidx, xmask, yidx, ymask,
                                     bl=8, interpret=True)
        np.testing.assert_allclose(np.asarray(got), 0.0)

    def test_zero_reducers(self):
        x, y, *_ = _rect_case(1, 4, 4, mx=5, my=5, d=3, seed=3)
        e = jnp.zeros((0, 4), jnp.int32)
        m = jnp.zeros((0, 4), bool)
        got = fused_gather_gram_rect(x, y, e, m, e, m, bl=8,
                                     interpret=True)
        assert got.shape == (0, 4, 4)


class TestRectPartition:
    """``partition_plan`` on rectangular plans: coverage, both-side
    sub-plan fidelity, and rect-aware (wx + wy + flop*wx*wy) work."""

    def _plan(self, seed=0, q=8.0):
        rng = np.random.default_rng(seed)
        wx = rng.integers(1, 4, size=14).astype(float)
        wy = rng.integers(1, 3, size=10).astype(float)
        schema = plan_x2y(wx, wy, q)
        return build_x2y_plan(schema, 14)

    @pytest.mark.parametrize("num_shards", [2, 3, 8])
    def test_partition_preserves_rect_rows(self, num_shards):
        plan = self._plan()
        part = partition_plan(plan, num_shards)
        all_rows = np.sort(np.concatenate(list(part.shard_rows)))
        np.testing.assert_array_equal(all_rows,
                                      np.arange(plan.num_reducers))
        assert part.ywidths is not None
        for rows, sub in zip(part.shard_rows, part.shards):
            np.testing.assert_array_equal(sub.idx, plan.idx[rows])
            np.testing.assert_array_equal(sub.mask, plan.mask[rows])
            # the Y side travels with the sub-plan
            np.testing.assert_array_equal(sub.yidx, plan.yidx[rows])
            np.testing.assert_array_equal(sub.ymask, plan.ymask[rows])
            assert sub.num_x == plan.num_x and sub.num_y == plan.num_y

    def test_rect_reducer_work_counts_both_sides(self):
        plan = self._plan()
        work = reducer_work(plan, flop_weight=0.0)
        xs = plan.mask[: plan.num_reducers].sum(axis=1)
        # zero flop weight -> work is the two execution widths summed,
        # which upper-bounds the true slot counts
        assert np.all(work[: plan.num_reducers] >= xs)

    def test_shipped_slots_count_both_sides(self):
        plan = self._plan()
        part = partition_plan(plan, 4)
        total = plan.mask[: plan.num_reducers].sum() \
            + plan.ymask[: plan.num_reducers].sum()
        assert int(part.shipped_rows.sum()) == int(total)


class TestStreamingX2Y:
    """Insert/delete on both sides: every delta's coverage proof passes
    and the patched matrix equals a cold dense build after every edit."""

    def _cold_dense(self, inc, X, Y):
        ax, ay = inc.active_x_ids(), inc.active_y_ids()
        out = np.zeros((len(inc.wx), len(inc.wy)), np.float32)
        if len(ax) and len(ay):
            out[np.ix_(ax, ay)] = np.asarray(X)[ax] @ np.asarray(Y)[ay].T
        return out

    def test_edit_stream_matches_cold_dense(self):
        import repro.stream as st
        from repro.mapreduce import make_executor

        rng = np.random.default_rng(7)
        d, q = 4, 8.0
        inc = st.IncrementalX2YPlanner(q, wx=[2.0, 1.0, 3.0],
                                       wy=[1.0, 2.0])
        ex = make_executor("streaming")
        fn = _block_fn_x2y("dot")
        X = rng.normal(size=(3, d)).astype(np.float32)
        Y = rng.normal(size=(2, d)).astype(np.float32)

        sims = ex.run_x2y((jnp.asarray(X), jnp.asarray(Y)), inc.plan(),
                          fn, (3, 2))
        np.testing.assert_allclose(np.asarray(sims),
                                   self._cold_dense(inc, X, Y), **TOL)

        ops = [("ix", 1.5), ("iy", 2.5), ("dx", 1), ("iy", 0.5),
               ("ix", 2.0), ("dy", 0), ("ix", 1.0), ("iy", 1.5),
               ("dx", 0), ("ix", 3.0), ("dy", 2), ("iy", 2.0)]
        saw_delta = saw_both_sides = 0
        for kind, arg in ops:
            if kind == "ix":
                delta = inc.insert_x(arg)
                X = np.concatenate(
                    [X, rng.normal(size=(1, d)).astype(np.float32)])
            elif kind == "iy":
                delta = inc.insert_y(arg)
                Y = np.concatenate(
                    [Y, rng.normal(size=(1, d)).astype(np.float32)])
            elif kind == "dx":
                delta = inc.delete_x(arg)
            else:
                delta = inc.delete_y(arg)
            # re-run the coverage proof explicitly (check=True already ran
            # it on the dirty subset; this is the full-expansion variant)
            delta.verify_x2y(inc.x_expanded(), inc.y_expanded(),
                             inc.active_x_ids(), inc.active_y_ids())
            sims = ex.apply_delta_x2y(
                (jnp.asarray(X), jnp.asarray(Y)), delta, fn,
                (X.shape[0], Y.shape[0]), plan_provider=inc.plan)
            np.testing.assert_allclose(
                np.asarray(sims), self._cold_dense(inc, X, Y),
                err_msg=f"{kind}({arg}) kind={delta.kind}", **TOL)
            saw_delta += int(not delta.full_replan)
            saw_both_sides += int(delta.kind in ("insert_y", "delete_y"))
        # the stream actually exercised the patch path on both sides
        assert saw_delta > 0 and saw_both_sides > 0
        st_stats = ex.stats()
        assert st_stats["delta_updates"] > 0

    def test_insert_infeasible_rolls_back(self):
        import repro.stream as st
        inc = st.IncrementalX2YPlanner(4.0, wx=[2.0], wy=[1.0])
        from repro.core.schema import InfeasibleError
        with pytest.raises(InfeasibleError):
            inc.insert_x(100.0)
        assert len(inc.wx) == 1 and inc.num_active_x == 1

    def test_one_sided_bootstrap(self):
        """Start with only X inputs (no cross pairs), then grow Y."""
        import repro.stream as st
        inc = st.IncrementalX2YPlanner(6.0, wx=[2.0, 3.0])
        assert inc.num_reducers == 0 and inc.comm_cost == 0.0
        delta = inc.insert_y(2.0)          # first Y forces a real split
        # forced re-plans are patch deltas now: the fresh plan is adopted
        # as planning state, but only the new input's reducers recompute
        assert delta.meta.get("replan") and delta.meta.get("forced")
        assert not delta.full_replan
        assert len(delta.dirty_rows) >= 1
        assert inc.num_reducers >= 1
        plan = inc.plan()
        assert plan.is_rect
        # every live cross pair covered
        covered = {(i, j)
                   for xs, ys in zip(inc.x_expanded(), inc.y_expanded())
                   for i in xs for j in ys}
        want = {(int(i), int(j)) for i in inc.active_x_ids()
                for j in inc.active_y_ids()}
        assert want <= covered


class TestSkewJoinExecutors:
    """Example 3 heavy-hitter profile: join through every executor equals
    the dense join (the documented ``executor=`` contract is real)."""

    def _example3(self):
        # one heavy B-value: 200 X-tuples, 8 Y-tuples, sizes skewed
        rng = np.random.default_rng(42)
        mx, my = 40, 8                     # scaled-down Example 3 shape
        xv = rng.normal(size=(mx, 3)).astype(np.float32)
        yv = rng.normal(size=(my, 2)).astype(np.float32)
        wx = rng.uniform(0.01, 0.1, mx)
        wx[0] = 2.0                        # the heavy hitter
        wy = rng.uniform(0.01, 0.5, my)
        return xv, yv, wx, wy, 4.0

    @pytest.mark.parametrize("executor",
                             ["bucketed", "fused", "sharded", "streaming"])
    def test_join_matches_dense(self, executor):
        xv, yv, wx, wy, q = self._example3()
        ref, schema = skew_join(jnp.asarray(xv), jnp.asarray(yv), q=q,
                                wx=wx, wy=wy, executor="dense")
        out, _ = skew_join(jnp.asarray(xv), jnp.asarray(yv), q=q,
                           wx=wx, wy=wy, schema=schema, executor=executor)
        assert out.shape == ref.shape == (40, 8, 5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)

    def test_fused_counts_fallback_not_silence(self):
        """The join's reducer is not a Gram block: the fused executor must
        take (and count) its fallback rather than mis-fusing."""
        from repro.mapreduce import make_executor
        from repro.mapreduce.allpairs import _x2y_plan_for
        from repro.mapreduce.skewjoin import join_block
        xv, yv, wx, wy, q = self._example3()
        schema = plan_x2y(wx, wy, q)
        plan = _x2y_plan_for(schema, len(wx), pad_reducers_to=1,
                             pad_slots_to=1)
        ex = make_executor("fused")
        ex.run_x2y((jnp.asarray(xv), jnp.asarray(yv)), plan, join_block,
                   (len(wx), len(wy)))
        assert ex.stats()["fallbacks"] == 1


class TestX2YSimilarityExecutors:
    """x2y_similarity differential: all executors, all metrics."""

    @pytest.mark.parametrize("metric", ["dot", "l2", "cosine"])
    @pytest.mark.parametrize("executor",
                             ["bucketed", "fused", "sharded", "streaming"])
    def test_matches_dense(self, metric, executor):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(13, 5)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(9, 5)), jnp.float32)
        wx = rng.integers(1, 4, size=13).astype(float)
        wy = rng.integers(1, 3, size=9).astype(float)
        q = float(wx.max() + wy.max() + 1)
        ref, plan, schema = x2y_similarity(x, y, q=q, wx=wx, wy=wy,
                                           metric=metric, executor="dense")
        out, _, _ = x2y_similarity(x, y, q=q, schema=schema, metric=metric,
                                   executor=executor)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
        # the dense result itself equals the direct formula
        direct = block_similarity_x2y(x, jnp.ones(13, bool), y,
                                      jnp.ones(9, bool), metric=metric)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(direct),
                                   **TOL)

    def test_fused_kernel_interpret_path(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(11, 4)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)
        ref, plan, schema = x2y_similarity(x, y, q=6.0, metric="cosine",
                                           executor="dense")
        out, _, _ = x2y_similarity(x, y, q=6.0, schema=schema,
                                   metric="cosine", executor="fused",
                                   use_kernel=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)

    def test_square_degenerate_case_matches_allpairs(self):
        """X == Y through the rect path reproduces the square all-pairs
        result off the diagonal (the rect path has no self-pairs to
        zero)."""
        from repro.mapreduce.allpairs import pairwise_similarity
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(10, 4)), jnp.float32)
        sq, _, _ = pairwise_similarity(x, q=4.0, executor="bucketed")
        rect, _, _ = x2y_similarity(x, x, q=8.0, executor="bucketed")
        sq = np.asarray(sq)
        rect = np.asarray(rect)
        off = ~np.eye(10, dtype=bool)
        np.testing.assert_allclose(rect[off], sq[off], **TOL)
