"""Hierarchical planner + sparse block serving (DESIGN.md section 1h).

Covers the composed optimality-gap ledger (gap_total == gap_outer *
gap_inner, and the measured gap it provably bounds), the array-native
prefix pack against the FFD/BFD oracles, PlanCache keying by grouping
factor, sampled pair-coverage conformance at large m, and run_block
against the dense executor over a full cross-check grid.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PLAN_CACHE,
    choose_grouping_factor,
    plan_a2a,
    plan_a2a_hierarchical,
    sampled_pair_coverage,
)
from repro.core.binpack import (
    ffd_reference,
    num_bins_lower_bound,
    pack,
    pack_prefix,
    prefix_bins,
)
from repro.core.bounds import a2a_comm_lower_bound
from repro.core.schema import InfeasibleError


# ------------------------------------------------------------- prefix pack
class TestPackPrefix:
    def test_capacity_and_count_guarantee(self):
        rng = np.random.default_rng(0)
        for _ in range(100):
            n = int(rng.integers(1, 80))
            w = rng.uniform(0.01, 1.0, n)
            b = float(rng.uniform(1.0, 3.0))
            bin_of = pack_prefix(w, b)
            sums = np.bincount(bin_of, weights=w)
            assert sums.max() <= b + 1e-9
            s = w.sum()
            # half-full guarantee, same form as Theorem 10's 2s/b
            assert bin_of.max() + 1 <= int(np.ceil(2 * s / b)) + 1
            assert bin_of.max() + 1 >= num_bins_lower_bound(w, b)

    def test_assignment_is_a_partition(self):
        rng = np.random.default_rng(1)
        w = rng.uniform(0.05, 0.9, 200)
        bin_of = pack_prefix(w, 2.0)
        bins = prefix_bins(w, 2.0)
        assert sorted(i for g in bins for i in g) == list(range(200))
        assert set(bin_of) == set(range(int(bin_of.max()) + 1))
        for gid, g in enumerate(bins):
            assert all(bin_of[i] == gid for i in g)

    def test_close_to_ffd_oracle(self):
        """Next-fit decreasing trails FFD by a bounded factor; at uniform
        profiles the slack stays well under the 2x the ledger allows."""
        rng = np.random.default_rng(2)
        w = rng.uniform(0.01, 1.0, 500)
        nf = len(ffd_reference(w, 2.0))
        npx = int(pack_prefix(w, 2.0).max()) + 1
        assert npx <= int(np.ceil(1.5 * nf)) + 1

    def test_pack_dispatch_and_edges(self):
        assert pack_prefix([], 1.0).size == 0
        assert prefix_bins([], 1.0) == []
        assert pack_prefix([1.0], 1.0).tolist() == [0]
        assert pack_prefix([0.9, 0.8, 0.7], 1.0).tolist() == [0, 1, 2]
        w = np.random.default_rng(3).uniform(0.1, 0.9, 40)
        assert pack(w, 2.0, method="prefix") == prefix_bins(w, 2.0)
        with pytest.raises(ValueError):
            pack_prefix([0.5], -1.0)
        with pytest.raises(ValueError):
            pack_prefix([1.5], 1.0)  # item does not fit


# ------------------------------------------------------------- gap ledger
class TestGapLedger:
    @pytest.mark.parametrize("m,c,seed", [(60, 1, 0), (150, 2, 1),
                                          (300, 3, 2), (200, 2, 3)])
    def test_product_identity_and_bound(self, m, c, seed):
        rng = np.random.default_rng(seed)
        q = 30.0
        w = rng.uniform(0.1, q / (2 * c), m) * 0.999
        schema = plan_a2a_hierarchical(w, q, c=c, use_cache=False)
        schema.validate("a2a")
        h = schema.meta["hierarchy"]
        assert h["gap_total"] == pytest.approx(
            h["gap_outer"] * h["gap_inner"], abs=1e-12)
        gap = schema.optimality_gap()
        if gap is not None:
            # flattening preserves cost and Thm-8 bound: measured == outer
            assert gap == pytest.approx(h["gap_outer"], rel=1e-9)
            assert gap <= h["gap_total"] + 1e-9
        assert schema.communication_cost() >= \
            a2a_comm_lower_bound(w, q) - 1e-9

    def test_ledger_fields(self):
        rng = np.random.default_rng(4)
        w = rng.uniform(0.2, 1.0, 400)
        schema = plan_a2a_hierarchical(w, 24.0, c=4, use_cache=False)
        h = schema.meta["hierarchy"]
        assert h["c"] == 4 and h["b"] == pytest.approx(3.0)
        assert h["num_super"] >= h["inner_bins_lb"]
        assert h["gap_inner"] >= 1.0 and h["gap_outer"] >= 1.0 - 1e-9
        assert schema.algorithm.startswith("hier-c4+")


# ------------------------------------------------------------ cache by c
class TestPlanCacheByGroupingFactor:
    def test_keyed_by_profile_and_c(self):
        """Satellite regression: hierarchical entries are keyed by
        (profile, c) — changing c misses instead of colliding, and flat
        plans for the same profile stay separate entries."""
        rng = np.random.default_rng(5)
        w = rng.uniform(0.3, 1.5, 300)
        q = 40.0
        PLAN_CACHE.clear()
        s2 = plan_a2a_hierarchical(w, q, c=2)
        after_miss = PLAN_CACHE.stats()
        s2b = plan_a2a_hierarchical(w, q, c=2)
        after_hit = PLAN_CACHE.stats()
        assert s2b is s2
        assert after_hit["hits"] == after_miss["hits"] + 1
        assert after_hit["misses"] == after_miss["misses"]

        s3 = plan_a2a_hierarchical(w, q, c=3)
        assert s3 is not s2
        assert s3.meta["hierarchy"]["c"] == 3
        assert s2.meta["hierarchy"]["c"] == 2

        flat = plan_a2a(w, q)
        assert "hierarchy" not in flat.meta
        # and the flat entry did not evict or alias the hierarchical ones
        assert plan_a2a_hierarchical(w, q, c=2) is s2


# ----------------------------------------------------------- planner paths
class TestHierarchicalPlanner:
    def test_auto_small_m_falls_back_flat(self):
        w = np.random.default_rng(6).uniform(0.2, 0.5, 64)
        schema = plan_a2a_hierarchical(w, 4.0, use_cache=False)
        assert "hierarchy" not in schema.meta
        schema.validate("a2a")

    def test_auto_big_input_falls_back_flat(self):
        w = np.random.default_rng(7).uniform(0.02, 0.1, 5000)
        w[0] = 0.8
        schema = plan_a2a_hierarchical(w, 1.0, use_cache=False,
                                       target_super=256)
        assert "hierarchy" not in schema.meta

    def test_auto_large_m_groups(self):
        w = np.random.default_rng(8).uniform(0.01, 0.05, 20000)
        schema = plan_a2a_hierarchical(w, 2.0, use_cache=False,
                                       target_super=512)
        h = schema.meta["hierarchy"]
        assert h["c"] >= 1 and h["num_super"] < 20000
        assert sampled_pair_coverage(schema, 1024, seed=0) == 1.0

    def test_explicit_c_infeasible_raises(self):
        w = np.array([0.4, 0.3, 0.2])
        with pytest.raises(InfeasibleError):
            plan_a2a_hierarchical(w, 1.0, c=2)  # b = 0.25 < wmax
        with pytest.raises(ValueError):
            plan_a2a_hierarchical(w, 1.0, c=0)
        with pytest.raises(InfeasibleError):
            plan_a2a_hierarchical(np.array([1.5]), 1.0, c=1)

    def test_choose_grouping_factor(self):
        w = np.full(100000, 0.01)
        assert choose_grouping_factor(w, 2.0, target_super=1000) >= 1
        # an input above q/2 makes grouping impossible
        assert choose_grouping_factor(np.array([0.9, 0.1]), 1.0) == 0
        assert choose_grouping_factor(np.zeros(0), 1.0) == 0
        # clamp: c never pushes b below wmax
        c = choose_grouping_factor(np.full(1000, 0.4), 2.0,
                                   target_super=10**6)
        assert 2.0 / (2 * c) >= 0.4


# --------------------------------------------------- sampled pair coverage
class TestSampledCoverage:
    def test_large_m_hierarchical(self):
        m = 100_000
        w = 1.0 / (np.arange(1, m + 1) ** 0.5)
        w = w / w.max()
        rng = np.random.default_rng(9)
        rng.shuffle(w)
        q = 20.0
        schema = plan_a2a_hierarchical(w, q)
        h = schema.meta["hierarchy"]
        assert h["gap_total"] >= schema.optimality_gap() - 1e-9
        assert sampled_pair_coverage(schema, 4096, seed=1) == 1.0

    def test_flat_schema_also_supported(self):
        w = np.random.default_rng(10).uniform(0.1, 0.45, 200)
        schema = plan_a2a(w, 1.0)
        if schema.meta.get("bins_overlap", False):
            pytest.skip("sampled coverage requires disjoint bins")
        assert sampled_pair_coverage(schema, 2048, seed=2) == 1.0

    def test_detects_broken_schema(self):
        """The sampler must actually look: drop a reducer and coverage
        falls below 1."""
        w = np.random.default_rng(11).uniform(0.1, 0.45, 100)
        schema = plan_a2a(w, 1.0, use_cache=False)
        assert len(schema.reducers) > 1
        schema.reducers.pop()
        assert sampled_pair_coverage(schema, 4096, seed=3) < 1.0


# -------------------------------------------------------- block execution
class TestRunBlockGrid:
    @pytest.mark.parametrize("executor", ["bucketed", "fused"])
    def test_full_grid_matches_dense(self, executor):
        from repro.mapreduce.allpairs import (
            pairwise_similarity,
            pairwise_similarity_block,
        )
        rng = np.random.default_rng(12)
        m, d, q = 160, 6, 18.0
        x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        w = rng.uniform(0.4, 2.0, m)
        schema = plan_a2a_hierarchical(w, q, c=2, use_cache=False)
        ref, _, _ = pairwise_similarity(x, q=q, schema=schema,
                                        executor="dense")
        ref = np.asarray(ref)
        B = 48  # uneven tail blocks included
        for i0 in range(0, m, B):
            for j0 in range(0, m, B):
                i1, j1 = min(i0 + B, m), min(j0 + B, m)
                blk, sparse, _ = pairwise_similarity_block(
                    x, i0, i1, j0, j1, q=q, schema=schema,
                    executor=executor)
                np.testing.assert_allclose(
                    np.asarray(blk), ref[i0:i1, j0:j1],
                    rtol=1e-5, atol=1e-5,
                    err_msg=f"block [{i0}:{i1})x[{j0}:{j1})")
        assert sparse.host_entries < m * m

    def test_serve_block_api(self):
        from repro.mapreduce.allpairs import pairwise_similarity
        from repro.serve import PairwiseService
        rng = np.random.default_rng(13)
        m, d, q = 96, 5, 14.0
        x = rng.normal(size=(m, d)).astype(np.float32)
        w = rng.uniform(0.5, 2.0, m)
        svc = PairwiseService(q, metric="dot", executor="bucketed")
        info = svc.load_block_table(x, w)
        assert info["host_entries"] < m * m
        ref, _, _ = pairwise_similarity(jnp.asarray(x), q=q, weights=w,
                                        executor="dense")
        ref = np.asarray(ref)
        blk, binfo = svc.block(8, 72, 30, 96)
        np.testing.assert_allclose(np.asarray(blk), ref[8:72, 30:96],
                                   rtol=1e-5, atol=1e-5)
        assert svc.stats["block_requests"] == 1
        assert binfo["block_calls"] >= 1

    def test_out_of_range_block_raises(self):
        from repro.mapreduce import build_sparse_plan, block_subplan
        w = np.random.default_rng(14).uniform(0.1, 0.25, 50)
        schema = plan_a2a(w, 1.0)
        sparse = build_sparse_plan(schema)
        with pytest.raises(IndexError):
            block_subplan(sparse, 0, 60, 0, 10)


# -------------------------------------------------------- block plan cache
class TestBlockCacheConfig:
    def _sparse(self, m=60, seed=15):
        from repro.mapreduce import build_sparse_plan
        w = np.random.default_rng(seed).uniform(0.1, 0.25, m)
        return build_sparse_plan(plan_a2a(w, 1.0))

    def test_eviction_order_is_lru(self):
        """Regression: the cache evicts least-recently-USED, not
        least-recently-inserted — touching an old block must protect it."""
        from repro.mapreduce import block_cache_stats, block_subplan
        sparse = self._sparse()
        blocks = [(0, 20), (20, 40), (40, 60)]

        def req(b):
            i0, i1 = b
            return block_subplan(sparse, i0, i1, i0, i1, cache_size=2)

        req(blocks[0])                        # cache: [A]
        req(blocks[1])                        # cache: [A, B]
        before = block_cache_stats()
        req(blocks[0])                        # touch A -> cache: [B, A]
        req(blocks[2])                        # insert C -> evicts B
        req(blocks[0])                        # A survived: hit
        delta = {k: block_cache_stats()[k] - before[k]
                 for k in ("hits", "misses", "evictions")}
        assert delta == {"hits": 2, "misses": 1, "evictions": 1}
        cache = sparse.__dict__["_block_cache"]
        kept = {key[:2] for key in cache}
        assert kept == {blocks[0], blocks[2]}
        req(blocks[1])                        # B was evicted: miss again
        assert block_cache_stats()["misses"] - before["misses"] == 2

    def test_configure_and_env_cap(self, monkeypatch):
        from repro.mapreduce import configure_block_cache
        from repro.mapreduce import engine as eng
        old = eng._BLOCK_CACHE_MAX
        try:
            assert configure_block_cache(7) == 7
            assert eng._BLOCK_CACHE_MAX == 7
            monkeypatch.setenv("REPRO_BLOCK_CACHE_SIZE", "13")
            assert configure_block_cache() == 13
            monkeypatch.setenv("REPRO_BLOCK_CACHE_SIZE", "bogus")
            assert configure_block_cache() == 64     # malformed -> default
            monkeypatch.setenv("REPRO_BLOCK_CACHE_SIZE", "-2")
            assert configure_block_cache() == 64     # non-positive -> default
            with pytest.raises(AssertionError):
                configure_block_cache(0)
        finally:
            configure_block_cache(old)

    def test_default_cap_applies_without_explicit_size(self):
        """cache_size=None takes the shared configurable cap."""
        from repro.mapreduce import block_subplan, configure_block_cache
        from repro.mapreduce import engine as eng
        sparse = self._sparse(seed=16)
        old = eng._BLOCK_CACHE_MAX
        try:
            configure_block_cache(1)
            block_subplan(sparse, 0, 20, 0, 20)
            block_subplan(sparse, 20, 40, 20, 40)
            assert len(sparse.__dict__["_block_cache"]) == 1
        finally:
            configure_block_cache(old)
