"""Fault tolerance: atomic checkpoints, crash-resume, elastic rescale,
straggler detection, preemption-safe data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.data import PackedLMDataset
from repro.train import AdamWConfig, CheckpointManager
from repro.train.elastic import (
    ElasticPolicy,
    StragglerMonitor,
    rescale_mesh_shape,
    scale_batch,
)
from repro.train.optimizer import adamw_init, adamw_update


def tiny_state(seed=0):
    k = jax.random.key(seed)
    params = {"w": jax.random.normal(k, (8, 8)),
              "b": jnp.zeros((8,), jnp.bfloat16)}
    cfg = AdamWConfig()
    return {"params": params, "opt": adamw_init(params, cfg),
            "step": jnp.zeros((), jnp.int32)}, cfg


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state, _ = tiny_state()
        mgr.save(5, state, extra={"data": {"seed": 0, "cursor": 3}})
        restored, manifest = mgr.restore(template=state)
        assert manifest["step"] == 5
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"], np.float32),
            np.asarray(state["params"]["w"], np.float32))
        # dtype restoration (bf16 survives npz round trip via template)
        assert restored["params"]["b"].dtype == np.dtype("bfloat16") or \
            str(restored["params"]["b"].dtype) == "bfloat16"
        assert manifest["extra"]["data"]["cursor"] == 3

    def test_keep_n_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state, _ = tiny_state()
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        steps = sorted(n for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]

    def test_crash_mid_save_keeps_previous(self, tmp_path):
        """A leftover tmp dir (simulated crash) never corrupts latest."""
        mgr = CheckpointManager(str(tmp_path), keep=3)
        state, _ = tiny_state()
        mgr.save(1, state)
        os.makedirs(os.path.join(str(tmp_path), ".tmp_crashed"))
        assert mgr.latest_step() == 1
        restored, m = mgr.restore()
        assert m["step"] == 1

    def test_restore_onto_new_mesh(self, tmp_path):
        """Elastic restore: same arrays, new shardings (1-device mesh)."""
        mgr = CheckpointManager(str(tmp_path), keep=1)
        state, _ = tiny_state()
        mgr.save(7, state)
        mesh = make_mesh((1,), ("data",))
        sh = jax.tree.map(
            lambda _: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), state)
        restored, _ = mgr.restore(shardings=sh, template=state)
        assert restored["params"]["w"].sharding.mesh.shape == {"data": 1}

    def test_training_resumes_identically(self, tmp_path):
        """Optimizer state + data cursor resume => bitwise-same trajectory."""
        state, cfg = tiny_state()
        g = {"w": jnp.ones((8, 8)) * 0.1, "b": jnp.ones((8,), jnp.bfloat16) * 0.1}
        # run 4 steps straight
        s_a = state
        for step in range(4):
            p, opt, _ = adamw_update(g, s_a["opt"], s_a["params"],
                                     jnp.asarray(step), cfg)
            s_a = {"params": p, "opt": opt, "step": s_a["step"] + 1}
        # run 2 steps, checkpoint, restore, run 2 more
        mgr = CheckpointManager(str(tmp_path), keep=1)
        s_b = state
        for step in range(2):
            p, opt, _ = adamw_update(g, s_b["opt"], s_b["params"],
                                     jnp.asarray(step), cfg)
            s_b = {"params": p, "opt": opt, "step": s_b["step"] + 1}
        mgr.save(2, s_b)
        s_b, _ = mgr.restore(template=s_b)
        for step in range(2, 4):
            p, opt, _ = adamw_update(g, s_b["opt"], s_b["params"],
                                     jnp.asarray(step), cfg)
            s_b = {"params": p, "opt": opt, "step": jnp.asarray(step + 1)}
        np.testing.assert_allclose(
            np.asarray(s_a["params"]["w"]), np.asarray(s_b["params"]["w"]),
            rtol=1e-6, atol=1e-7)


class TestElastic:
    def test_rescale_drops_replicas(self):
        pol = ElasticPolicy(min_data_parallel=2)
        new = rescale_mesh_shape({"pod": 2, "data": 16, "model": 16}, 30, pol)
        assert new == {"pod": 2, "data": 15, "model": 16}
        new = rescale_mesh_shape({"data": 16, "model": 16}, 12, pol)
        assert new == {"data": 12, "model": 16}

    def test_rescale_below_minimum(self):
        pol = ElasticPolicy(min_data_parallel=4)
        assert rescale_mesh_shape({"data": 16, "model": 16}, 3, pol) is None

    def test_batch_rescale_preserves_global(self):
        assert scale_batch(256, 16, 12) * 12 >= 256

    def test_straggler_eviction(self):
        pol = ElasticPolicy(straggler_factor=2.0, straggler_patience=3)
        mon = StragglerMonitor(4, pol, ema=0.0)
        for _ in range(5):
            for h in range(4):
                mon.observe(h, 10.0 if h != 2 else 50.0)
            evict = mon.update_flags()
        assert evict == [2]

    def test_healthy_fleet_no_eviction(self):
        pol = ElasticPolicy()
        mon = StragglerMonitor(8, pol)
        for _ in range(10):
            for h in range(8):
                mon.observe(h, 10.0 + 0.1 * h)
            assert mon.update_flags() == []


class TestDataPipelineResume:
    def test_cursor_resume_reproduces_stream(self):
        ds1 = PackedLMDataset(vocab_size=512, seq_len=128, batch_size=4,
                              seed=3)
        it1 = iter(ds1)
        batches = [next(it1) for _ in range(5)]
        state = ds1.state()
        after = [next(it1) for _ in range(2)]

        ds2 = PackedLMDataset(vocab_size=512, seq_len=128, batch_size=4,
                              seed=999)
        ds2.restore(state)
        it2 = iter(ds2)
        after2 = [next(it2) for _ in range(2)]
        for a, b in zip(after, after2):
            np.testing.assert_array_equal(a["tokens"], b["tokens"])
            np.testing.assert_array_equal(a["mask"], b["mask"])
