"""Make ``hypothesis`` optional for the property-based tests.

The tier-1 suite must collect and run in environments without dev extras
(the seed image has pytest but not hypothesis).  Importing ``given`` /
``settings`` / ``st`` from this module instead of from ``hypothesis``
keeps the example-based tests running everywhere and turns each
property-based test into an individual skip when hypothesis is missing —
the per-test equivalent of ``pytest.importorskip("hypothesis")``, without
skipping the whole module.

Install the real dependency with ``pip install -r requirements-dev.txt``.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        # Mirror hypothesis' decorator shape: the wrapper takes
        # (*args, **kwargs) so pytest does not mistake strategy parameters
        # for fixtures; the skip fires at call time.
        def deco(fn):
            def wrapper(*args, **kwargs):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Absorbs any strategy expression built at decoration time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()
