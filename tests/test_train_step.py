"""Train-step substrate: microbatching, compression flag, sharding specs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.rules import rules_for
from repro.models import RuntimeFlags, build_model
from repro.train import AdamWConfig, make_state_shardings, make_train_step
from repro.train.optimizer import adamw_init

# excluded from `make test-fast` (full arch/kernel e2e sweeps)
pytestmark = pytest.mark.slow

CFG = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                 num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                 vocab_size=128)


def setup(flags=None):
    mesh = make_local_mesh()
    flags = flags or RuntimeFlags(param_dtype="float32",
                                  compute_dtype="float32", remat="none")
    rules = rules_for(CFG, mesh, flags)
    model = build_model(CFG, flags, rules)
    params = model.init(jax.random.key(0))
    opt_cfg = AdamWConfig(warmup_steps=0, peak_lr=1e-3)
    state = {"params": params, "opt": adamw_init(params, opt_cfg),
             "step": jnp.zeros((), jnp.int32)}
    rng = np.random.default_rng(0)
    B, S = 4, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 128, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 128, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    return mesh, model, opt_cfg, state, batch


class TestMicrobatch:
    def test_microbatch_matches_full_batch_loss(self):
        mesh, model, opt_cfg, state, batch = setup()
        s1 = jax.jit(make_train_step(model, opt_cfg))
        s2 = jax.jit(make_train_step(model, opt_cfg, microbatch=2))
        _, m1 = s1(state, batch)
        _, m2 = s2(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)

    def test_microbatch_params_close(self):
        mesh, model, opt_cfg, state, batch = setup()
        s1 = jax.jit(make_train_step(model, opt_cfg))
        s2 = jax.jit(make_train_step(model, opt_cfg, microbatch=2))
        n1, _ = s1(state, batch)
        n2, _ = s2(state, batch)
        for a, b in zip(jax.tree.leaves(n1["params"]),
                        jax.tree.leaves(n2["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-4)


class TestCompression:
    def test_bf16_compression_step_runs(self):
        flags = RuntimeFlags(param_dtype="float32", compute_dtype="float32",
                             remat="none", grad_compression="bf16")
        mesh, model, opt_cfg, state, batch = setup(flags)
        step = jax.jit(make_train_step(model, opt_cfg))
        new_state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))


class TestShardings:
    def test_state_shardings_cover_tree(self):
        mesh, model, opt_cfg, state, batch = setup()
        rules = rules_for(CFG, mesh, model.flags)
        sh = make_state_shardings(model, mesh, rules, zero1=True)
        flat_state = jax.tree.leaves(state)
        flat_sh = jax.tree.leaves(
            sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
        assert len(flat_state) == len(flat_sh)
        assert all(isinstance(s, jax.sharding.NamedSharding)
                   for s in flat_sh)
