"""Streaming maintenance: after any sequence of insert/delete/reweight
edits the maintained schema must still be a valid mapping schema (the
``test_schema_conformance`` coverage/capacity/>=lower-bound properties),
and the streamed pair matrix must equal a cold full re-plan on the dense
executor.

Deterministic edit-sequence sweeps run everywhere; the @given variant
fuzzes the same properties when hypothesis is installed
(tests/_hypothesis_compat turns it into a per-test skip otherwise).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import PLAN_CACHE, a2a_comm_lower_bound
from repro.core.schema import InfeasibleError
from repro.core.strategies import PlanCache
from repro.mapreduce import get_executor, list_executors, make_executor
from repro.mapreduce.allpairs import _block_fn, pairwise_similarity
from repro.serve import PairwiseService
from repro.stream import IncrementalPlanner, StreamingExecutor

TOL = 1e-9


def _profile(kind: str, m: int, seed: int, q: float = 1.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.uniform(0.05, 0.33, m)
    if kind == "zipf":
        return np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45 * q)
    if kind == "small":                     # fits one reducer -> 'single'
        return rng.uniform(0.01, 0.04, m)
    if kind == "near-half":                 # hybrid/binpack-k2 territory
        return rng.uniform(0.30 * q, 0.49 * q, m)
    raise ValueError(kind)


def _check_conformance(planner: IncrementalPlanner) -> None:
    """The maintained schema passes the same coverage/capacity/bound
    checks test_schema_conformance.py applies to cold plans."""
    if planner.num_active == 0:
        return
    snap = planner.snapshot()
    snap.validate("a2a")
    lb = a2a_comm_lower_bound(planner.active_weights(), planner.q)
    assert snap.communication_cost() >= lb - TOL
    # the incrementally maintained cost ledger matches the real schema
    assert snap.communication_cost() == pytest.approx(planner.comm_cost)


def _apply_random_edit(planner, rng, q):
    act = planner.active_ids()
    op = rng.choice(["insert", "delete", "reweight"], p=[0.5, 0.3, 0.2])
    if op == "insert" or len(act) < 3:
        return planner.insert(float(rng.uniform(0.02, 0.45 * q))), "insert"
    if op == "delete":
        return planner.delete(int(rng.choice(act))), "delete"
    return planner.reweight(int(rng.choice(act)),
                            float(rng.uniform(0.02, 0.45 * q))), "reweight"


# ---------------------------------------------------------------- planner
class TestIncrementalPlanner:
    @pytest.mark.parametrize("kind,m,seed", [
        ("uniform", 7, 0), ("uniform", 23, 1), ("uniform", 48, 2),
        ("zipf", 23, 3), ("zipf", 48, 4),
        ("small", 12, 5), ("near-half", 16, 6),
    ])
    def test_random_edit_sequences_conform(self, kind, m, seed):
        q = 1.0
        rng = np.random.default_rng(seed)
        planner = IncrementalPlanner(q, _profile(kind, m, seed, q))
        _check_conformance(planner)
        for _ in range(25):
            delta, op = _apply_random_edit(planner, rng, q)
            assert delta.kind == op
            assert delta.num_reducers == planner.num_reducers
            assert 0.0 <= delta.recompute_fraction <= 1.0
            _check_conformance(planner)

    def test_insert_repairs_locally_on_binpack(self):
        """On a bin-packing schema a single insert dirties a strict
        minority of reducers (the paper's O(n) useful work, not O(n^2))."""
        q = 1.0
        w = _profile("zipf", 96, 0, q)
        planner = IncrementalPlanner(q, w)
        assert planner.kind == "binpack"
        deltas = [planner.insert(0.03) for _ in range(5)]
        for d in deltas:
            assert not d.full_replan
            assert d.recompute_fraction < 0.25
            assert len(d.dirty_rows) >= 1
        _check_conformance(planner)

    def test_delete_is_pure_patch(self):
        q = 1.0
        planner = IncrementalPlanner(q, _profile("uniform", 30, 1, q))
        delta = planner.delete(7)
        assert not planner.active[7]
        if not delta.full_replan:
            assert len(delta.dirty_rows) == 0
            assert list(delta.touched_inputs) == [7]
        _check_conformance(planner)

    def test_reweight_in_place_keeps_structure(self):
        q = 1.0
        planner = IncrementalPlanner(q, np.full(20, 0.18))
        assert planner.kind == "binpack"
        before = planner.num_reducers
        delta = planner.reweight(3, 0.19)        # tiny change: slack holds
        assert not delta.full_replan
        assert len(delta.dirty_rows) == 0 and len(delta.touched_inputs) == 0
        assert planner.num_reducers == before
        assert planner.weights[3] == pytest.approx(0.19)
        _check_conformance(planner)

    def test_reweight_overflow_moves_or_replans(self):
        """A reweight past the bin's slack must leave a conformant schema
        (bin move or re-plan) with the new weight in force."""
        q = 1.0
        planner = IncrementalPlanner(q, np.full(20, 0.18))
        delta = planner.reweight(3, 0.35)        # overflows the 0.2 bin
        assert planner.weights[3] == pytest.approx(0.35)
        assert delta.kind == "reweight"
        _check_conformance(planner)

    def test_gap_drift_triggers_amortized_replan(self):
        """A tight drift threshold forces the re-plan path; the schema
        stays conformant through it and the planner counts it.  Re-plans
        are *patch* deltas now (pair values are plan-independent, so the
        executor never cold-rebuilds): ``meta['replan']`` marks them,
        ``full_replan`` stays False."""
        q = 1.0
        rng = np.random.default_rng(2)
        planner = IncrementalPlanner(q, _profile("uniform", 40, 2, q),
                                     replan_drift=1.0 + 1e-9)
        saw_replan = False
        for _ in range(20):
            delta, _ = _apply_random_edit(planner, rng, q)
            saw_replan |= bool(delta.meta.get("replan"))
            assert not delta.full_replan
            _check_conformance(planner)
        assert saw_replan
        assert planner.stats["replans"] >= 2     # init + >=1 drift/forced

    def test_infeasible_insert_rolls_back(self):
        q = 1.0
        planner = IncrementalPlanner(q, np.array([0.6, 0.3]))
        m0, r0 = len(planner.weights), planner.num_reducers
        edits0, inv0 = planner.stats["edits"], PLAN_CACHE.invalidations
        key0 = planner._cache_key
        with pytest.raises(InfeasibleError):
            planner.insert(0.7)                  # two inputs > q/2
        assert len(planner.weights) == m0
        assert planner.num_reducers == r0
        # the rolled-back edit leaves the live profile's cache entry and
        # key intact and is not counted
        assert planner.stats["edits"] == edits0
        assert planner._cache_key == key0
        assert PLAN_CACHE.invalidations == inv0
        assert PLAN_CACHE.get(key0) is not None
        _check_conformance(planner)

    def test_plan_ids_reference_full_table(self):
        """plan() indexes the full (tombstoned) table; deleted ids never
        appear in any reducer slot."""
        q = 1.0
        planner = IncrementalPlanner(q, _profile("uniform", 24, 3, q))
        planner.delete(5)
        planner.insert(0.1)
        plan = planner.plan()
        used = np.unique(plan.idx[plan.mask])
        assert 5 not in used
        assert used.max(initial=0) < len(planner.weights)

    @given(st.lists(st.floats(0.02, 0.45), min_size=3, max_size=24),
           st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_property_random_profiles_and_edits(self, weights, seed):
        q = 1.0
        rng = np.random.default_rng(seed)
        planner = IncrementalPlanner(q, np.asarray(weights))
        for _ in range(8):
            _apply_random_edit(planner, rng, q)
            _check_conformance(planner)


# --------------------------------------------------------------- executor
class TestStreamingExecutor:
    def test_registered_lazily(self):
        ex = get_executor("streaming")
        assert isinstance(ex, StreamingExecutor)
        assert "streaming" in list_executors()
        fresh = make_executor("streaming")
        assert fresh is not ex and fresh.stats()["calls"] == 0

    @pytest.mark.parametrize("kind,m,seed", [
        ("uniform", 24, 0), ("zipf", 40, 1), ("small", 10, 2),
    ])
    @pytest.mark.parametrize("metric", ["dot", "cosine"])
    def test_streamed_matches_cold_dense_replan(self, kind, m, seed,
                                                metric):
        """After every edit the streamed matrix equals a cold full re-plan
        executed on the dense oracle."""
        q = 1.0
        rng = np.random.default_rng(seed)
        w = _profile(kind, m, seed, q)
        x = rng.normal(size=(m, 8)).astype(np.float32)
        planner = IncrementalPlanner(q, w)
        ex = make_executor("streaming")
        fn = _block_fn(metric, False)
        sims = ex.run_pairs(jnp.asarray(x), planner.plan(), fn, m)

        table = x
        for _ in range(10):
            delta, op = _apply_random_edit(planner, rng, q)
            if op == "insert":
                table = np.concatenate(
                    [table, rng.normal(size=(1, 8)).astype(np.float32)])
            sims = ex.apply_delta(jnp.asarray(table), delta, fn,
                                  table.shape[0],
                                  plan_provider=planner.plan)
            act = planner.active_ids()
            ref, _, _ = pairwise_similarity(
                jnp.asarray(table[act]), q=q,
                weights=planner.active_weights(), metric=metric,
                executor="dense")
            got = np.asarray(sims)[np.ix_(act, act)]
            np.testing.assert_allclose(got, np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
            # tombstoned rows/cols serve zeros
            dead = sorted(set(range(table.shape[0])) - set(act.tolist()))
            if dead:
                assert np.all(np.asarray(sims)[dead, :] == 0.0)
                assert np.all(np.asarray(sims)[:, dead] == 0.0)

    def test_stats_track_recompute(self):
        q = 1.0
        w = _profile("uniform", 48, 2, q)
        rng = np.random.default_rng(0)
        x = rng.normal(size=(48, 8)).astype(np.float32)
        planner = IncrementalPlanner(q, w)
        assert planner.kind == "binpack"         # the repair path is live
        ex = make_executor("streaming")
        fn = _block_fn("dot", False)
        ex.run_pairs(jnp.asarray(x), planner.plan(), fn, 48)
        delta = planner.insert(0.05)
        x = np.concatenate([x, rng.normal(size=(1, 8)).astype(np.float32)])
        ex.apply_delta(jnp.asarray(x), delta, fn, 49,
                       plan_provider=planner.plan)
        s = ex.stats()
        assert s["full_builds"] == 1
        if not delta.full_replan:
            assert s["delta_updates"] == 1
            assert s["recompute_fraction"] == pytest.approx(
                delta.recompute_fraction)
        assert s["reducers_total"] >= s["dirty_reducers"] > 0

    def test_delta_lowering_is_smaller(self):
        """The executor lowers the delta program over the dirty sub-plan;
        its gather is a fraction of the full plan's."""
        q = 1.0
        planner = IncrementalPlanner(q, _profile("zipf", 64, 1, q))
        delta = planner.insert(0.05)
        if delta.full_replan:
            pytest.skip("profile re-planned; no delta program")
        ex = get_executor("streaming")
        fn = _block_fn("dot", False)
        m = len(planner.weights)
        low_delta = ex.lower((m, 8), planner.plan(), reducer_fn=fn,
                             mesh=None, delta=delta)
        low_full = ex.lower((m, 8), planner.plan(), reducer_fn=fn,
                            mesh=None)
        rows = lambda lows: sum(b.idx.shape[0] * b.width for b, _ in lows)
        assert rows(low_delta) < rows(low_full)
        for _, lo in low_delta:
            assert "gather" in lo.compile().as_text().lower()


# -------------------------------------------------------- PlanCache (sat)
class TestPlanCacheInvalidate:
    def test_invalidate_and_eviction_stats(self):
        c = PlanCache(maxsize=2)
        k1 = PlanCache.key(np.array([1.0]), 1.0, "auto")
        k2 = PlanCache.key(np.array([2.0]), 1.0, "auto")
        k3 = PlanCache.key(np.array([3.0]), 1.0, "auto")
        c.put(k1, "a"), c.put(k2, "b")
        assert c.invalidate(k1) and not c.invalidate(k1)
        assert c.get(k1) is None                 # counted as a miss
        c.put(k1, "a"), c.put(k3, "c")           # overflows: evicts k2 (LRU)
        assert c.get(k2) is None
        s = c.stats()
        assert s["evictions"] == 1 and s["invalidations"] == 1
        assert s["size"] == 2 and s["maxsize"] == 2
        assert s["misses"] == 2 and s["hits"] == 0
        c.clear()
        s = c.stats()
        assert s["evictions"] == s["invalidations"] == s["hits"] == \
            s["misses"] == s["size"] == 0

    def test_drift_replan_invalidates_superseded_profile(self):
        """A streaming re-plan drops its *previous* profile's entry (this
        stream can never query it again) instead of letting churn evict
        live profiles."""
        inv0 = PLAN_CACHE.invalidations
        planner = IncrementalPlanner(1.0, _profile("uniform", 24, 0),
                                     replan_drift=1.0 + 1e-9)
        rng = np.random.default_rng(0)
        for _ in range(12):
            _apply_random_edit(planner, rng, 1.0)
        assert planner.stats["replans"] >= 2
        assert PLAN_CACHE.invalidations > inv0


# ----------------------------------------------------------- serving tier
class TestPairwiseServiceStreaming:
    def _service_with_table(self, m=24, d=8, seed=0, q=1.0):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(m, d)).astype(np.float32)
        w = _profile("uniform", m, seed, q)
        svc = PairwiseService(q, executor="streaming")
        sims, info = svc.load_table(x, w)
        return svc, rng, sims, info

    def test_edit_api_roundtrip(self):
        svc, rng, sims, info = self._service_with_table()
        assert info["executor"] == "streaming"
        ref, _, _ = pairwise_similarity(
            jnp.asarray(svc._table), q=svc.q,
            weights=svc._planner.active_weights(), executor="dense")
        np.testing.assert_allclose(np.asarray(sims), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

        sims, info = svc.add_input(rng.normal(size=8), weight=0.1)
        new = info["input_id"]
        assert info["kind"] == "insert"
        assert 0 < info["recompute_fraction"] <= 1.0
        assert info["gap_drift"] > 0
        # the new input's similarities are served
        act = svc._planner.active_ids()
        ref, _, _ = pairwise_similarity(
            jnp.asarray(svc._table[act]), q=svc.q,
            weights=svc._planner.active_weights(), executor="dense")
        np.testing.assert_allclose(
            np.asarray(sims)[np.ix_(act, act)], np.asarray(ref),
            rtol=1e-4, atol=1e-4)

        sims, info = svc.remove_input(new)
        assert info["kind"] == "delete"
        assert np.all(np.asarray(sims)[new] == 0.0)

        _, info = svc.update_weight(0, 0.2)
        assert info["kind"] == "reweight"
        assert svc.stats["edits"] == 3
        assert svc.stats["edit_reducers_total"] >= \
            svc.stats["dirty_reducers"]

    def test_edits_require_streaming_executor(self):
        rng = np.random.default_rng(0)
        svc = PairwiseService(1.0, executor="bucketed")
        with pytest.raises(AssertionError, match="streaming"):
            svc.load_table(rng.normal(size=(8, 4)).astype(np.float32))

    def test_failed_add_input_rolls_back_table(self):
        svc, rng, _, _ = self._service_with_table()
        m0 = svc._table.shape[0]
        with pytest.raises(InfeasibleError):
            svc.add_input(rng.normal(size=8), weight=5.0)  # > q
        assert svc._table.shape[0] == m0

    def test_reset_stats_clears_both_coherently(self):
        """The satellite fix: reset_stats() zeroes the request counters AND
        the private executor instance's counters together."""
        svc, rng, _, _ = self._service_with_table()
        svc.add_input(rng.normal(size=8), weight=0.1)
        assert svc.stats["requests"] > 0 and svc.stats["edits"] > 0
        assert svc.executor_stats()["calls"] > 0
        svc.reset_stats()
        assert all(v == 0 for v in svc.stats.values())
        assert all(v == 0 for v in svc.executor_stats().values())
        # the service keeps serving after a reset
        sims, info = svc.add_input(rng.normal(size=8), weight=0.1)
        assert svc.stats["edits"] == 1
        assert svc.executor_stats()["calls"] == 1

    def test_streaming_on_multi_device_mesh(self):
        """Streaming serving under a real 2-device mesh: the planner pads
        reducer rows (full plan AND delta sub-plans) to the device count,
        so cold builds and edits both shard (subprocess: the main test
        process keeps its default device count)."""
        import subprocess
        import sys
        import textwrap
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \\
                "--xla_force_host_platform_device_count=2"
            import jax, jax.numpy as jnp, numpy as np
            assert len(jax.devices()) == 2, jax.devices()
            from repro.compat import make_mesh
            from repro.mapreduce import pairwise_similarity
            from repro.serve import PairwiseService

            rng = np.random.default_rng(0)
            m, d = 25, 6
            x = rng.normal(size=(m, d)).astype(np.float32)
            w = rng.uniform(0.05, 0.33, m)
            mesh = make_mesh((2,), ("r",))
            svc = PairwiseService(1.0, executor="streaming", mesh=mesh)
            sims, _ = svc.load_table(x, w)
            for _ in range(4):
                sims, info = svc.add_input(
                    rng.normal(size=d).astype(np.float32), 0.1)
            act = svc._planner.active_ids()
            ref, _, _ = pairwise_similarity(
                jnp.asarray(svc._table[act]), q=1.0,
                weights=svc._planner.active_weights(), executor="dense")
            np.testing.assert_allclose(
                np.asarray(sims)[np.ix_(act, act)], np.asarray(ref),
                rtol=1e-4, atol=1e-4)
            print("STREAM_MESH_OK", info["recompute_fraction"])
        """)
        import os
        res = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src",
                 "PATH": "/usr/bin:/bin:/usr/local/bin",
                 "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
                 "HOME": os.environ.get("HOME", "/tmp")},
        )
        assert "STREAM_MESH_OK" in res.stdout, res.stdout + res.stderr

    def test_reset_stats_non_streaming(self):
        """reset_stats works on every executor, not just streaming."""
        rng = np.random.default_rng(0)
        svc = PairwiseService(1.0, executor="bucketed")
        x = rng.normal(size=(12, 4)).astype(np.float32)
        svc.similarity(x, weights=np.full(12, 0.2))
        assert svc.stats["requests"] == 1
        svc.reset_stats()
        assert svc.stats["requests"] == 0
        assert svc.executor_stats()["calls"] == 0
